import importlib.util
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

# ---------------------------------------------------------------------------
# Multi-device host platform for the in-process sharded suite
# (tests/test_dmf_sharded.py and friends): conftest runs before any test
# module imports jax, which is early enough — jax binds XLA_FLAGS at first
# backend init, not import (so importing repro.launch.mesh here is safe).
# 8 virtual CPU devices; single-device tests are unaffected (everything
# placed on device 0 by default).
# ---------------------------------------------------------------------------
N_TEST_DEVICES = 8
sys.path.insert(0, str(REPO / "src"))
from repro.launch.mesh import ensure_host_platform_devices  # noqa: E402

ensure_host_platform_devices(N_TEST_DEVICES)

# ---------------------------------------------------------------------------
# Property tests without a package index: when the real `hypothesis` is not
# installed (see tests/requirements.txt), register the offline fallback under
# its name BEFORE test modules import it, so `pytest.importorskip` finds a
# working module instead of skipping the 8 property-test files wholesale.
# ---------------------------------------------------------------------------
if importlib.util.find_spec("hypothesis") is None:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).with_name("_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies


def run_in_subprocess_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a fresh process with exactly ``n_devices``
    XLA host-platform devices (overriding whatever count this process runs
    under) — for lowering/executing tests that must control the device
    count independently of the suite-wide 8-device default above."""
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
    }
    env = {**os.environ, **env}
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=str(REPO),
    )
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{res.stdout[-4000:]}\nSTDERR:\n{res.stderr[-4000:]}"
        )
    return res.stdout
