import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_in_subprocess_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet with XLA host platform devices (the dry-run-style
    device-count flag must never be set in THIS process — smoke tests and
    benches are required to see the real single CPU device)."""
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
        "PYTHONPATH": str(REPO / "src"),
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
    }
    import os
    env = {**os.environ, **env}
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=str(REPO),
    )
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{res.stdout[-4000:]}\nSTDERR:\n{res.stderr[-4000:]}"
        )
    return res.stdout
