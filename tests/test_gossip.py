"""DMF gossip protocol at pod scale (core/gossip.py).

Validates the Nedic-Ozdaglar conditions the paper leans on: mixing is
mean-preserving (doubly stochastic), drives consensus, and never touches
the personal (q^i) partition.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import gossip
from tests.conftest import run_in_subprocess_with_devices


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.floats(0.2, 0.9), st.integers(0, 99))
def test_ring_mix_preserves_mean(L, w_self, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(L, 5)), jnp.float32)
    cfg = gossip.GossipConfig(self_weight=w_self)
    y = gossip.ring_mix(x, cfg)
    np.testing.assert_allclose(
        np.asarray(y.mean(0)), np.asarray(x.mean(0)), rtol=1e-4, atol=1e-5
    )


def test_mixing_contracts_to_consensus():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    cfg = gossip.GossipConfig(self_weight=0.5)
    devs = [float(jnp.abs(x - x.mean(0)).max())]
    for _ in range(40):
        x = gossip.ring_mix(x, cfg)
        devs.append(float(jnp.abs(x - x.mean(0)).max()))
    assert devs[-1] < 0.05 * devs[0]
    assert all(b <= a + 1e-6 for a, b in zip(devs, devs[1:]))


def test_walk_length_matches_matrix_power():
    """D rounds of ring mixing == applying the ring matrix W^D (Eq. 4)."""
    L, D = 6, 3
    rng = np.random.default_rng(1)
    x = np.asarray(rng.normal(size=(L, 2)), np.float32)
    cfg = gossip.GossipConfig(self_weight=0.5, walk_length=D)
    W = np.zeros((L, L), np.float32)
    for i in range(L):
        W[i, i] = 0.5
        W[i, (i - 1) % L] = 0.25
        W[i, (i + 1) % L] = 0.25
    want = np.linalg.matrix_power(W, D) @ x
    got = jnp.asarray(x)
    for _ in range(D):
        got = gossip.ring_mix(got, cfg)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_personal_partition_untouched():
    params = {
        "blocks": {"0": {"attn": {"wq": jnp.ones((4, 3, 2))},
                         "ln1": jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)}},
    }
    cfg = gossip.GossipConfig(walk_length=2)
    mixed = gossip.mix_global(params, cfg)
    # ln1 (personal, q^i) unchanged; wq (global, p) mixed
    np.testing.assert_array_equal(
        np.asarray(mixed["blocks"]["0"]["ln1"]),
        np.asarray(params["blocks"]["0"]["ln1"]),
    )
    assert not np.allclose(
        np.asarray(mixed["blocks"]["0"]["attn"]["wq"]).std(0), 0
    ) or True
    # wq constant across learners stays constant (fixed point)
    np.testing.assert_allclose(
        np.asarray(mixed["blocks"]["0"]["attn"]["wq"]),
        np.asarray(params["blocks"]["0"]["attn"]["wq"]), rtol=1e-6,
    )


def test_gossip_training_converges_small_lm():
    """End-to-end: gossip-trained tiny LM loss decreases and learners reach
    approximate consensus (the paper's convergence claim, transformer-scale)."""
    run_in_subprocess_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.core import gossip as gossip_lib
from repro.data.lm_pipeline import LMDataConfig, SyntheticLM
from repro.launch.train import make_train_step
from repro.models import config as mc
from repro.optim import adamw

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = mc.reduced(registry.get_config("qwen1.5-4b"), n_kv_heads=4, vocab_size=256,
                 d_model=128, d_ff=256, n_heads=4, head_dim=32)
gcfg = gossip_lib.GossipConfig(learner_axis="data", walk_length=2)
step, init_fn, pshard = make_train_step(cfg, mesh, adamw(6e-3), sync="gossip", gossip=gcfg)
state = init_fn(jax.random.PRNGKey(0))
data = SyntheticLM(LMDataConfig(vocab_size=256, seq_len=64, batch_size=16, seed=0))
losses = []
for i in range(60):
    b = data.batch(i)
    state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
    losses.append(float(m["loss"]))
cons = float(m["consensus_err"])
assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
assert cons < 0.5, cons
print("OK", losses[0], losses[-1], cons)
""", n_devices=8, timeout=900)
