"""Sparse-neighborhood fused training path == seed dense reference.

The production path (neighbor-table scatter + lax.scan epochs, optional
fused Pallas step) must reproduce the seed per-batch dense-M loop —
same losses, same factors — for every mode and for paper_literal
weighting. See DESIGN.md §5 for the equivalence argument.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.kernels import ops, ref


def _world(seed=0):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=50, n_ratings=600, n_cities=4, seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    return ds, gcfg, W


def test_neighbor_table_reconstructs_dense_m():
    ds, gcfg, W = _world()
    for cfg in [gcfg, graph.GraphConfig(n_neighbors=2, walk_length=3,
                                        paper_literal=True)]:
        M = graph.walk_propagation_matrix(W, cfg)
        nbr = graph.walk_neighbor_table(W, cfg)
        # S is the max realized 1 + |N^D(i)| (self always has M[i,i]=1)
        nnz = (M != 0).sum(axis=1)
        assert nbr.idx.shape == (ds.n_users, int(nnz.max()))
        Md = graph.dense_from_neighbor_table(nbr, ds.n_users)
        np.testing.assert_array_equal(Md, M)
        # padded slots are zero-weight self-indices -> scatter no-ops
        pad = np.asarray(nbr.wgt) == 0.0
        np.testing.assert_array_equal(
            np.asarray(nbr.idx)[pad],
            np.broadcast_to(np.arange(ds.n_users)[:, None], nbr.idx.shape)[pad],
        )


@pytest.mark.parametrize("mode", ["dmf", "gdmf", "ldmf"])
def test_scan_sparse_epoch_matches_dense_reference(mode):
    ds, gcfg, W = _world()
    M = graph.walk_propagation_matrix(W, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                        mode=mode, batch_size=64, beta=0.1, gamma=0.01)
    rd = dmf.fit(cfg, ds.train, M, epochs=3, test=ds.test, dense_reference=True)
    rs = dmf.fit(cfg, ds.train, nbr, epochs=3, test=ds.test)
    np.testing.assert_allclose(rd.train_losses, rs.train_losses, atol=1e-4)
    np.testing.assert_allclose(rd.test_losses, rs.test_losses, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rd.state.U), np.asarray(rs.state.U),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(rd.state.P), np.asarray(rs.state.P),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(rd.state.Q), np.asarray(rs.state.Q),
                               atol=1e-5)


def test_scan_sparse_epoch_matches_dense_paper_literal():
    ds, _, W = _world()
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=2, paper_literal=True)
    M = graph.walk_propagation_matrix(W, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    # tiny lr: the literal |N^d| amplification diverges fast otherwise
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=4,
                        batch_size=64, lr=0.01)
    rd = dmf.fit(cfg, ds.train, M, epochs=2, dense_reference=True)
    rs = dmf.fit(cfg, ds.train, nbr, epochs=2)
    np.testing.assert_allclose(rd.train_losses, rs.train_losses, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rd.state.P), np.asarray(rs.state.P),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("mode", ["dmf", "gdmf", "ldmf"])
def test_pallas_fused_step_path_matches_jnp(mode):
    ds, gcfg, W = _world(seed=1)
    nbr = graph.walk_neighbor_table(W, gcfg)
    kw = dict(n_users=ds.n_users, n_items=ds.n_items, dim=6, mode=mode,
              batch_size=64)
    rj = dmf.fit(dmf.DMFConfig(**kw), ds.train, nbr, epochs=2, test=ds.test)
    rp = dmf.fit(dmf.DMFConfig(**kw, use_pallas=True), ds.train, nbr,
                 epochs=2, test=ds.test)
    np.testing.assert_allclose(rj.train_losses, rp.train_losses, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rj.state.U), np.asarray(rp.state.U),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(rj.state.P), np.asarray(rp.state.P),
                               atol=1e-5)


def test_fused_step_kernel_matches_ref():
    rng = np.random.default_rng(3)
    B, K = 300, 10   # non-aligned on purpose: exercises batch + lane padding
    u, p, q = (jnp.asarray(rng.normal(size=(B, K)), jnp.float32) for _ in range(3))
    r = jnp.asarray(rng.integers(0, 2, B), jnp.float32)
    c = jnp.asarray(rng.uniform(0.2, 1.0, B), jnp.float32)
    got = ops.dmf_fused_step(u, p, q, r, c, theta=0.1, alpha=0.3, beta=0.2,
                             gamma=0.1)
    want = ref.dmf_fused_step_ref(u, p, q, r, c, 0.1, 0.3, 0.2, 0.1)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_streaming_evaluate_matches_dense_evaluate():
    ds, gcfg, W = _world()
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                        beta=0.1, gamma=0.01, batch_size=64)
    res = dmf.fit(cfg, ds.train, nbr, epochs=10)
    ev_s = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
    ev_d = dmf.evaluate_dense(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
    for k in ev_d:
        np.testing.assert_allclose(ev_s[k], ev_d[k], atol=1e-9, err_msg=k)


def test_recommend_topk_peruser_matches_ref():
    rng = np.random.default_rng(5)
    I, J, K, k = 70, 90, 7, 10
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    mask = jnp.asarray(rng.random((I, J)) < 0.2)
    vals, idx = ops.recommend_topk_peruser(U, V, mask, k)
    v_ref, i_ref = ref.topk_scores_peruser_ref(U, V, mask, k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               rtol=1e-5, atol=1e-6)
    # continuous random scores: ties have measure zero -> indices agree
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
