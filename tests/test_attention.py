"""Attention layer correctness: blockwise==dense, decode==train slice,
MLA absorbed decode == expanded attention."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import attention as A
from repro.models.config import LayerSpec, ModelConfig


def _dense_ref(q, k, v, causal=True, q_offset=0):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    kf = np.repeat(np.asarray(k, np.float32), G, axis=2)
    vf = np.repeat(np.asarray(v, np.float32), G, axis=2)
    s = np.einsum("bqhd,bshd->bhqs", np.asarray(q, np.float32), kf) / math.sqrt(hd)
    if causal:
        qpos = q_offset + np.arange(Sq)
        mask = qpos[:, None] >= np.arange(Sk)[None]
        s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqs,bshd->bqhd", p, vf)


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(64, 64), (128, 128), (96, 128), (128, 256)]),
    st.sampled_from([(4, 4), (4, 2), (8, 1)]),
    st.booleans(), st.integers(0, 99),
)
def test_blockwise_matches_dense(sqk, heads, causal, seed):
    Sq, Sk = sqk
    if Sq > Sk:
        return
    H, KV = heads
    rng = np.random.default_rng(seed)
    B, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, hd)), jnp.float32)
    off = Sk - Sq
    got = A.blockwise_attention(q, k, v, causal=causal, q_offset=off,
                                q_chunk=32, kv_chunk=32)
    want = _dense_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_decode_attend_matches_last_row():
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 33, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    # decode at position S-1 (length S)
    got = A.decode_attend(q[:, 0], k, v, jnp.asarray(S))
    want = _dense_ref(q, k, v, causal=True, q_offset=S - 1)[:, 0]
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_decode_attend_respects_length_mask():
    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 1, 16, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    got8 = A.decode_attend(q, k, v, jnp.asarray(8))
    # garbage beyond position 8 must not matter
    k2 = k.at[:, 8:].set(999.0)
    v2 = v.at[:, 8:].set(-999.0)
    got8b = A.decode_attend(q, k2, v2, jnp.asarray(8))
    np.testing.assert_allclose(np.asarray(got8), np.asarray(got8b), rtol=1e-5)


def _mla_cfg():
    return ModelConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, attn_type="mla", kv_lora_rank=32,
        rope_head_dim=8, v_head_dim=16, period=(LayerSpec(kind="attn"),),
        compute_dtype="float32",
    )


def test_mla_absorbed_decode_matches_full():
    """Absorbed-latent decode == expanded-KV attention at the last position."""
    cfg = _mla_cfg()
    params, _ = A.init_mla(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    B, S = 2, 12
    x = jnp.asarray(rng.normal(0, 0.5, (B, S, cfg.d_model)), jnp.float32)
    positions = jnp.arange(S)[None, :]
    out_full, (ckv, kr) = A.mla_attend_full(params, x, positions, cfg,
                                            jnp.float32, kv_chunk=64)
    out_dec = A.mla_decode(
        params, x[:, -1:], ckv, kr, jnp.asarray(S),
        jnp.full((B, 1), S - 1), cfg, jnp.float32,
    )
    np.testing.assert_allclose(
        np.asarray(out_dec[:, 0]), np.asarray(out_full[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_cross_attend_gate_zero_init():
    cfg = ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, n_image_tokens=8, period=(LayerSpec(kind="cross"),),
        compute_dtype="float32",
    )
    params, _ = A.init_cross_attn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 6, 32)), jnp.float32)
    media = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
    out = A.cross_attend(params, x, media, cfg, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), 0.0)  # tanh(0) gate
