"""User adjacency graph + random-walk propagation (paper Eqs. 2-4)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import graph


def _toy(I=40, C=3, seed=0):
    rng = np.random.default_rng(seed)
    cities = rng.integers(0, C, size=I)
    coords = (cities[:, None] * 100.0) + rng.normal(0, 1, (I, 2))
    return coords.astype(np.float32), cities


def test_adjacency_same_city_only():
    coords, cities = _toy()
    W = graph.build_adjacency(coords, cities, graph.GraphConfig(n_neighbors=3))
    idx = np.argwhere(W > 0)
    assert len(idx) > 0
    for i, j in idx:
        assert cities[i] == cities[j], "Eq. 2 indicator violated"


def test_adjacency_symmetric_no_selfloop():
    coords, cities = _toy()
    W = graph.build_adjacency(coords, cities, graph.GraphConfig(n_neighbors=2))
    assert np.allclose(W, W.T)
    assert np.all(np.diag(W) == 0)


def test_top_n_truncation_bounds_degree():
    coords, cities = _toy(I=60)
    N = 2
    W = graph.build_adjacency(coords, cities, graph.GraphConfig(n_neighbors=N))
    # each user *selects* at most N neighbors; symmetrization can add
    # unbounded in-edges (popular users), so the sharp bound is on the
    # total edge count: <= 2 * N * I after max(W, W^T)
    deg = (W > 0).sum(1)
    assert (W > 0).sum() <= 2 * N * len(deg)
    assert deg.mean() <= 2 * N


def test_row_normalize_stochastic():
    coords, cities = _toy()
    W = graph.build_adjacency(coords, cities, graph.GraphConfig(n_neighbors=2))
    What = graph.row_normalize(W)
    sums = What.sum(1)
    nz = (W.sum(1) > 0)
    assert np.allclose(sums[nz], 1.0, atol=1e-5)
    assert np.allclose(sums[~nz], 0.0)


def test_walk_matrix_includes_self_and_hops():
    coords, cities = _toy()
    cfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(coords, cities, cfg)
    M = graph.walk_propagation_matrix(W, cfg)
    assert np.allclose(np.diag(M) >= 1.0, True)   # line-11 self update
    # row mass bounded: 1 (self) + D stochastic rows
    assert M.sum(1).max() <= 1 + cfg.walk_length + 1e-4


def test_walk_distance_monotone_reach():
    coords, cities = _toy(I=80)
    W = graph.build_adjacency(coords, cities, graph.GraphConfig(n_neighbors=2))
    reach = []
    for D in [1, 2, 3, 4]:
        cfg = graph.GraphConfig(n_neighbors=2, walk_length=D)
        M = graph.walk_propagation_matrix(W, cfg)
        reach.append((M > 1e-9).sum())
    assert all(b >= a for a, b in zip(reach, reach[1:])), reach


def test_paper_literal_amplifies():
    coords, cities = _toy()
    cfg_n = graph.GraphConfig(n_neighbors=2, walk_length=2)
    cfg_l = graph.GraphConfig(n_neighbors=2, walk_length=2, paper_literal=True)
    W = graph.build_adjacency(coords, cities, cfg_n)
    Mn = graph.walk_propagation_matrix(W, cfg_n)
    Ml = graph.walk_propagation_matrix(W, cfg_l)
    assert Ml.sum() >= Mn.sum()


@settings(max_examples=20, deadline=None)
@given(st.integers(10, 60), st.integers(1, 4), st.integers(1, 4))
def test_property_walk_row_mass(I, N, D):
    rng = np.random.default_rng(I * 7 + N)
    cities = rng.integers(0, 3, size=I)
    coords = (cities[:, None] * 50.0 + rng.normal(0, 1, (I, 2))).astype(np.float32)
    cfg = graph.GraphConfig(n_neighbors=N, walk_length=D)
    W = graph.build_adjacency(coords, cities, cfg)
    M = graph.walk_propagation_matrix(W, cfg)
    # propagation mass of any sender is within [1, 1+D] (self + D hops)
    assert (M.sum(1) <= 1 + D + 1e-4).all()
    assert (M.sum(1) >= 1 - 1e-6).all()
    assert np.isfinite(M).all()


def test_communication_bytes_linear_in_ratings():
    coords, cities = _toy(I=50)
    W = graph.build_adjacency(coords, cities, graph.GraphConfig(n_neighbors=2))
    b1 = graph.communication_bytes(W, D=3, K=10, n_ratings=1000)
    b2 = graph.communication_bytes(W, D=3, K=10, n_ratings=2000)
    assert b2 == 2 * b1
