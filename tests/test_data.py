"""Synthetic POI generator + LM pipeline invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import lm_pipeline, synthetic_poi


def test_poi_split_disjoint_and_sized():
    ds = synthetic_poi.foursquare_like(reduced=True)
    tr = {tuple(x) for x in ds.train}
    te = {tuple(x) for x in ds.test}
    assert not (tr & te)
    n = len(tr) + len(te)
    assert abs(len(te) / n - 0.10) < 0.03


def test_poi_location_aggregation():
    """Paper Fig. 2: most check-ins are in the user's home city."""
    ds = synthetic_poi.foursquare_like(reduced=True)
    allr = np.concatenate([ds.train, ds.test])
    same = (ds.user_city[allr[:, 0]] == ds.item_city[allr[:, 1]]).mean()
    assert same > 0.9, same


def test_poi_indices_in_range():
    ds = synthetic_poi.alipay_like(reduced=True)
    allr = np.concatenate([ds.train, ds.test])
    assert allr[:, 0].max() < ds.n_users and allr[:, 0].min() >= 0
    assert allr[:, 1].max() < ds.n_items and allr[:, 1].min() >= 0


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 3))
def test_poi_generator_deterministic(seed):
    a = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=60, n_ratings=500, n_cities=4, seed=seed))
    b = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=60, n_ratings=500, n_cities=4, seed=seed))
    np.testing.assert_array_equal(a.train, b.train)
    np.testing.assert_array_equal(a.user_coords, b.user_coords)


def test_lm_pipeline_shapes_and_determinism():
    cfg = lm_pipeline.LMDataConfig(vocab_size=128, seq_len=32, batch_size=4)
    p = lm_pipeline.SyntheticLM(cfg)
    b1 = p.batch(7)
    b2 = p.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert b1["tokens"].max() < 128
    mb = p.batch(3, n_codebooks=4)
    assert mb["tokens"].shape == (4, 32, 4)
