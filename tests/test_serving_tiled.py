"""Million-user tiled serving: window kernel == slab kernel == dense oracle
(bitwise, including tie-heavy zero-init inputs), quantized-V error bounds,
cold-city / empty-input candidate-index regressions, chunked eligibility,
hierarchical geohash-cell index invariants, TiledServingEngine parity with
the classic ServingEngine, streaming evaluate exactness, and a slow
1M-user peak-memory smoke."""
import dataclasses

import numpy as np
import pytest

from repro.core import dmf, graph, metrics
from repro.data import synthetic_poi
from repro.kernels import ops, ref
from repro.serving import (ServingConfig, ServingEngine, SyntheticFactors,
                           TiledFactorStore, TiledServingEngine,
                           build_candidate_index, build_hierarchical_index,
                           index_from_dataset, synthetic_world)

pytestmark = pytest.mark.serving


def _world(seed=0, epochs=4):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=50, n_ratings=600, n_cities=4, seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                        beta=0.1, gamma=0.01, batch_size=64)
    res = dmf.fit(cfg, ds.train, nbr, epochs=epochs)
    return ds, nbr, cfg, res.state


def _random_windows(rng, R, J, Cw, K, zero_factors=False):
    """Random per-request candidate windows + matching dense inputs so the
    window kernel can be cross-checked against the whole-slab kernel and
    the dense oracle on identical problems."""
    U = rng.standard_normal((R, K)).astype(np.float32)
    V = rng.standard_normal((R, J, K)).astype(np.float32)
    if zero_factors:
        # tie-heavy regime: zero factors make every candidate score 0.0 —
        # the tie-break contract (lowest candidate id wins) is all that
        # orders the slate, exactly the zero-init serving cold-start case.
        U[:] = 0.0
        V[:] = 0.0
    seen = (rng.random((R, J)) < 0.2)
    cand = np.full((R, Cw), -1, np.int32)
    for r in range(R):
        n = rng.integers(1, Cw + 1)
        cand[r, :n] = np.sort(rng.choice(J, size=n, replace=False))
    safe = np.maximum(cand, 0)
    Vw = V[np.arange(R)[:, None], safe]                       # (R, Cw, K)
    seen_w = np.where(cand >= 0, seen[np.arange(R)[:, None], safe], False)
    return U, V, seen, cand, Vw, seen_w.astype(np.int8)


# ------------------------------------------------------- tiled kernel family
@pytest.mark.parametrize("zero_factors", [False, True],
                         ids=["random", "tie-heavy-zero-init"])
def test_window_kernel_matches_slab_and_oracle(zero_factors):
    rng = np.random.default_rng(0)
    R, J, Cw, K, k = 5, 40, 17, 6, 8
    U, V, seen, cand, Vw, seen_w = _random_windows(
        rng, R, J, Cw, K, zero_factors)
    wv, wi = ops.serve_topk_window(U, Vw, cand, seen_w, k)
    sv, si = ops.serve_topk(U, V, cand, seen, k)
    rv, ri = ref.serve_topk_window_ref(U, Vw, cand, seen_w, k)
    dv, di = ref.serve_topk_ref(U, V, cand, seen, k)
    # all four agree bitwise: window kernel == slab kernel == both oracles
    for v2, i2 in [(sv, si), (rv, ri), (dv, di)]:
        np.testing.assert_array_equal(np.asarray(wi), np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(wv), np.asarray(v2))
    if zero_factors:
        # the slate is ordered purely by the tie contract: ascending
        # candidate ids among unseen candidates
        for r in range(R):
            unseen = cand[r][(cand[r] >= 0) & (seen_w[r] == 0)]
            want = np.sort(unseen)[:k]
            got = np.asarray(wi)[r][np.asarray(wi)[r] >= 0]
            np.testing.assert_array_equal(got, want)


def test_window_kernel_multiple_tiles_and_padding():
    # Cw spanning several 128-lane tiles with a ragged tail exercises the
    # inner-grid streaming and the -1 padding path together.
    rng = np.random.default_rng(1)
    R, J, Cw, K, k = 9, 700, 300, 8, 10
    U, V, seen, cand, Vw, seen_w = _random_windows(rng, R, J, Cw, K)
    wv, wi = ops.serve_topk_window(U, Vw, cand, seen_w, k)
    rv, ri = ref.serve_topk_window_ref(U, Vw, cand, seen_w, k)
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(wv), np.asarray(rv))


def test_quant_kernel_bitwise_equals_dequantized_window():
    """The int8 kernel's in-kernel dequant (codes·scale, f32) must equal
    running the fp32 window kernel on host-dequantized values — bitwise,
    since both perform the identical f32 multiply before the contraction."""
    rng = np.random.default_rng(2)
    R, J, Cw, K, k = 6, 60, 20, 5, 7
    U, V, seen, cand, Vw, seen_w = _random_windows(rng, R, J, Cw, K)
    scale = np.maximum(np.abs(Vw).max(axis=(1, 2)) / 127.0, 1e-12)
    scale = scale.astype(np.float32)
    codes = np.clip(np.rint(Vw / scale[:, None, None]), -127, 127
                    ).astype(np.int8)
    qv, qi = ops.serve_topk_window_quant(U, codes, scale, cand, seen_w, k)
    deq = codes.astype(np.float32) * scale[:, None, None]
    fv, fi = ops.serve_topk_window(U, deq, cand, seen_w, k)
    np.testing.assert_array_equal(np.asarray(qi), np.asarray(fi))
    np.testing.assert_array_equal(np.asarray(qv), np.asarray(fv))


def test_quant_scores_within_analytic_bound_and_exact_on_gaps():
    rng = np.random.default_rng(3)
    R, Cw, K, k = 8, 24, 6, 5
    U = rng.standard_normal((R, K)).astype(np.float32)
    # gap-separated construction: candidate c of request r scores ~ 3·c,
    # far above any quantization error, so int8 must return the exact
    # fp32 top-k slate (overlap 1.0), not merely a close one.
    Vw = np.zeros((R, Cw, K), np.float32)
    for r in range(R):
        u = U[r]
        Vw[r] = np.outer(3.0 * np.arange(Cw), u / (u @ u))
    cand = np.tile(np.arange(Cw, dtype=np.int32), (R, 1))
    seen_w = np.zeros((R, Cw), np.int8)
    scale = np.maximum(np.abs(Vw).max(axis=(1, 2)) / 127.0, 1e-12
                       ).astype(np.float32)
    codes = np.clip(np.rint(Vw / scale[:, None, None]), -127, 127
                    ).astype(np.int8)
    qv, qi = ops.serve_topk_window_quant(U, codes, scale, cand, seen_w, k)
    fv, fi = ops.serve_topk_window(U, Vw, cand, seen_w, k)
    np.testing.assert_array_equal(np.asarray(qi), np.asarray(fi))
    bound = np.abs(U).sum(axis=1) * scale * 0.5        # ||u||₁ · scale/2
    delta = np.abs(np.asarray(qv) - np.asarray(fv))
    assert (delta <= bound[:, None] + 1e-6).all(), (delta.max(), bound)


# ----------------------------------------------- cold-city index regressions
def test_build_candidate_index_city_with_users_but_no_items():
    """Regression: a city appearing only in user_city used to crash the
    builder (C was derived from item_city alone, so user buckets indexed
    out of range). Such users get an empty bucket, not a crash."""
    item_city = np.array([0, 0, 1], np.int64)
    user_city = np.array([0, 1, 2, 2], np.int64)   # city 2 has no POIs
    idx = build_candidate_index(item_city, user_city)
    assert idx.n_buckets == 3
    assert idx.bucket_size[2] == 0
    assert (idx.bucket_items[2] == -1).all()
    np.testing.assert_array_equal(idx.user_bucket, user_city)


def test_build_candidate_index_empty_arrays():
    idx = build_candidate_index(np.empty(0, np.int64), np.empty(0, np.int64))
    assert idx.n_buckets == 1 and (idx.bucket_items == -1).all()
    idx2 = build_candidate_index(np.array([0, 1]), np.empty(0, np.int64))
    assert idx2.n_buckets == 2 and len(idx2.user_bucket) == 0


def test_engine_cold_city_fallback_round_trip():
    """End-to-end: users whose city has zero POIs are served the flagged
    popularity slate by both engines (classic and tiled), identically."""
    ds, nbr, cfg, state = _world()
    user_city = ds.user_city.copy()
    user_city[:5] = ds.item_city.max() + 1   # rehome 5 users to a POI-less city
    idx = build_candidate_index(ds.item_city, user_city)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    eng = ServingEngine(state, idx, ServingConfig(microbatch=32),
                        train=ds.train)
    v1, i1, f1 = eng.recommend(np.arange(ds.n_users), return_flags=True)
    assert f1[:5].all()
    np.testing.assert_array_equal(np.asarray(i1)[:5],
                                  np.tile(eng._pop_items, (5, 1)))
    store = TiledFactorStore.from_state(state, idx, seen)
    teng = TiledServingEngine(store, ServingConfig(microbatch=32))
    v2, i2, f2 = teng.recommend(np.arange(ds.n_users), return_flags=True)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(np.asarray(i1), i2)
    np.testing.assert_array_equal(np.asarray(v1), v2)


def test_eligible_mask_chunked_matches_whole():
    ds, *_ = _world(epochs=0)
    idx = index_from_dataset(ds)
    users = np.arange(ds.n_users)
    whole = idx.eligible_mask(users)
    parts = list(idx.eligible_mask_chunks(users, rows_per_chunk=7))
    assert [s for s, _ in parts] == list(range(0, ds.n_users, 7))
    np.testing.assert_array_equal(np.concatenate([m for _, m in parts]), whole)
    np.testing.assert_array_equal(idx.eligible_mask(users, rows_per_chunk=7),
                                  whole)


# ------------------------------------------------------- hierarchical index
def test_hierarchical_index_invariants():
    rng = np.random.default_rng(4)
    uc, ic, ucoord, icoord = synthetic_world(3000, 800, 6, seed=5)
    hier = build_hierarchical_index(ic, uc, icoord, ucoord, cell_cap=64)
    flat = hier.flat
    # every item lands in exactly one cell, of its own city and ≤ cell_cap
    assert hier.cell_of_item.min() >= 0
    for c in range(hier.n_cells):
        members = np.flatnonzero(hier.cell_of_item == c)
        assert len(members) <= 64
        if len(members):
            assert (ic[members] == hier.cell_city[c]).all()
        # the flat index bucket holds exactly the cell's items, ascending
        row = flat.bucket_items[c]
        np.testing.assert_array_equal(row[row >= 0], members)
    # users are assigned to cells of their own city
    assert (hier.cell_city[hier.cell_of_user] == uc).all()
    np.testing.assert_array_equal(flat.user_bucket, hier.cell_of_user)
    # subdivision actually engaged (cities are bigger than cell_cap)
    assert hier.n_cells > 6 and hier.max_depth >= 1
    st = hier.stats()
    assert st["n_cells"] == hier.n_cells and st["cap"] == flat.cap


def test_hierarchical_cells_reduce_cap():
    uc, ic, ucoord, icoord = synthetic_world(2000, 4000, 4, seed=6)
    flat = build_candidate_index(ic, uc)
    hier = build_hierarchical_index(ic, uc, icoord, ucoord, cell_cap=128)
    assert hier.flat.cap < flat.cap    # the point of the hierarchy


# --------------------------------------------------- tiled store and engine
def test_tiled_store_matches_serving_engine_bitwise():
    ds, nbr, cfg, state = _world()
    idx = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    eng = ServingEngine(state, idx, ServingConfig(microbatch=32),
                        train=ds.train)
    store = TiledFactorStore.from_state(state, idx, seen)
    teng = TiledServingEngine(store, ServingConfig(microbatch=32))
    uids = np.concatenate([np.arange(ds.n_users), [-1, ds.n_users + 7]])
    v1, i1, f1 = eng.recommend(uids, return_flags=True)
    v2, i2, f2 = teng.recommend(uids, return_flags=True)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(np.asarray(i1), i2)
    np.testing.assert_array_equal(np.asarray(v1), v2)


def test_tiled_store_quantized_modes_bounded():
    ds, nbr, cfg, state = _world()
    idx = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    store = TiledFactorStore.from_state(state, idx, seen)
    store.quantize_int8()
    store.quantize_bf16()
    nb = store.nbytes()
    assert nb["slab_int8"] < nb["slab_fp32"] / 3
    assert nb["slab_bf16"] == nb["slab_fp32"] // 2
    users = np.arange(ds.n_users)
    fp = TiledServingEngine(store, ServingConfig(microbatch=32))
    vf, iff, fl = fp.recommend(users, return_flags=True)
    cand = idx.bucket_items[idx.user_bucket[users]]
    for mode, bound in [("int8", store.int8_score_bound(users)),
                        ("bf16", store.bf16_score_bound(users))]:
        qe = TiledServingEngine(store, ServingConfig(microbatch=32), mode=mode)
        vq, iq, flq = qe.recommend(users, return_flags=True)
        np.testing.assert_array_equal(fl, flq)
        for r in np.flatnonzero(~fl):
            sc = store.slab[r] @ store.U[r]       # fp32 scores of the window
            for slot in range(qe.cfg.k):
                j = iq[r, slot]
                if j < 0:
                    continue
                pos = np.flatnonzero(cand[r] == j)
                assert len(pos) == 1
                assert abs(float(vq[r, slot]) - float(sc[pos[0]])) \
                    <= bound[r] + 1e-6, (mode, r, slot)


def test_tiled_store_shard_rows_parity():
    ds, nbr, cfg, state = _world()
    idx = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    store = TiledFactorStore.from_state(state, idx, seen)
    full = TiledServingEngine(store, ServingConfig(microbatch=16))
    vf, iff = full.recommend(np.arange(ds.n_users))
    for s, sub in store.shard_rows(3):
        assert sub.slab.base is store.slab        # views, not copies
        se = TiledServingEngine(sub, ServingConfig(microbatch=16))
        vs, is_ = se.recommend(np.arange(sub.n_users))
        np.testing.assert_array_equal(vs, vf[s: s + sub.n_users])
        np.testing.assert_array_equal(is_, iff[s: s + sub.n_users])


def test_synthetic_store_windows_match_dense_generator():
    uc, ic, ucoord, icoord = synthetic_world(1500, 400, 5, seed=7)
    hier = build_hierarchical_index(ic, uc, icoord, ucoord, cell_cap=64)
    sf = SyntheticFactors.create(1500, 400, 8, seed=8)
    store = TiledFactorStore.synthetic(sf, hier.flat, seen_per_user=3, seed=9)
    samp = np.arange(0, 1500, 97)
    dense = sf.dense_rows(samp)               # (n, J, K) oracle item views
    cand = hier.flat.bucket_items[hier.flat.user_bucket[samp]]
    for r, u in enumerate(samp):
        m = cand[r] >= 0
        np.testing.assert_array_equal(dense[r][cand[r][m]], store.slab[u][m])
    assert int(store.item_counts.sum()) == int(store.seen.sum())


# ------------------------------------------------------- streaming evaluate
def test_evaluate_chunked_exactly_matches_unchunked():
    ds, nbr, cfg, state = _world()
    base = dmf.evaluate(state, ds.train, ds.test, ds.n_users, ds.n_items)
    for chunk in (7, 32, 1000):
        got = dmf.evaluate(state, ds.train, ds.test, ds.n_users, ds.n_items,
                           chunk_users=chunk)
        assert got == base, (chunk, got, base)


@pytest.mark.sharded
def test_evaluate_sharded_chunked_exactly_matches():
    ds, nbr, cfg, state = _world()
    base = dmf.evaluate(state, ds.train, ds.test, ds.n_users, ds.n_items)
    sh = dmf.evaluate(state, ds.train, ds.test, ds.n_users, ds.n_items,
                      n_shards=4)
    assert sh == base
    for chunk in (5, 16):
        got = dmf.evaluate(state, ds.train, ds.test, ds.n_users, ds.n_items,
                           n_shards=4, chunk_users=chunk)
        assert got == base, (chunk, got, base)


# --------------------------------------------------------- million-user smoke
@pytest.mark.slow
def test_million_user_store_bounded_memory():
    """1M users × 100k POIs, K=4: build the synthetic world + hierarchical
    index + tiled store and serve a batch, asserting peak RSS stays far
    below what any dense per-user item view would need (the fp32 slab at
    cell_cap=128 is ~2 GB; a single dense (I, J) score matrix alone would
    be 400 GB). Runs in a subprocess so the RSS measurement is isolated."""
    from conftest import run_in_subprocess_with_devices
    out = run_in_subprocess_with_devices("""
import resource
import numpy as np
from repro.serving import (ServingConfig, SyntheticFactors, TiledFactorStore,
                           TiledServingEngine, build_hierarchical_index,
                           synthetic_world)

I, J, K = 1_000_000, 100_000, 4
uc, ic, ucoord, icoord = synthetic_world(I, J, n_cities=1024, seed=0)
hier = build_hierarchical_index(ic, uc, icoord, ucoord, cell_cap=128)
sf = SyntheticFactors.create(I, J, K, seed=1)
store = TiledFactorStore.synthetic(sf, hier.flat, seen_per_user=2, seed=2)
eng = TiledServingEngine(store, ServingConfig(microbatch=128, k=10))
rng = np.random.default_rng(3)
vals, idx, flags = eng.recommend(rng.integers(0, I, 512), return_flags=True)
assert vals.shape == (512, 10) and (idx[~flags] >= 0).any()
peak_gb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6
print('cap', store.cap, 'cells', hier.n_cells, 'peak_gb', round(peak_gb, 2))
assert peak_gb < 12.0, peak_gb
""", n_devices=1, timeout=1200)
    assert "peak_gb" in out
