"""End-to-end behaviour of the paper's system (deliverable c).

The full DMF story on one synthetic city-world: build the graph, train
decentralized, verify the paper's headline orderings, recommend with the
Pallas serving kernel, and round-trip a checkpoint.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, dmf, graph, metrics
from repro.data import synthetic_poi
from repro.kernels import ops


@pytest.fixture(scope="module")
def world():
    ds = synthetic_poi.foursquare_like(reduced=True)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    return ds, W, M


@pytest.fixture(scope="module")
def trained(world):
    ds, W, M = world
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    res = dmf.fit(cfg, ds.train, M, epochs=60)
    return cfg, res


def test_dmf_beats_centralized_mf(world, trained):
    ds, W, M = world
    cfg, res = trained
    ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
    mfc = baselines.MFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10)
    st, _ = baselines.fit_mf(mfc, ds.train, epochs=60)
    ev_mf = baselines.evaluate_mf(st, ds.train, ds.test, ds.n_users, ds.n_items)
    assert ev["R@10"] > ev_mf["R@10"], (ev, ev_mf)
    assert ev["P@5"] > ev_mf["P@5"], (ev, ev_mf)


def test_privacy_invariant_ratings_stay_local(world):
    """Without exchange (LDMF limit), changing user A's ratings can never
    move any other user's personal state — ratings stay on-device; the only
    cross-user pathway in full DMF is the gradient message through P."""
    ds, W, M = world
    rng = np.random.default_rng(0)
    train2 = ds.train.copy()
    victim = int(train2[0, 0])
    mask = train2[:, 0] == victim
    train2[mask, 1] = rng.integers(0, ds.n_items, mask.sum())
    lcfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=4,
                         mode="ldmf", gamma=0.01)
    r1 = dmf.fit(lcfg, ds.train, M, epochs=2)
    r2 = dmf.fit(lcfg, train2, M, epochs=2)
    other = (victim + 1) % ds.n_users
    np.testing.assert_array_equal(
        np.asarray(r1.state.Q[other]), np.asarray(r2.state.Q[other])
    )


def test_serving_kernel_matches_dense_eval(world, trained):
    from repro.kernels import ref
    ds, W, M = world
    cfg, res = trained
    train_mask = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    # pick a user with non-degenerate (touched) scores — zero-init leaves
    # out-of-neighborhood items exactly tied at 0, where top-k order is
    # arbitrary; the kernel still must match the jnp oracle on values.
    scores_all = np.asarray(dmf.scores(res.state.U, res.state.P, res.state.Q))
    uid = int(np.argmax((np.abs(scores_all) > 1e-6).sum(1)))
    U_row = res.state.U[uid][None]                       # (1, K)
    V_user = res.state.P[uid] + res.state.Q[uid]         # (J, K)
    mask_row = jnp.asarray(train_mask[uid][None])
    vals, idx = ops.recommend_topk(U_row, V_user, mask_row, 10)
    v_ref, i_ref = ref.topk_scores_ref(U_row, V_user, mask_row, 10)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               rtol=1e-5, atol=1e-6)
    # where values are distinct, indices must agree exactly
    distinct = np.abs(np.diff(np.asarray(v_ref)[0])) > 1e-6
    same = np.asarray(idx)[0] == np.asarray(i_ref)[0]
    assert all(s for s, d in zip(same[:-1], distinct) if d)


def test_checkpoint_roundtrip_dmf_state(trained, tmp_path):
    from repro.checkpoint import ckpt
    res = trained[1]
    tree = {"U": res.state.U, "P": res.state.P, "Q": res.state.Q}
    ckpt.save(tmp_path / "step_60", tree, step=60)
    back = ckpt.restore(tmp_path / "step_60",
                        {k: jnp.zeros_like(v) for k, v in tree.items()})
    np.testing.assert_array_equal(np.asarray(back["U"]), np.asarray(tree["U"]))
