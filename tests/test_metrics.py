"""P@k / R@k — hand example + hypothesis invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import metrics


def test_hand_example():
    # 2 users, 6 items
    scores = np.array([
        [0.9, 0.8, 0.7, 0.1, 0.0, -1.0],
        [0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
    ])
    train = np.zeros((2, 6), bool)
    train[0, 0] = True          # item 0 seen by user 0 -> excluded
    test = np.zeros((2, 6), bool)
    test[0, 1] = True           # hit at rank 1
    test[0, 3] = True           # hit at rank 3
    test[1, 0] = True           # user 1's test item ranked last -> miss@2
    p2, r2 = metrics.precision_recall_at_k(scores, train, test, 2)
    # user0 top2 (excl item0): [1,2] -> 1 hit -> P=0.5, R=1/2
    # user1 top2: [5,4] -> 0 hits
    assert np.isclose(p2, (0.5 + 0.0) / 2)
    assert np.isclose(r2, (0.5 + 0.0) / 2)


def test_users_without_test_items_excluded():
    scores = np.random.default_rng(0).random((3, 5))
    train = np.zeros((3, 5), bool)
    test = np.zeros((3, 5), bool)
    test[0, 1] = True
    p, r = metrics.precision_recall_at_k(scores, train, test, 5)
    assert r == 1.0  # only user 0 counts; all items recommended at k=5


@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 12), st.integers(5, 20), st.integers(1, 5),
    st.integers(0, 10_000),
)
def test_property_bounds_and_monotone_recall(I, J, k, seed):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=(I, J))
    train = rng.random((I, J)) < 0.2
    test = (rng.random((I, J)) < 0.2) & ~train
    k = min(k, J)
    p, r = metrics.precision_recall_at_k(scores, train, test, k)
    assert 0.0 <= p <= 1.0 and 0.0 <= r <= 1.0
    if k + 1 <= J:
        _, r2 = metrics.precision_recall_at_k(scores, train, test, k + 1)
        assert r2 >= r - 1e-9  # recall monotone in k


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(4, 15), st.integers(0, 1000))
def test_property_train_items_never_recommended(I, J, seed):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=(I, J)) + 100.0  # make seen items attractive
    train = rng.random((I, J)) < 0.3
    k = min(3, J - int(train.sum(1).max()))
    if k <= 0:
        return
    rec = np.asarray(metrics.topk_recommend(scores, train, k))
    for i in range(I):
        assert not train[i, rec[i]].any()
