"""Fault-tolerant decentralized training (src/repro/robustness/): churn
schedule determinism, no-churn bit-exactness with the PR 1-4 paths
(single-device and every shard count), the offline bit-freeze /
message-loss / late-join contracts, stale-gradient DelayRing delivery
semantics, sharded-churn equivalence, crash-resume bit-identity (DP on),
and the dropout+staleness degradation envelope (DESIGN.md §10)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.robustness import (ChurnConfig, ChurnPlan, DelayRing, no_churn,
                              recovery)

pytestmark = pytest.mark.robustness

EPOCHS = 5


def _world(n_users=80, n_items=50, n_ratings=600, seed=0):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=n_users, n_items=n_items, n_ratings=n_ratings, n_cities=4,
        seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    return ds, graph.walk_neighbor_table(W, gcfg)


def _cfg(ds, **kw):
    base = dict(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                batch_size=64, beta=0.1, gamma=0.01)
    base.update(kw)
    return dmf.DMFConfig(**base)


def _assert_states_equal(a, b, **tol):
    for name in ("U", "P", "Q"):
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        if tol:
            np.testing.assert_allclose(x, y, **tol, err_msg=name)
        else:
            np.testing.assert_array_equal(x, y, err_msg=name)


# ---------------------------------------------------------------------------
# Schedule compilation
# ---------------------------------------------------------------------------
def test_churn_compile_deterministic_and_seed_keyed():
    cc = ChurnConfig(dropout=0.2, session_alpha=1.5, late_frac=0.2,
                     delay_classes=(0, 1, 2), seed=7)
    a, b = cc.compile(64, 20), cc.compile(64, 20)
    np.testing.assert_array_equal(a.online, b.online)
    np.testing.assert_array_equal(a.delay, b.delay)
    np.testing.assert_array_equal(a.join_epoch, b.join_epoch)
    c = dataclasses.replace(cc, seed=8).compile(64, 20)
    assert (a.online != c.online).any()
    assert a.k_max == 2 and 0.0 < a.participation_rate < 1.0
    assert not a.is_trivial()
    # late joiners are offline (stateless) strictly before their join epoch
    late = np.flatnonzero(a.join_epoch > 0)
    assert late.size > 0
    for u in late:
        assert not a.online[: a.join_epoch[u], u].any()


def test_no_churn_plan_is_trivial():
    plan = no_churn(16, 4)
    assert plan.is_trivial()
    assert plan.participation_rate == 1.0 and plan.k_max == 0
    # trivial plan ⇒ no ring allocated at all
    assert DelayRing.create(plan.k_max, 128, 6) is None


def test_epoch_row_masks_semantics():
    online = np.ones((3, 6), bool)
    online[1, 2] = False
    delay = np.asarray([0, 1, 0, 2, 0, 0], np.int32)
    plan = ChurnPlan(online=online, delay=delay,
                     join_epoch=np.zeros(6, np.int32))
    ui = np.asarray([[0, 1, 2, 3]])
    on, sender_on, prop_now, due = plan.epoch_row_masks(1, ui)
    np.testing.assert_array_equal(on, online[1])
    np.testing.assert_array_equal(sender_on, [[True, True, False, True]])
    # stragglers (delay>0) never propagate now; offline rows never at all
    np.testing.assert_array_equal(prop_now, [[True, False, False, False]])
    # due = t + delay for online stragglers only, -1 otherwise
    np.testing.assert_array_equal(due, [[-1, 2, -1, 3]])


# ---------------------------------------------------------------------------
# No-churn ⇒ bit-exact with the fault-free paths (acceptance)
# ---------------------------------------------------------------------------
def test_no_churn_bitexact_single_device():
    ds, nbr = _world()
    plain = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, test=ds.test)
    churn = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, test=ds.test,
                    churn=ChurnConfig())
    assert churn.train_losses == plain.train_losses
    assert churn.test_losses == plain.test_losses
    _assert_states_equal(churn.state, plain.state)


def test_no_churn_bitexact_with_dp():
    """The trivial plan composes with the DP mechanism bit-exactly too:
    same rng protocol ⇒ same per-epoch noise seeds ⇒ same noise."""
    ds, nbr = _world()
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=3)
    plain = dmf.fit(cfg, ds.train, nbr, epochs=3)
    churn = dmf.fit(cfg, ds.train, nbr, epochs=3, churn=no_churn(ds.n_users, 3))
    assert churn.train_losses == plain.train_losses
    _assert_states_equal(churn.state, plain.state)
    assert churn.privacy["eps_max"] == plain.privacy["eps_max"]


@pytest.mark.sharded
def test_no_churn_bitexact_sharded():
    ds, nbr = _world()
    for n_shards in (1, 2, 4, 8):
        cfg = _cfg(ds, n_shards=n_shards)
        plain = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS)
        churn = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS,
                        churn=ChurnConfig())
        assert churn.train_losses == plain.train_losses, n_shards
        _assert_states_equal(churn.state, plain.state)


# ---------------------------------------------------------------------------
# Offline ⇒ bit-frozen; rejoin catches up through the protocol
# ---------------------------------------------------------------------------
def test_offline_learner_rows_bit_frozen():
    """Learners offline in an epoch neither release nor receive: their U, Q
    AND P rows come out bitwise identical, while online learners train."""
    ds, nbr = _world()
    cfg = _cfg(ds)
    online = np.ones((2, ds.n_users), bool)
    offline = np.asarray([3, 11, 40, 79])
    online[0, offline] = False
    plan = ChurnPlan(online=online, delay=np.zeros(ds.n_users, np.int32),
                     join_epoch=np.zeros(ds.n_users, np.int32))
    rng = np.random.default_rng(cfg.seed)
    state0 = dmf.init_state(cfg, rng)
    before = {k: np.asarray(getattr(state0, k)).copy() for k in ("U", "P", "Q")}
    state1, loss = dmf.train_epoch_churn(state0, nbr, ds.train, cfg, rng,
                                         0, plan, None)
    assert np.isfinite(loss)
    for name in ("U", "P", "Q"):
        after = np.asarray(getattr(state1, name))
        np.testing.assert_array_equal(after[offline], before[name][offline],
                                      err_msg=f"offline {name} rows moved")
    # the fleet minus the offline set still trained
    U1 = np.asarray(state1.U).copy()   # the next epoch donates state1's buffers
    assert (U1 != before["U"]).any()
    # rejoin: epoch 1 (everyone online) moves the previously-frozen rows
    state2, _ = dmf.train_epoch_churn(state1, nbr, ds.train, cfg, rng,
                                      1, plan, None)
    moved = np.asarray([
        (np.asarray(state2.U)[u] != U1[u]).any() for u in offline])
    assert moved.any(), "rejoined learners never caught back up"


def test_late_joiner_stateless_until_join_epoch():
    ds, nbr = _world()
    cfg = _cfg(ds)
    cc = ChurnConfig(late_frac=0.2, late_by=0.5, seed=5)
    plan = cc.compile(ds.n_users, EPOCHS)
    late = np.flatnonzero(plan.join_epoch > 0)
    assert late.size > 0
    rng = np.random.default_rng(cfg.seed)
    state = dmf.init_state(cfg, rng)
    init = {k: np.asarray(getattr(state, k)).copy() for k in ("U", "P", "Q")}
    for t in range(EPOCHS):
        for u in late[plan.join_epoch[late] > t]:
            # not joined yet ⇒ still exactly the init rows
            np.testing.assert_array_equal(np.asarray(state.U)[u], init["U"][u])
            np.testing.assert_array_equal(np.asarray(state.Q)[u], init["Q"][u])
            np.testing.assert_array_equal(np.asarray(state.P)[u], init["P"][u])
        state, _ = dmf.train_epoch_churn(state, nbr, ds.train, cfg, rng,
                                         t, plan, None)


# ---------------------------------------------------------------------------
# Stale exchange: DelayRing delivery semantics
# ---------------------------------------------------------------------------
def _straggler_world():
    """A world where ONLY user s rates: the epoch stream carries s's
    messages exclusively, so neighbor-row movement isolates the exchange."""
    ds, nbr = _world()
    wgt = np.asarray(nbr.wgt)
    idx = np.asarray(nbr.idx)
    # a sender with at least one real (positive-weight, non-self) receiver
    s = next(u for u in range(ds.n_users)
             if ((wgt[u] > 0) & (idx[u] != u)).any())
    receivers = np.unique(idx[s][(wgt[s] > 0) & (idx[s] != s)])
    train = ds.train[ds.train[:, 0] == s]
    if len(train) < 8:   # top up so the stream fills at least two batches
        items = np.random.default_rng(0).choice(ds.n_items, 8, replace=False)
        train = np.stack([np.full(8, s), items], 1).astype(ds.train.dtype)
    cfg = _cfg(ds, batch_size=16)
    return ds, nbr, cfg, s, receivers, train


def _run_epochs(cfg, nbr, train, plan, epochs):
    rng = np.random.default_rng(cfg.seed)
    state = dmf.init_state(cfg, rng)
    nb = (len(train) * (1 + cfg.neg_samples)) // cfg.batch_size
    ring = DelayRing.create(plan.k_max, nb * cfg.batch_size, cfg.dim)
    hist = [np.asarray(state.P).copy()]
    for t in range(epochs):
        state, _ = dmf.train_epoch_churn(state, nbr, train, cfg, rng, t,
                                         plan, ring)
        hist.append(np.asarray(state.P).copy())
    return hist


def test_straggler_messages_land_exactly_k_epochs_late():
    ds, nbr, cfg, s, receivers, train = _straggler_world()
    delay = np.zeros(ds.n_users, np.int32)
    delay[s] = 2
    plan = ChurnPlan(online=np.ones((4, ds.n_users), bool), delay=delay,
                     join_epoch=np.zeros(ds.n_users, np.int32))
    hist = _run_epochs(cfg, nbr, train, plan, 4)
    # epochs 0 and 1: s's neighbor scatters are in flight — receiver P rows
    # bitwise untouched (s's own rows DO move: local compute is never late)
    np.testing.assert_array_equal(hist[1][receivers], hist[0][receivers])
    np.testing.assert_array_equal(hist[2][receivers], hist[0][receivers])
    assert (hist[1][s] != hist[0][s]).any()
    # epoch 2 starts by delivering epoch 0's messages (due = 0 + 2)
    assert (hist[3][receivers] != hist[2][receivers]).any()


def test_message_to_offline_receiver_is_lost_not_queued():
    ds, nbr, cfg, s, receivers, train = _straggler_world()
    delay = np.zeros(ds.n_users, np.int32)
    delay[s] = 1
    online = np.ones((3, ds.n_users), bool)
    online[1, receivers] = False     # offline exactly when delivery is due
    online[1:, s] = False            # sender quiet after epoch 0: the only
    plan = ChurnPlan(online=online, delay=delay,  # in-flight message is t=0's
                     join_epoch=np.zeros(ds.n_users, np.int32))
    hist = _run_epochs(cfg, nbr, train, plan, 3)
    # due==1 never matches any later epoch: the message is gone for good,
    # not delivered late at t=2 when the receivers come back
    np.testing.assert_array_equal(hist[2][receivers], hist[0][receivers])
    np.testing.assert_array_equal(hist[3][receivers], hist[0][receivers])
    # control: same schedule with the receivers online delivers at t=1
    plan_on = ChurnPlan(online=np.ones((3, ds.n_users), bool), delay=delay,
                        join_epoch=np.zeros(ds.n_users, np.int32))
    hist_on = _run_epochs(cfg, nbr, train, plan_on, 2)
    assert (hist_on[2][receivers] != hist_on[1][receivers]).any()


def test_delay_ring_slot_reuse_is_collision_free():
    ring = DelayRing.create(2, 8, 4)
    assert ring.slots == 2
    gp = jnp.ones((8, 4))
    ui = np.arange(8, dtype=np.int32)
    for t in range(5):
        ring.write(t, gp * (t + 1), ui, ui, np.full(8, t + 2, np.int32))
    # slot t%2 holds the LATEST write for that parity; older dues are gone
    np.testing.assert_array_equal(ring.due[0], np.full(8, 4 + 2))  # t=4
    np.testing.assert_array_equal(ring.due[1], np.full(8, 3 + 2))  # t=3
    np.testing.assert_array_equal(np.asarray(ring.gp[0]), 5.0 * np.ones((8, 4)))


# ---------------------------------------------------------------------------
# Sharded churn == single-device churn (one SPMD dispatch per epoch)
# ---------------------------------------------------------------------------
@pytest.mark.sharded
def test_sharded_churn_matches_single_device():
    ds, nbr = _world()
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1, 2), late_frac=0.1,
                     seed=4)
    ref = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, churn=cc)
    for n_shards in (2, 4, 8):
        got = dmf.fit(_cfg(ds, n_shards=n_shards), ds.train, nbr,
                      epochs=EPOCHS, churn=cc)
        np.testing.assert_allclose(ref.train_losses, got.train_losses,
                                   atol=1e-7, err_msg=str(n_shards))
        _assert_states_equal(got.state, ref.state, rtol=0, atol=1e-5)


@pytest.mark.sharded
def test_sharded_churn_with_dp_matches_single_device():
    """Churn, staleness AND the DP mechanism compose shard-invariantly:
    counter-keyed noise + shard-invariant ring delivery."""
    ds, nbr = _world()
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1), seed=4)
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=3)
    ref = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS, churn=cc)
    got = dmf.fit(dataclasses.replace(cfg, n_shards=4), ds.train, nbr,
                  epochs=EPOCHS, churn=cc)
    np.testing.assert_allclose(ref.train_losses, got.train_losses, atol=1e-7)
    _assert_states_equal(got.state, ref.state, rtol=0, atol=1e-5)
    assert got.privacy["eps_max"] == pytest.approx(ref.privacy["eps_max"])


# ---------------------------------------------------------------------------
# Recovery: resume-after-crash is bit-identical (acceptance)
# ---------------------------------------------------------------------------
def test_resume_bit_identical_with_dp_and_churn(tmp_path):
    ds, nbr = _world()
    cfg = _cfg(ds, dp_sigma=0.7, dp_clip=1.0, dp_seed=2)
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1, 2), late_frac=0.1,
                     seed=9)
    full = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS, test=ds.test, churn=cc,
                   checkpoint_dir=tmp_path, checkpoint_every=2)
    # "crash" after epoch 2, resume from its snapshot — every field of the
    # run (factors, losses, ε ledger) must come out bit-identical
    resumed = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS, test=ds.test,
                      churn=cc, resume_from=tmp_path / "step_2")
    assert resumed.train_losses == full.train_losses
    assert resumed.test_losses == full.test_losses
    _assert_states_equal(resumed.state, full.state)
    assert resumed.privacy == full.privacy


def test_resume_from_root_picks_latest_step(tmp_path):
    ds, nbr = _world()
    cfg = _cfg(ds)
    full = dmf.fit(cfg, ds.train, nbr, epochs=4,
                   checkpoint_dir=tmp_path, checkpoint_every=1)
    assert recovery.resolve_step_dir(tmp_path).name == "step_4"
    resumed = dmf.fit(cfg, ds.train, nbr, epochs=4, resume_from=tmp_path)
    # latest snapshot is the finished run: nothing left to train
    assert resumed.train_losses == full.train_losses
    _assert_states_equal(resumed.state, full.state)


@pytest.mark.sharded
def test_resume_sharded_and_across_mesh_widths(tmp_path):
    """Snapshots are unpadded (global learner axis): a sharded run resumes
    bit-identically, and the SAME snapshot restores onto a different mesh
    width within the cross-shard tolerance."""
    ds, nbr = _world()
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1), seed=4)
    cfg2 = _cfg(ds, n_shards=2)
    full = dmf.fit(cfg2, ds.train, nbr, epochs=EPOCHS, churn=cc,
                   checkpoint_dir=tmp_path, checkpoint_every=2)
    resumed = dmf.fit(cfg2, ds.train, nbr, epochs=EPOCHS, churn=cc,
                      resume_from=tmp_path / "step_2")
    assert resumed.train_losses == full.train_losses
    _assert_states_equal(resumed.state, full.state)
    # mesh-width switch mid-run: 2-shard snapshot, 4-shard continuation
    wider = dmf.fit(_cfg(ds, n_shards=4), ds.train, nbr, epochs=EPOCHS,
                    churn=cc, resume_from=tmp_path / "step_2")
    np.testing.assert_allclose(wider.train_losses, full.train_losses,
                               atol=1e-6)
    _assert_states_equal(wider.state, full.state, rtol=0, atol=1e-5)


def test_resume_ring_mismatch_raises(tmp_path):
    ds, nbr = _world()
    cfg = _cfg(ds)
    dmf.fit(cfg, ds.train, nbr, epochs=2, churn=ChurnConfig(),  # k_max=0
            checkpoint_dir=tmp_path, checkpoint_every=2)
    with pytest.raises(ValueError, match="has_ring"):
        dmf.fit(cfg, ds.train, nbr, epochs=2,
                churn=ChurnConfig(delay_classes=(0, 1)),        # wants a ring
                resume_from=tmp_path / "step_2")


# ---------------------------------------------------------------------------
# Degradation envelope: bounded churn ⇒ bounded loss gap, still converging
# ---------------------------------------------------------------------------
def test_degradation_envelope_dropout_and_staleness():
    ds, nbr = _world()
    cfg = _cfg(ds)
    free = dmf.fit(cfg, ds.train, nbr, epochs=8)
    cc = ChurnConfig(dropout=0.3, delay_classes=(0, 1, 2), seed=1)
    hit = dmf.fit(cfg, ds.train, nbr, epochs=8, churn=cc)
    # still optimizing, loss finite every epoch
    assert all(np.isfinite(hit.train_losses))
    assert hit.train_losses[-1] < hit.train_losses[0]
    # pinned envelope: dropout ≤ 0.3 + staleness ≤ 2 costs a bounded final-
    # loss gap vs the fault-free run (per-realized-row losses: comparable)
    gap = abs(hit.train_losses[-1] - free.train_losses[-1])
    assert gap <= 0.5 * free.train_losses[-1], (
        hit.train_losses[-1], free.train_losses[-1])
