"""Minimal, honest stand-in for the ``hypothesis`` API surface this test
suite uses, for containers with no package index access.

``tests/requirements.txt`` pins the real dependency (pytest + hypothesis);
install it where you can — `tests/conftest.py` registers this module under
the ``hypothesis`` name ONLY when the real package is absent, so the
property-test modules execute (instead of `importorskip`-skipping wholesale)
even offline. This is not a property-testing engine: no shrinking, no
example database, no health checks — just deterministic example generation
over the strategy subset the suite uses (`integers`, `floats`, `booleans`,
`sampled_from`).

Example schedule per test: the all-minimum and all-maximum corner examples
first (bounds are where padding/alignment bugs live), then pseudo-random
draws from an rng seeded by the test name — stable across runs and
processes, so a failure reproduces. The failing example is printed in the
assertion message, hypothesis-style.
"""
from __future__ import annotations

import random as _random
import types


class _Strategy:
    def __init__(self, lo_fn, hi_fn, draw_fn):
        self._lo = lo_fn
        self._hi = hi_fn
        self._draw = draw_fn

    def lo(self):
        return self._lo()

    def hi(self):
        return self._hi()

    def draw(self, rng: _random.Random):
        return self._draw(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda: min_value, lambda: max_value,
                     lambda rng: rng.randint(min_value, max_value))


def _floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda: min_value, lambda: max_value,
                     lambda rng: rng.uniform(min_value, max_value))


def _booleans() -> _Strategy:
    return _Strategy(lambda: False, lambda: True,
                     lambda rng: bool(rng.getrandbits(1)))


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda: elements[0], lambda: elements[-1],
                     lambda rng: rng.choice(elements))


strategies = types.ModuleType(
    "hypothesis.strategies",
    "Offline-fallback strategies (subset; see module docstring).")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
st = strategies

_DEFAULT_MAX_EXAMPLES = 20


class _Assumption(Exception):
    """Raised by `assume(False)` — the example is discarded, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


def note(message) -> None:
    print(message)


class HealthCheck:
    """Attribute sink: settings(suppress_health_check=[...]) is accepted
    and ignored (there are no health checks here)."""
    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


def given(*strats, **kw_strats):
    assert not kw_strats, "fallback @given supports positional strategies only"

    def decorate(fn):
        def runner(*fixture_args, **fixture_kwargs):
            cfg = getattr(runner, "_fallback_settings", {})
            n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = _random.Random(f"{fn.__module__}.{fn.__qualname__}")
            examples = [tuple(s.lo() for s in strats),
                        tuple(s.hi() for s in strats)]
            while len(examples) < n:
                examples.append(tuple(s.draw(rng) for s in strats))
            for ex in examples[:n]:
                try:
                    fn(*fixture_args, *ex, **fixture_kwargs)
                except _Assumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): args={ex!r}"
                    ) from e

        # pytest introspects the signature for fixtures: expose a bare
        # callable (no __wrapped__ -> no phantom fixture params)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__qualname__ = fn.__qualname__
        if hasattr(fn, "pytestmark"):
            runner.pytestmark = fn.pytestmark
        runner.hypothesis = types.SimpleNamespace(inner_test=fn)
        return runner

    return decorate


def settings(**config):
    def decorate(fn):
        fn._fallback_settings = config
        return fn

    return decorate


__version__ = "0.0.0+offline-fallback"
