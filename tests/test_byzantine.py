"""Byzantine-robust gradient exchange (src/repro/robustness/byzantine.py):
attack-plan compilation determinism, sender-boundary corruption semantics,
receiver-side screening (finite check + calibrated norm cap), robust
trimmed-mean/median aggregation, the no-attack/no-defense bit-exactness
contract with the PR 1-8 paths (single-device and every shard count, DP
and churn on), DelayRing × attack delivery screening, the degradation
envelope, and the divergence sentinel (DESIGN.md §13)."""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.privacy import audit, screening_threshold
from repro.robustness import ChurnConfig
from repro.robustness.byzantine import (AttackConfig, DefenseConfig,
                                        AttackPlan, group_messages,
                                        no_attack, robust_combine, screen_ok)

pytestmark = pytest.mark.byzantine

EPOCHS = 5


def _world(n_users=80, n_items=50, n_ratings=600, seed=0):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=n_users, n_items=n_items, n_ratings=n_ratings, n_cities=4,
        seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    return ds, graph.walk_neighbor_table(W, gcfg)


def _cfg(ds, **kw):
    base = dict(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                batch_size=64, beta=0.1, gamma=0.01)
    base.update(kw)
    return dmf.DMFConfig(**base)


def _assert_states_equal(a, b, **tol):
    for name in ("U", "P", "Q"):
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        if tol:
            np.testing.assert_allclose(x, y, **tol, err_msg=name)
        else:
            np.testing.assert_array_equal(x, y, err_msg=name)


# ---------------------------------------------------------------------------
# Attack-plan compilation
# ---------------------------------------------------------------------------
@settings(max_examples=12)
@given(st.sampled_from(["nan", "inf", "norm_inflate", "sign_flip", "shill"]),
       st.floats(min_value=0.05, max_value=0.5),
       st.integers(min_value=0, max_value=4),
       st.integers(min_value=0, max_value=100))
def test_attack_plan_deterministic_and_seed_keyed(family, frac, start, seed):
    ac = AttackConfig(family=family, frac=frac, scale=3.0, target_item=2,
                      start_epoch=start, seed=seed)
    a, b = ac.compile(96, 8, 6), ac.compile(96, 8, 6)
    np.testing.assert_array_equal(a.malicious, b.malicious)
    np.testing.assert_array_equal(a.active, b.active)
    np.testing.assert_array_equal(a.dirs, b.dirs)
    assert a.n_malicious == max(1, int(round(frac * 96)))
    # sleeper agents: statically inactive before their start epoch
    assert not a.active[:start].any()
    if start < 8:
        assert a.active[start:, a.malicious].all()
    c = dataclasses.replace(ac, seed=seed + 1).compile(96, 8, 6)
    assert (a.malicious != c.malicious).any()


def test_attack_plan_trivial_and_collusion():
    assert no_attack(16, 4, 6).is_trivial()
    assert AttackConfig(family="none").compile(16, 4, 6).is_trivial()
    assert AttackConfig(family="nan", frac=0.0).compile(16, 4, 6).is_trivial()
    assert not AttackConfig(family="nan", frac=0.2).compile(16, 4, 6).is_trivial()
    # colluding shills share ONE direction; independent ones don't
    co = AttackConfig(family="shill", frac=0.5, scale=2.0, collude=True,
                      seed=1).compile(32, 2, 6)
    mal = np.where(co.malicious)[0]
    assert all((co.dirs[m] == co.dirs[mal[0]]).all() for m in mal)
    ind = dataclasses.replace(
        AttackConfig(family="shill", frac=0.5, scale=2.0, seed=1),
        collude=False).compile(32, 2, 6)
    imal = np.where(ind.malicious)[0]
    assert any((ind.dirs[m] != ind.dirs[imal[0]]).any() for m in imal[1:])
    # shill directions carry the attack magnitude
    np.testing.assert_allclose(np.linalg.norm(co.dirs[mal], axis=1), 2.0,
                               rtol=1e-5)


def test_epoch_row_attack_gating():
    plan = AttackConfig(family="norm_inflate", frac=0.5, scale=7.0,
                        seed=0).compile(16, 3, 4)
    mal = np.where(plan.malicious)[0]
    hon = np.where(~plan.malicious)[0]
    ui = np.concatenate([mal[:2], hon[:2], [999]]).astype(np.int64)
    vj = np.arange(5, dtype=np.int32)
    amul, ashill, vjm = plan.epoch_row_attack(0, ui, vj)
    np.testing.assert_array_equal(amul, [7.0, 7.0, 1.0, 1.0, 1.0])
    assert not ashill.any()
    np.testing.assert_array_equal(vjm, vj)   # non-shill never re-addresses
    # offline senders can't attack (their messages are lost anyway, but the
    # realized mask must not mark them malicious-active)
    g = np.array([0.0, 1.0, 1.0, 1.0, 1.0], np.float32)
    amul2, _, _ = plan.epoch_row_attack(0, ui, vj, sender_on=g)
    np.testing.assert_array_equal(amul2, [1.0, 7.0, 1.0, 1.0, 1.0])


# ---------------------------------------------------------------------------
# Screening + robust combine primitives
# ---------------------------------------------------------------------------
def test_screen_ok_semantics():
    g = jnp.array([[1.0, 2.0, 2.0], [np.nan, 0.0, 0.0],
                   [np.inf, 1.0, 1.0], [30.0, 0.0, 0.0], [0.0, 0.0, 0.0]])
    np.testing.assert_array_equal(screen_ok(g, 10.0), [1, 0, 0, 0, 1])
    # infinite cap = finite check only
    np.testing.assert_array_equal(screen_ok(g, math.inf), [1, 0, 0, 1, 1])
    # boundary: exactly tau passes
    np.testing.assert_array_equal(screen_ok(g, 3.0), [1, 0, 0, 0, 1])


def test_robust_combine_trim_and_median_math():
    vals = jnp.array([[1.0], [2.0], [100.0], [3.0], [5.0], [77.0]])
    validity = jnp.array([1.0, 1.0, 1.0, 1.0, 1.0, 0.0])
    bucket = jnp.array([0, 0, 0, 0, 1, 2], jnp.int32)   # 2 = overflow
    pos = jnp.array([0, 1, 2, 3, 0, 0], jnp.int32)
    trim = DefenseConfig(aggregation="trim", trim_frac=0.25)
    got = robust_combine(vals, validity, bucket, pos, 2, 4, trim)
    # bucket 0: sorted [1,2,3,100], k=1 -> mean(2,3)*4 = 10; bucket 1: 5
    np.testing.assert_allclose(np.asarray(got), [[10.0], [5.0]])
    med = DefenseConfig(aggregation="median")
    got = robust_combine(vals, validity, bucket, pos, 2, 4, med)
    np.testing.assert_allclose(np.asarray(got), [[10.0], [5.0]])
    # no outlier pressure: trim equals plain summation
    clean = jnp.array([[1.0], [2.0], [2.5], [3.0], [5.0], [0.0]])
    got = robust_combine(clean, validity, bucket, pos, 2, 4,
                         DefenseConfig(aggregation="trim", trim_frac=0.0))
    np.testing.assert_allclose(np.asarray(got), [[8.5], [5.0]], rtol=1e-6)
    # empty bucket combines to exactly zero (no inf sentinel leakage)
    none = robust_combine(vals, jnp.zeros(6), bucket, pos, 2, 4, med)
    np.testing.assert_array_equal(np.asarray(none), np.zeros((2, 1)))


@settings(max_examples=10)
@given(st.integers(min_value=2, max_value=40),
       st.integers(min_value=2, max_value=6),
       st.integers(min_value=0, max_value=1000))
def test_group_messages_invariants(B, S, seed):
    rng = np.random.default_rng(seed)
    nb, I, J = 2, 12, 7
    ui = rng.integers(0, I, (nb, B)).astype(np.int64)
    vj = rng.integers(0, J, (nb, B)).astype(np.int32)
    idx = rng.integers(0, I, (I, S)).astype(np.int32)
    wgt = (rng.random((I, S)) * (rng.random((I, S)) > 0.2)).astype(np.float32)
    mg = group_messages(ui, vj, idx, wgt, J)
    assert mg.bucket_id.shape == (nb, B, S) and mg.pos.shape == (nb, B, S)
    for b in range(nb):
        fb = mg.bucket_id[b].reshape(-1)
        fp = mg.pos[b].reshape(-1)
        fr = idx[ui[b]].reshape(-1)
        fi = np.broadcast_to(vj[b][:, None], (B, S)).reshape(-1)
        v = fb < mg.n_buckets
        pairs = list(zip(fb[v].tolist(), fp[v].tolist()))
        assert len(pairs) == len(set(pairs)), "bucket position collision"
        assert (fp < mg.cap).all()
        for slot in np.flatnonzero(v):
            assert mg.recv[b, fb[slot]] == fr[slot]
            assert mg.item[b, fb[slot]] == fi[slot]
        # self slots and zero-weight slots land in the overflow bucket
        w = wgt[ui[b]].reshape(-1)
        dead = (w <= 0) | (fr == np.repeat(ui[b], S))
        assert (fb[dead] == mg.n_buckets).all()


# ---------------------------------------------------------------------------
# Bit-exactness: no attack + defenses off IS the PR 1-8 program
# ---------------------------------------------------------------------------
def test_byz_off_bitexact_single_device():
    ds, nbr = _world()
    plain = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, test=ds.test)
    off = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, test=ds.test,
                  attack=None, defense=None)
    assert off.train_losses == plain.train_losses
    _assert_states_equal(off.state, plain.state)
    # a compiled-trivial attack (frac=0) is statically removed too
    triv = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, test=ds.test,
                   attack=AttackConfig(family="none"))
    _assert_states_equal(triv.state, plain.state)


def test_byz_off_bitexact_with_dp_and_churn():
    ds, nbr = _world()
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=3)
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1), seed=4)
    plain = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS, churn=cc)
    off = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS, churn=cc,
                  attack=None, defense=None)
    assert off.train_losses == plain.train_losses
    _assert_states_equal(off.state, plain.state)


@pytest.mark.sharded
def test_byz_off_bitexact_sharded_with_dp():
    ds, nbr = _world()
    for n_shards in (1, 2, 4, 8):
        cfg = _cfg(ds, n_shards=n_shards, dp_sigma=0.5, dp_clip=1.0,
                   dp_seed=3)
        plain = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS)
        off = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS,
                      attack=None, defense=None)
        assert off.train_losses == plain.train_losses, n_shards
        _assert_states_equal(off.state, plain.state)


# ---------------------------------------------------------------------------
# Screening and robust aggregation under live attacks
# ---------------------------------------------------------------------------
def test_nan_bomb_screened_out():
    ds, nbr = _world()
    atk = AttackConfig(family="nan", frac=0.2, seed=5)
    und = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, attack=atk,
                  on_nonfinite="halt")
    assert und.diverged_at is not None          # the bomb really lands
    dfd = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, attack=atk,
                  defense=DefenseConfig(screen=True))
    assert np.isfinite(dfd.train_losses).all()
    for n in ("U", "P", "Q"):
        assert np.isfinite(np.asarray(getattr(dfd.state, n))).all(), n


def test_degradation_envelope_norm_inflation():
    """The acceptance contract: 20% malicious with lambda=100 collapses the
    undefended run (>=5x fault-free loss or non-finite) while screening +
    trimmed-mean holds the defended run within 1.5x."""
    ds, nbr = _world()
    anchor = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS)
    base = anchor.train_losses[-1]
    atk = AttackConfig(family="norm_inflate", frac=0.2, scale=100.0, seed=5)
    und = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, attack=atk,
                  on_nonfinite="halt")
    last = und.train_losses[-1]
    assert (not np.isfinite(last)) or und.diverged_at is not None \
        or last >= 5.0 * base
    dfd = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, attack=atk,
                  defense=DefenseConfig(screen=True, norm_cap=1.0,
                                        aggregation="trim", trim_frac=0.25))
    assert dfd.diverged_at is None
    assert dfd.train_losses[-1] <= 1.5 * base


def test_robust_aggregation_alone_tracks_plain():
    """Trim/median with NO attackers is a benign re-aggregation: same
    fixed point, final loss within a tight envelope of plain summation."""
    ds, nbr = _world()
    plain = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS)
    for agg in ("trim", "median"):
        got = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS,
                      defense=DefenseConfig(aggregation=agg, trim_frac=0.25))
        assert got.train_losses[-1] == pytest.approx(
            plain.train_losses[-1], rel=0.02), agg


def test_delayring_stale_malicious_message_screened_at_delivery():
    """A straggler's corrupted message buffered k epochs in the DelayRing
    must STILL be screened when it lands — the defense sits at delivery,
    not only on the fresh path."""
    ds, nbr = _world()
    cc = ChurnConfig(dropout=0.0, delay_classes=(0, 1, 2), seed=4)
    atk = AttackConfig(family="nan", frac=0.3, seed=5)
    und = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, churn=cc,
                  attack=atk, on_nonfinite="halt")
    assert und.diverged_at is not None
    dfd = dmf.fit(_cfg(ds), ds.train, nbr, epochs=EPOCHS, churn=cc,
                  attack=atk, defense=DefenseConfig(screen=True))
    assert np.isfinite(dfd.train_losses).all()
    for n in ("U", "P", "Q"):
        assert np.isfinite(np.asarray(getattr(dfd.state, n))).all(), n


@pytest.mark.sharded
def test_attack_defense_shard_invariant():
    """Screening + robust aggregation compose with DP, churn and the ring
    shard-invariantly: every mesh width reproduces the single-device run
    within the cross-shard tolerance the repo pins elsewhere."""
    ds, nbr = _world()
    atk = AttackConfig(family="sign_flip", frac=0.2, seed=5)
    dfn = DefenseConfig(screen=True, norm_cap=2.0, aggregation="median")
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1), seed=4)
    cfg = _cfg(ds, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
    ref = dmf.fit(cfg, ds.train, nbr, epochs=EPOCHS, churn=cc, attack=atk,
                  defense=dfn)
    for n_shards in (2, 4, 8):
        got = dmf.fit(dataclasses.replace(cfg, n_shards=n_shards), ds.train,
                      nbr, epochs=EPOCHS, churn=cc, attack=atk, defense=dfn)
        np.testing.assert_allclose(ref.train_losses, got.train_losses,
                                   atol=1e-6, err_msg=str(n_shards))
        _assert_states_equal(got.state, ref.state, rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# Screening threshold calibration (privacy interplay)
# ---------------------------------------------------------------------------
def test_screening_threshold_calibration():
    cfg = _cfg(_world()[0], dp_sigma=0.5, dp_clip=1.0)
    tau = screening_threshold(cfg, 16, reject_prob=1e-6)
    assert tau > cfg.dp_clip
    # degenerate regimes: sigma=0 -> exactly C; no DP -> no cap
    assert screening_threshold(
        dataclasses.replace(cfg, dp_sigma=0.0), 16) == cfg.dp_clip
    assert screening_threshold(
        dataclasses.replace(cfg, dp_sigma=0.0, dp_clip=math.inf),
        16) == math.inf
    # empirically: honest clipped+noised messages pass at far better than
    # the calibrated bound (Laurent-Massart is conservative)
    rng = np.random.default_rng(0)
    g = np.full((50_000, 16), 0.25)          # at the clip boundary
    z = rng.normal(0.0, 0.5, (50_000, 16))
    assert ((np.linalg.norm(g + z, axis=1) > tau).mean()) <= 1e-4


def test_screening_report_on_honest_stream():
    ds, nbr = _world()
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=3)
    log = audit.observe_messages(cfg, ds.train, nbr, epochs=2, seed=0)
    tau = screening_threshold(cfg, cfg.dim, reject_prob=1e-6)
    rep = audit.screening_report(log, tau, reject_prob=1e-6)
    assert rep["pass_rate"] == 1.0 and rep["reject_rate"] == 0.0
    assert rep["norm_max"] <= tau
    # accept bit over an all-pass honest stream carries no rating signal
    assert rep["accept_bit_rating_advantage"] == 0.0
    assert rep["calibrated_reject_prob"] == 1e-6


# ---------------------------------------------------------------------------
# Divergence sentinel
# ---------------------------------------------------------------------------
def test_divergence_sentinel_on_noise_blowup():
    """Regression: an absurd DP noise scale (sigma*C >> 1) used to poison
    the factors silently — the sentinel now warns/halts/raises."""
    ds, nbr = _world()
    cfg = _cfg(ds, lr=5.0, dp_sigma=40.0, dp_clip=25.0, dp_seed=3)
    halted = dmf.fit(cfg, ds.train, nbr, epochs=12, on_nonfinite="halt")
    assert halted.diverged_at is not None
    for n in ("U", "P", "Q"):
        assert np.isfinite(np.asarray(getattr(halted.state, n))).all(), n
    # halt keeps the offending loss in the trace for post-mortems
    assert len(halted.train_losses) == halted.diverged_at + 1
    with pytest.raises(dmf.DivergenceError):
        dmf.fit(cfg, ds.train, nbr, epochs=12, on_nonfinite="raise")
    with pytest.warns(RuntimeWarning, match="non-finite"):
        dmf.fit(cfg, ds.train, nbr, epochs=12, on_nonfinite="warn")
    with pytest.raises(AssertionError):
        dmf.fit(cfg, ds.train, nbr, epochs=2, on_nonfinite="explode")


def test_sentinel_quiet_on_healthy_run():
    ds, nbr = _world()
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        res = dmf.fit(_cfg(ds), ds.train, nbr, epochs=3, on_nonfinite="halt")
    assert res.diverged_at is None
    assert len(res.train_losses) == 3
