"""Unit tests for the dry-run's HLO collective-bytes parser — the roofline's
collective term depends on it, so pin its semantics."""
import importlib
import sys


def _collective_bytes():
    # import the parser without triggering dryrun's XLA_FLAGS side effect in
    # this process: the env line only matters before first jax init, and jax
    # is already initialized here with 1 device — but be safe and restore.
    import os
    old = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch.dryrun import collective_bytes
        return collective_bytes
    finally:
        if old is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = old


HLO = """
HloModule test
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[16,2048]{1,0} all-gather(%p0), dim=1
  %ar = bf16[1024]{0} all-reduce(%x), to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = f32[256]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s32[4,4]{1,0} all-to-all(%w), dimensions={0}
  %ags = (f32[128]{0}, f32[128]{0}) all-gather-start(%q), dim=0
  %agd = f32[128]{0} all-gather-done(%ags)
  %not_a_collective = f32[999]{0} add(%p0, %p0)
}
"""


def test_counts_each_collective_once():
    cb = _collective_bytes()
    out = cb(HLO)
    assert out["all-reduce"] == 1024 * 2          # bf16
    assert out["reduce-scatter"] == 8 * 64 * 4
    assert out["collective-permute"] == 256 * 4
    assert out["all-to-all"] == 16 * 4
    # all-gather: the plain op (16*2048*4) + the -start tuple (2*128*4);
    # -done must NOT double count
    assert out["all-gather"] == 16 * 2048 * 4 + 2 * 128 * 4


def test_ignores_non_collectives():
    cb = _collective_bytes()
    out = cb("%x = f32[10]{0} add(%a, %b)\n%y = f32[5]{0} multiply(%a, %b)")
    assert out == {}
