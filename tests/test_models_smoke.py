"""Deliverable f: per-architecture smoke tests — a REDUCED same-family
variant (<=2 periods, d_model<=512, <=4 experts) runs one forward/train
step on CPU; asserts output shapes and no NaNs. Plus decode-consistency:
prefix decode reproduces the full forward's last-token logits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import config as mc
from repro.models import transformer
from repro.optim import adamw, apply_updates


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=shape), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_image_tokens:
        batch["media"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.n_image_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = mc.reduced(registry.get_config(arch))
    assert cfg.n_layers <= 2 * len(cfg.period) and cfg.d_model <= 512
    if cfg.n_routed_experts:
        assert cfg.n_routed_experts <= 4
    params, specs = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    # forward
    h, aux, _ = transformer.forward(params, batch["tokens"], cfg,
                                    media=batch.get("media"))
    B, S = batch["tokens"].shape[:2]
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    # one real train step (loss + grad + adamw update)
    opt = adamw(1e-3)
    loss, grads = jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    upd, _ = opt.update(grads, opt.init(params), params)
    params2 = apply_updates(params, upd)
    loss2 = transformer.loss_fn(params2, batch, cfg)
    assert np.isfinite(float(loss2))
    # logical-spec tree structurally aligns with the param tree
    is_spec = lambda s: isinstance(s, tuple) and all(
        x is None or isinstance(x, str) for x in s
    )
    n_specs = len(jax.tree_util.tree_leaves(specs, is_leaf=is_spec))
    assert n_specs == len(jax.tree_util.tree_leaves(params))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = mc.reduced(registry.get_config(arch))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    cache = transformer.init_cache(cfg, B, S)
    tok = jnp.zeros((B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1), jnp.int32)
    logits, cache2 = transformer.decode_step(params, cache, tok,
                                             jnp.asarray(3, jnp.int32), cfg)
    vshape = (B, 1, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks else (
        B, 1, cfg.vocab_size)
    assert logits.shape == vshape
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "minicpm3-4b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b", "musicgen-medium"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode over a prefix reproduces the full forward's
    logits at the last position (cache correctness across GQA/MLA/SSM/MoE)."""
    cfg = mc.reduced(registry.get_config(arch))
    cfg = dataclasses.replace(cfg, remat=False)
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 1, 12
    rng = np.random.default_rng(0)
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=shape), jnp.int32)
    # full forward logits
    h, _, _ = transformer.forward(params, tokens, cfg)
    head = params["lm_head"]
    if cfg.n_codebooks:
        full_logits = jnp.einsum("bd,qdv->bqv", h[:, -1], head.astype(h.dtype))
    else:
        full_logits = jnp.einsum("bd,dv->bv", h[:, -1], head.astype(h.dtype))
    # token-by-token decode
    cache = transformer.init_cache(cfg, B, S)
    for t in range(S):
        tok = tokens[:, t : t + 1]
        logits, cache = transformer.decode_step(
            params, cache, tok, jnp.asarray(t, jnp.int32), cfg
        )
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits), rtol=5e-2, atol=5e-3
    )


def test_vlm_cross_cache_decode():
    cfg = mc.reduced(registry.get_config("llama-3.2-vision-90b"))
    cfg = dataclasses.replace(cfg, remat=False)
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 10
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    media = jnp.asarray(rng.normal(0, 0.02, (B, cfg.n_image_tokens, cfg.d_model)),
                        jnp.float32)
    logits_full, cache = transformer.prefill(params, tokens, cfg, media=media)
    # decode one more token against the prefill-produced media cache
    cache_sized = transformer.init_cache(cfg, B, S + 4)
    # splice prefill caches (self-attn k/v at [:S]; media kv as-is)
    for pos_key, c in cache.items():
        for k, v in c.items():
            buf = cache_sized[pos_key][k]
            if k in ("mk", "mv", "conv", "state"):
                cache_sized[pos_key][k] = v.astype(buf.dtype)
            else:
                cache_sized[pos_key][k] = jax.lax.dynamic_update_slice(
                    buf, v.astype(buf.dtype), (0,) * buf.ndim
                )
    logits, _ = transformer.decode_step(
        params, cache_sized, tokens[:, -1:], jnp.asarray(S, jnp.int32), cfg
    )
    assert np.isfinite(np.asarray(logits)).all()
