"""Mamba2 SSD: chunked algorithm vs naive recurrence; decode step agrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import ssm


def naive(x, dt, A, Bm, Cm, s0=None):
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    s = np.zeros((B, H, P, N)) if s0 is None else s0.copy()
    ys = []
    for t in range(L):
        decay = np.exp(dt[:, t] * A)
        Bh = np.repeat(Bm[:, t], rep, 1)
        Ch = np.repeat(Cm[:, t], rep, 1)
        s = s * decay[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], Bh)
        ys.append(np.einsum("bhpn,bhn->bhp", s, Ch))
    return np.stack(ys, 1), s


def _rand(seed, B=2, L=64, H=4, P=8, G=2, N=16):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(B, L, H, P)).astype(np.float32)
    dt = (0.5 * np.abs(rng.normal(size=(B, L, H)))).astype(np.float32)
    A = (-np.abs(rng.normal(size=(H,)))).astype(np.float32)
    Bm = rng.normal(size=(B, L, G, N)).astype(np.float32)
    Cm = rng.normal(size=(B, L, G, N)).astype(np.float32)
    return x, dt, A, Bm, Cm


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([8, 16, 32, 64]), st.integers(0, 50))
def test_chunked_matches_naive(chunk, seed):
    x, dt, A, Bm, Cm = _rand(seed)
    y_ref, s_ref = naive(x, dt, A, Bm, Cm)
    y, s = ssm.ssd_chunked(*(jnp.asarray(a) for a in (x, dt, A, Bm, Cm)), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=2e-4, atol=2e-4)


def test_initial_state_carried():
    x, dt, A, Bm, Cm = _rand(7, L=32)
    s0 = np.random.default_rng(1).normal(size=(2, 4, 8, 16)).astype(np.float32)
    y_ref, s_ref = naive(x, dt, A, Bm, Cm, s0=s0)
    y, s = ssm.ssd_chunked(
        *(jnp.asarray(a) for a in (x, dt, A, Bm, Cm)), chunk=8,
        init_state=jnp.asarray(s0),
    )
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=2e-4, atol=2e-4)


def test_block_forward_then_decode_continues():
    """mamba_forward's cache lets mamba_decode continue exactly."""
    from repro.models.config import LayerSpec, ModelConfig
    cfg = ModelConfig(
        name="t", n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=32,
        d_ff=0, vocab_size=64, ssm_d_state=16, ssm_head_dim=32, ssm_n_groups=1,
        ssm_chunk=16, period=(LayerSpec(kind="mamba"),), compute_dtype="float32",
    )
    params, _ = ssm.init_mamba(jax.random.PRNGKey(0), cfg), None
    params = params[0]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.5, (1, 33, 64)), jnp.float32)
    # full pass over all 33 tokens (chunk=11 divides 33)
    import dataclasses
    cfg_full = dataclasses.replace(cfg, ssm_chunk=11)
    y_full, _ = ssm.mamba_forward(params, x, cfg_full, jnp.float32)
    # 32-token forward then 1 recurrent decode step
    y32, cache = ssm.mamba_forward(params, x[:, :32], cfg, jnp.float32)
    y33, cache2 = ssm.mamba_decode(params, x[:, 32:33], cache, cfg, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y_full[:, :32]), np.asarray(y32), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(y_full[:, 32]), np.asarray(y33[:, 0]), rtol=2e-3, atol=2e-3
    )
