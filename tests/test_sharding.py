"""Distribution tests (deliverable e support): lowering + compiling on a
multi-device host mesh for representative (arch-family × step-kind) pairs,
and sharding-rule unit behaviour. Heavy lowers run in a subprocess so this
process keeps seeing exactly one device."""
import jax
import numpy as np
import pytest

from tests.conftest import run_in_subprocess_with_devices


def test_rules_divisibility_fallback():
    """56 heads on a 4-wide model axis -> replicated, not an error."""
    from jax.sharding import PartitionSpec as P
    # use a host mesh in-process is not allowed (single device) -> build an
    # abstract mesh for spec resolution only. AbstractMesh wants
    # ((name, size), ...) pairs; newer jax also accepts (sizes, names).
    from jax.sharding import AbstractMesh
    try:
        mesh = AbstractMesh((("data", 2), ("model", 16)))
    except TypeError:
        mesh = AbstractMesh((2, 16), ("data", "model"))
    from repro.sharding import rules
    # yi-34b: 56 heads on a 16-wide model axis -> replicate (56 % 16 != 0)
    spec = rules.resolve_spec(("embed", "heads", None), (64, 56, 16), mesh)
    assert spec == P("data", None, None)
    spec2 = rules.resolve_spec(("embed", "heads", None), (64, 32, 16), mesh)
    assert spec2 == P("data", "model", None)
    spec3 = rules.resolve_spec(("vocab", "embed_nodiv"), (1000, 63), mesh)
    assert spec3 == P(None, None)  # 1000 % 16 != 0 -> fallback
    spec3b = rules.resolve_spec(("vocab", "embed_nodiv"), (1024, 63), mesh)
    assert spec3b == P("model", None)
    # direct mesh-axis pin (gossip learner axis)
    spec4 = rules.resolve_spec(("__mesh__data", "ff"), (2, 64), mesh)
    assert spec4 == P("data", "model")


def test_make_production_mesh_shapes():
    """Mesh constructors produce the contracted shapes (checked abstractly —
    this process has one real device, so only validate the spec)."""
    from repro.launch import mesh as mesh_lib
    import inspect
    src = inspect.getsource(mesh_lib.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src


@pytest.mark.slow
def test_lower_all_step_kinds_small_mesh():
    run_in_subprocess_with_devices("""
import jax
from repro.configs import registry
from repro.models import transformer, config as mc
from repro.launch import specs as specs_lib
from repro.launch.train import make_train_step, TrainState
from repro.launch.serve import make_decode_step, make_prefill_step, serve_param_shardings
from repro.launch.dryrun import _state_shardings
from repro.models.config import InputShape
from repro.optim import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"))
REDUCED = {
  "minitron-4b": dict(n_kv_heads=4, vocab_size=512),
  "deepseek-v2-236b": dict(vocab_size=512, n_routed_experts=8),
  "jamba-1.5-large-398b": dict(vocab_size=512, n_routed_experts=8, ssm_head_dim=64, n_kv_heads=4),
}
def sds(cfg, pshard):
    ps, _ = transformer.abstract_params(cfg)
    return jax.tree_util.tree_map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), ps, pshard)
for arch, over in REDUCED.items():
    cfg = mc.reduced(registry.get_config(arch), **over)
    for kind, S, B in [("train", 256, 8), ("decode", 512, 8)]:
        shape = InputShape(kind, S, B, "train" if kind == "train" else "decode")
        if kind == "train":
            step, _, pshard = make_train_step(cfg, mesh, adamw(3e-4))
            batch = specs_lib.batch_specs(cfg, shape, mesh)
            ps, _ = transformer.abstract_params(cfg)
            opt = jax.eval_shape(adamw(3e-4).init, ps)
            st = TrainState(ps, opt)
            st = jax.tree_util.tree_map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), st, _state_shardings(st, pshard))
            step.lower(st, batch).compile()
        else:
            pshard = serve_param_shardings(cfg, mesh)
            cache, cps, tokens, pos = specs_lib.decode_specs(cfg, shape, mesh)
            make_decode_step(cfg, mesh, cps).lower(sds(cfg, pshard), cache, tokens, pos).compile()
    print("OK", arch)
""", n_devices=8, timeout=1200)


@pytest.mark.slow
def test_train_step_executes_and_loss_drops_on_mesh():
    """Not just lowering: a real sharded training run on 8 host devices."""
    run_in_subprocess_with_devices("""
import jax, jax.numpy as jnp
from repro.configs import registry
from repro.data.lm_pipeline import LMDataConfig, SyntheticLM
from repro.launch.train import make_train_step
from repro.models import config as mc
from repro.optim import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = mc.reduced(registry.get_config("minitron-4b"), n_kv_heads=4, vocab_size=256,
                 d_model=128, d_ff=256, n_heads=4, head_dim=32)
step, init_fn, _ = make_train_step(cfg, mesh, adamw(3e-3))
state = init_fn(jax.random.PRNGKey(0))
data = SyntheticLM(LMDataConfig(vocab_size=256, seq_len=64, batch_size=8))
losses = []
for i in range(25):
    state, m = step(state, {k: jnp.asarray(v) for k, v in data.batch(i).items()})
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
print("OK", losses[0], "->", losses[-1])
""", n_devices=8, timeout=900)
