"""DMF core: gradients match autodiff of Eq. 6; Alg. 1 semantics; ablations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmf, graph
from repro.data import synthetic_poi


def test_gradients_match_autodiff_single_rating():
    """_batch_step's update for one rating == SGD on Eq. 6's per-sample loss
    (sanity for Eqs. 9-11), with no neighbors (M = I)."""
    I, J, K = 4, 5, 3
    cfg = dmf.DMFConfig(n_users=I, n_items=J, dim=K, alpha=0.3, beta=0.2,
                        gamma=0.1, lr=0.05, batch_size=1)
    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    P = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    Q = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    M = jnp.eye(I)
    i, j, r, c = 2, 3, 0.8, 1.0

    def loss(u_i, p_ij, q_ij):
        pred = jnp.dot(u_i, p_ij + q_ij)
        return (
            0.5 * c * (r - pred) ** 2
            + 0.5 * cfg.alpha * jnp.sum(u_i ** 2)
            + 0.5 * cfg.beta * jnp.sum(p_ij ** 2)
            + 0.5 * cfg.gamma * jnp.sum(q_ij ** 2)
        )

    gu, gp, gq = jax.grad(loss, argnums=(0, 1, 2))(U[i], P[i, j], Q[i, j])
    U2, P2, Q2, _ = dmf._batch_step(
        U.copy(), P.copy(), Q.copy(), M,
        jnp.array([i]), jnp.array([j]), jnp.array([r], jnp.float32),
        jnp.array([c], jnp.float32), cfg,
    )
    np.testing.assert_allclose(U2[i], U[i] - cfg.lr * gu, rtol=2e-5)
    np.testing.assert_allclose(P2[i, j], P[i, j] - cfg.lr * gp, rtol=2e-5)
    np.testing.assert_allclose(Q2[i, j], Q[i, j] - cfg.lr * gq, rtol=2e-5)
    # untouched entries unchanged
    np.testing.assert_allclose(P2[i, (j + 1) % J], P[i, (j + 1) % J])
    np.testing.assert_allclose(U2[(i + 1) % I], U[(i + 1) % I])


def test_neighbor_propagation_weights():
    """Alg. 1 line 15: neighbor i' receives -θ·M[i,i']·∂L/∂p^i_j."""
    I, J, K = 3, 2, 2
    cfg = dmf.DMFConfig(n_users=I, n_items=J, dim=K, alpha=0.0, beta=0.0,
                        gamma=0.0, lr=0.1, batch_size=1)
    rng = np.random.default_rng(1)
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    P = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    Q = jnp.zeros((I, J, K), jnp.float32)
    M = jnp.asarray([[1.0, 0.5, 0.0], [0.5, 1.0, 0.0], [0.0, 0.0, 1.0]])
    i, j, r = 0, 1, 1.0
    pred = float(jnp.dot(U[i], P[i, j]))
    gp = -(r - pred) * np.asarray(U[i])
    _, P2, _, _ = dmf._batch_step(
        U, P.copy(), Q, M, jnp.array([i]), jnp.array([j]),
        jnp.array([r], jnp.float32), jnp.array([1.0], jnp.float32), cfg,
    )
    np.testing.assert_allclose(np.asarray(P2[1, j]), np.asarray(P[1, j]) - 0.1 * 0.5 * gp, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(P2[2, j]), np.asarray(P[2, j]), rtol=1e-6)


def test_modes_freeze_partitions():
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=60, n_items=40, n_ratings=400, n_cities=3))
    gcfg = graph.GraphConfig()
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    for mode in ["gdmf", "ldmf"]:
        cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=4, mode=mode)
        res = dmf.fit(cfg, ds.train, M, epochs=2)
        if mode == "gdmf":
            assert float(jnp.abs(res.state.Q).max()) == 0.0
        else:
            assert float(jnp.abs(res.state.P).max()) == 0.0


def test_training_reduces_loss_and_beats_ldmf():
    ds = synthetic_poi.foursquare_like(reduced=True)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=8,
                        beta=0.1, gamma=0.01)
    res = dmf.fit(cfg, ds.train, M, epochs=25)
    assert res.train_losses[-1] < 0.5 * res.train_losses[0]
    ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
    lcfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=8,
                         mode="ldmf", gamma=0.01)
    lres = dmf.fit(lcfg, ds.train, M, epochs=25)
    lev = dmf.evaluate(lres.state, ds.train, ds.test, ds.n_users, ds.n_items)
    assert ev["R@10"] > lev["R@10"], (ev, lev)


def test_negative_sampling_confidence():
    cfg = dmf.DMFConfig(n_users=10, n_items=20, dim=4, neg_samples=3)
    rng = np.random.default_rng(0)
    train = np.stack([rng.integers(0, 10, 50), rng.integers(0, 20, 50)], 1)
    ui, vj, r, conf = dmf.sample_epoch(train, cfg, rng)
    assert len(ui) == 50 * 4
    assert set(np.unique(r)) == {0.0, 1.0}
    np.testing.assert_allclose(conf[r == 0], 1.0 / 3)
    np.testing.assert_allclose(conf[r == 1], 1.0)


def test_rating_privacy_no_rating_in_message():
    """The gradient message ∂L/∂p^i_j = -(e)·u_i + β p^i_j does not reveal
    r directly: identical for (r, pred) pairs with equal residual — the
    paper's privacy argument. Check two different ratings with matching
    residuals produce the same message."""
    K = 4
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(size=(K,)), jnp.float32)
    p = jnp.asarray(rng.normal(size=(K,)), jnp.float32)
    q1 = jnp.zeros((K,))
    # message depends on err = c(r - u·(p+q)); construct equal errs
    from repro.kernels import ref
    g1 = ref.dmf_grads_ref(u[None], p[None], q1[None],
                           jnp.array([1.0]), jnp.array([0.5]), 0.1, 0.2, 0.3)[1]
    # different r, different conf, same product err
    pred = float(jnp.dot(u, p))
    # err1 = 0.5*(1-pred); choose r2=0, c2 = err1/(0-pred)
    err1 = 0.5 * (1 - pred)
    c2 = err1 / (0.0 - pred)
    g2 = ref.dmf_grads_ref(u[None], p[None], q1[None],
                           jnp.array([0.0]), jnp.array([c2], dtype=jnp.float32),
                           0.1, 0.2, 0.3)[1]
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)
