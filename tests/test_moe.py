"""MoE: routing/capacity semantics + sharded-vs-local path equality."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import LayerSpec, ModelConfig
from tests.conftest import run_in_subprocess_with_devices


def _cfg(E=8, k=2, d=64, ff=32, shared=1):
    return ModelConfig(
        name="t", n_layers=2, d_model=d, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=0, vocab_size=64, n_routed_experts=E, n_shared_experts=shared,
        moe_top_k=k, moe_d_ff=ff, period=(LayerSpec(kind="attn", moe=True),),
        compute_dtype="float32",
    )


def test_local_moe_shapes_and_aux():
    cfg = _cfg()
    params, _ = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 16, 64)), jnp.float32)
    y, aux = moe.moe_ffn_local(params, x, cfg, jnp.float32)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # Switch aux loss is >= 1 (E * sum f_e p_e >= 1 by Cauchy-Schwarz at balance)
    assert float(aux) >= 0.99


def test_router_topk_normalized():
    cfg = _cfg(E=16, k=4)
    params, _ = moe.init_moe(jax.random.PRNGKey(1), cfg)
    x2d = jnp.asarray(np.random.default_rng(1).normal(size=(32, 64)), jnp.float32)
    w, idx, aux = moe._route(params, x2d, cfg)
    assert w.shape == (32, 4) and idx.shape == (32, 4)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(idx) < 16).all()


def test_capacity_drop_semantics():
    """With capacity 1 slot per expert, overflow routes are dropped (output
    contribution zero), never mis-assigned."""
    cfg = dataclasses.replace(_cfg(E=2, k=1, shared=0), capacity_factor=1e-9)
    params, _ = moe.init_moe(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(1, 8, 64)), jnp.float32)
    y, _ = moe.moe_ffn_local(params, x, cfg, jnp.float32)
    # capacity = max(1, ...) = 1 -> at most 2 tokens (1/expert) get output
    nonzero_tokens = int((np.abs(np.asarray(y)[0]).sum(-1) > 1e-9).sum())
    assert nonzero_tokens <= 2


def test_grouped_ffn_matches_dense_reference():
    """Capacity-sorted dispatch == dense per-expert compute when capacity
    is ample."""
    cfg = _cfg(E=4, k=2, shared=0)
    params, _ = moe.init_moe(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 12, 64)), jnp.float32)
    x2d = x.reshape(-1, 64)
    w, idx, _ = moe._route(params, x2d, cfg)
    y, _ = moe.moe_ffn_local(params, x, cfg, jnp.float32)
    # dense reference
    ref = np.zeros((12, 64), np.float32)
    for e in range(4):
        h = np.asarray(x2d) @ np.asarray(params["wi"][e])
        g = np.asarray(x2d) @ np.asarray(params["wg"][e])
        o = (g / (1 + np.exp(-g)) * h) @ np.asarray(params["wo"][e])
        we = np.where(np.asarray(idx) == e, np.asarray(w), 0.0).sum(-1)
        ref += we[:, None] * o
    np.testing.assert_allclose(np.asarray(y[0]), ref, rtol=2e-4, atol=2e-4)


def test_sharded_matches_local_on_mesh():
    run_in_subprocess_with_devices("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.models import moe
from repro.models.config import LayerSpec, ModelConfig
cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=0, vocab_size=64, n_routed_experts=8, n_shared_experts=1,
    moe_top_k=2, moe_d_ff=32, period=(LayerSpec(kind="attn", moe=True),),
    compute_dtype="float32")
mesh = jax.make_mesh((2, 4), ("data", "model"))
params, _ = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, 64)), jnp.float32)
y_loc, aux_loc = moe.moe_ffn_local(params, x, cfg, jnp.float32)
with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
    y_sh, aux_sh = jax.jit(lambda p, x: moe.moe_ffn_sharded(p, x, cfg, jnp.float32, mesh))(params, x)
# capacity differs (per-shard tokens) -> tiny drop differences possible;
# with ample capacity_factor the results match
np.testing.assert_allclose(np.asarray(y_loc), np.asarray(y_sh), rtol=2e-3, atol=2e-3)
print("OK")
""")
