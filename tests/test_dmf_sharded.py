"""Learner-sharded training/serving == the single-device path, and the
paper's privacy contract holds at the shard boundary.

Device-count invariance: for any shard count, the SPMD epoch (shard_map +
all_to_all gradient-message exchange, sharding/dmf.py) must reproduce the
single-device sparse scan — same loss trajectory, same factors — because
sharding only redistributes an order-free minibatch sum (DESIGN.md §8).

Privacy invariants (paper: "only gradients ever leave a learner"):
a learner's ratings, u_i and q^i rows influence no other shard except
through the global-factor gradient messages, and the outbox content is a
pure function of those gradients + static graph structure — independent of
the rating values that produced a given error.

Runs on 8 host-platform devices provisioned by tests/conftest.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.serving import ServingConfig, ServingEngine, index_from_dataset
from repro.sharding import dmf as sharded_dmf

pytestmark = pytest.mark.sharded

EPOCHS = 5


def _world(n_users=80, n_items=50, n_ratings=600, seed=0, walk_length=3):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=n_users, n_items=n_items, n_ratings=n_ratings, n_cities=4,
        seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=walk_length)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    return ds, nbr


def _cfg(ds, mode="dmf", **kw):
    return dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                         mode=mode, batch_size=64, beta=0.1, gamma=0.01, **kw)


_REF_CACHE: dict = {}


def _reference(ds, nbr, mode):
    """Single-device sparse-path fit, shared across the shard-count grid."""
    if mode not in _REF_CACHE:
        _REF_CACHE[mode] = dmf.fit(_cfg(ds, mode), ds.train, nbr,
                                   epochs=EPOCHS, test=ds.test)
    return _REF_CACHE[mode]


def test_partition_reconstructs_table():
    """Destination-split table sums back to the original: the sharded
    exchange ships exactly the single-device scatter mass."""
    ds, nbr = _world()
    for n_shards in (1, 3, 4, 8):
        part = graph.partition_neighbor_table(nbr, n_shards, ds.n_users)
        rows = part.rows_per_shard
        assert part.idx.shape == (rows * n_shards, n_shards, nbr.idx.shape[1])
        M_ref = graph.dense_from_neighbor_table(nbr, ds.n_users)
        M_got = np.zeros_like(M_ref)
        pidx, pwgt = np.asarray(part.idx), np.asarray(part.wgt)
        for d in range(n_shards):
            rcv = d * rows + pidx[: ds.n_users, d]      # back to global rows
            np.add.at(M_got, (np.repeat(np.arange(ds.n_users), rcv.shape[1]),
                              rcv.reshape(-1)),
                      pwgt[: ds.n_users, d].reshape(-1))
        np.testing.assert_array_equal(M_got, M_ref)
        # padded sender rows carry no mass
        assert not np.asarray(part.wgt)[ds.n_users:].any()


@pytest.mark.parametrize("mode", ["dmf", "gdmf", "ldmf"])
@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
def test_sharded_matches_single_device(mode, n_shards):
    """Loss trajectory ≤1e-5 over 5 epochs and matching final factors, for
    every mode × shard count (acceptance contract)."""
    ds, nbr = _world()
    ref = _reference(ds, nbr, mode)
    got = dmf.fit(_cfg(ds, mode, n_shards=n_shards), ds.train, nbr,
                  epochs=EPOCHS, test=ds.test)
    np.testing.assert_allclose(ref.train_losses, got.train_losses, atol=1e-5)
    np.testing.assert_allclose(ref.test_losses, got.test_losses, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.state.U), np.asarray(got.state.U),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.state.P), np.asarray(got.state.P),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.state.Q), np.asarray(got.state.Q),
                               atol=1e-5)
    assert np.asarray(got.state.U).shape == (ds.n_users, 6)  # unpadded out


def test_sharded_nondivisible_users_padding():
    """I=77 over 4 shards: the learner axis pads to 80, padded rows are
    inert, and the result still matches the single-device path."""
    ds, nbr = _world(n_users=77, n_items=40, n_ratings=500, seed=1)
    cfg = _cfg(ds)
    ref = dmf.fit(cfg, ds.train, nbr, epochs=3)
    got = dmf.fit(_cfg(ds, n_shards=4), ds.train, nbr, epochs=3)
    np.testing.assert_allclose(ref.train_losses, got.train_losses, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.state.P), np.asarray(got.state.P),
                               atol=1e-5)
    assert np.asarray(got.state.U).shape[0] == 77


def test_sharded_no_exchange_walk_zero():
    """D=0 (walk_length=0): the table is self-only, every message routes
    back to its own shard — still equivalent, still one SPMD dispatch."""
    ds, nbr = _world(walk_length=0)
    assert nbr.idx.shape[1] == 1          # self only
    ref = dmf.fit(_cfg(ds), ds.train, nbr, epochs=3)
    got = dmf.fit(_cfg(ds, n_shards=4), ds.train, nbr, epochs=3)
    np.testing.assert_allclose(ref.train_losses, got.train_losses, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.state.P), np.asarray(got.state.P),
                               atol=1e-5)


def test_sharded_evaluate_matches_single_device():
    ds, nbr = _world()
    res = _reference(ds, nbr, "dmf")
    ev1 = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
    ev8 = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items,
                       n_shards=8)
    assert ev1 == ev8


# ---------------------------------------------------------------------------
# Privacy invariants
# ---------------------------------------------------------------------------
def _one_sharded_epoch(state, plan, cfg, batches, dp_seed=0):
    ui, vj, r, conf, valid, rid = batches
    U, P, Q, _ = sharded_dmf._epoch_sharded(
        state.U, state.P, state.Q,
        plan.part.idx, plan.part.wgt,
        jnp.asarray(ui), jnp.asarray(vj), jnp.asarray(r), jnp.asarray(conf),
        jnp.asarray(valid), jnp.asarray(rid), jnp.asarray(dp_seed, jnp.int32),
        cfg, plan.mesh)
    return np.asarray(U), np.asarray(P), np.asarray(Q)


def test_privacy_rating_perturbation_stays_local():
    """Perturb ONE learner's rating values (same interaction structure) and
    run a single exchange round: across the whole mesh, U and Q may change
    only at that learner's own rows (they never leave its shard), and P only
    at its neighbor-table receivers — i.e. the only cross-shard influence of
    a rating is the global-factor gradient message, bit-identical everywhere
    else. (Over MULTIPLE rounds influence spreads further — through the
    updated global factor, which is the protocol working as designed — so
    the boundary invariant is per-round.)"""
    ds, nbr = _world()
    n_shards = 4
    cfg = _cfg(ds, n_shards=n_shards)
    plan = sharded_dmf.make_shard_plan(nbr, cfg)
    rng = np.random.default_rng(0)
    ui, vj, r, conf = dmf.sample_epoch(ds.train, cfg, rng)
    nb = 1                                           # ONE minibatch = one round
    n = nb * cfg.batch_size
    shape = (nb, cfg.batch_size)
    L = int(ui[0])                                   # the perturbed learner
    r2 = r.copy()
    r2[ui == L] = 0.37                               # different rating values

    def batches(rr):
        return sharded_dmf.shard_batches(
            ui[:n].reshape(shape), vj[:n].reshape(shape),
            rr[:n].reshape(shape), conf[:n].reshape(shape),
            n_shards, plan.rows)

    # jit donates U/P/Q: run each world on its own padded copy
    U1, P1, Q1 = _one_sharded_epoch(
        sharded_dmf.shard_state(dmf.init_state(cfg), plan), plan, cfg, batches(r))
    U2, P2, Q2 = _one_sharded_epoch(
        sharded_dmf.shard_state(dmf.init_state(cfg), plan), plan, cfg, batches(r2))

    receivers = np.asarray(nbr.idx)[L][np.asarray(nbr.wgt)[L] > 0]
    u_diff = np.nonzero((U1 != U2).any(axis=1))[0]
    q_diff = np.nonzero((Q1 != Q2).any(axis=(1, 2)))[0]
    p_diff = np.nonzero((P1 != P2).any(axis=(1, 2)))[0]
    assert set(u_diff) <= {L}, u_diff                # u_i never leaves learner
    assert set(q_diff) <= {L}, q_diff                # q^i never leaves learner
    assert set(p_diff) <= set(receivers), (p_diff, receivers)
    assert L in receivers                            # sender is its own receiver


def test_privacy_outbox_pure_function_of_gradient():
    """The cross-shard payload is built by `build_outbox(gp, tables, vj)` —
    no ratings, confidences, u or q in its signature — and equal errors
    produce a bit-identical outbox whatever rating values caused them
    (zero-init item factors make pred=0 exact, so err = conf·r exactly)."""
    ds, nbr = _world()
    cfg = _cfg(ds)
    part = graph.partition_neighbor_table(nbr, 4, ds.n_users)
    rng = np.random.default_rng(3)
    B, K = 32, cfg.dim
    u = jnp.asarray(rng.normal(size=(B, K)), jnp.float32)
    p = jnp.zeros((B, K), jnp.float32)
    q = jnp.zeros((B, K), jnp.float32)
    users = jnp.asarray(rng.integers(0, ds.n_users, B), jnp.int32)
    vj = jnp.asarray(rng.integers(0, ds.n_items, B), jnp.int32)
    # two different rating worlds with identical errors: err = conf * r
    r1, c1 = jnp.full((B,), 1.0), jnp.full((B,), 0.25)
    r2, c2 = jnp.full((B,), 0.25), jnp.full((B,), 1.0)
    _, gp1, _, _ = dmf._grads_and_loss(u, p, q, r1, c1, cfg)
    _, gp2, _, _ = dmf._grads_and_loss(u, p, q, r2, c2, cfg)
    np.testing.assert_array_equal(np.asarray(gp1), np.asarray(gp2))

    tbl_i, tbl_w = part.idx[users], part.wgt[users]
    box1 = sharded_dmf.build_outbox(gp1, tbl_i, tbl_w, vj)
    box2 = sharded_dmf.build_outbox(gp2, tbl_i, tbl_w, vj)
    for a, b in zip(box1, box2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # fixed-shape contract: (D, B, S) weights/rows, (D, B, K) grads, (D, B) items
    D, S = 4, nbr.idx.shape[1]
    assert [tuple(x.shape) for x in box1] == [
        (D, B, S), (D, B, S), (D, B, K), (D, B)]


# ---------------------------------------------------------------------------
# Sharded serving
# ---------------------------------------------------------------------------
@pytest.mark.serving
@pytest.mark.parametrize("prune", [True, False])
def test_sharded_engine_matches_single_shard(prune):
    """One SPMD serve dispatch per mesh-wide microbatch, bit-identical
    recommendations (values AND item ids) to the single-shard engine,
    results aligned to request order."""
    ds, nbr = _world(n_users=90, n_items=70, n_ratings=800)
    cfg = _cfg(ds)
    res = dmf.fit(cfg, ds.train, nbr, epochs=6)
    index = index_from_dataset(ds)
    users = np.random.default_rng(0).integers(0, ds.n_users, 150)
    e1 = ServingEngine(res.state, index,
                       ServingConfig(microbatch=16, k=5, prune=prune),
                       train=ds.train)
    v1, i1 = e1.recommend(users)
    e8 = ServingEngine(res.state, index,
                       ServingConfig(microbatch=16, k=5, prune=prune,
                                     n_shards=8),
                       train=ds.train)
    v8, i8 = e8.recommend(users)
    np.testing.assert_array_equal(i1, i8)
    np.testing.assert_allclose(v1, v8, rtol=1e-6, atol=1e-7)
    # 150 requests over 8 queues of cap 16 -> 2 SPMD dispatches, not 10
    assert e8.stats.n_dispatches < e1.stats.n_dispatches
    assert e8.stats.n_requests == len(users)


@pytest.mark.serving
def test_sharded_engine_ingest_stays_in_sync():
    ds, nbr = _world(n_users=90, n_items=70, n_ratings=800)
    cfg = _cfg(ds)
    res = dmf.fit(cfg, ds.train, nbr, epochs=4)
    index = index_from_dataset(ds)
    rng = np.random.default_rng(1)
    users = rng.integers(0, ds.n_users, 96)
    events = np.stack([rng.integers(0, ds.n_users, 20),
                       rng.integers(0, ds.n_items, 20)], 1)
    engines = [
        ServingEngine(res.state, index, ServingConfig(microbatch=16, k=5,
                                                      n_shards=s),
                      train=ds.train, nbr=nbr, dmf_cfg=cfg)
        for s in (1, 4)]
    for e in engines:
        e.ingest(events)
    (v1, i1), (v4, i4) = (e.recommend(users) for e in engines)
    np.testing.assert_array_equal(i1, i4)
    np.testing.assert_allclose(v1, v4, rtol=1e-6, atol=1e-7)
