"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("B", [64, 256, 300, 1024])
@pytest.mark.parametrize("K", [5, 10, 15, 128])
def test_dmf_grads_shapes(B, K):
    rng = np.random.default_rng(B * K)
    u, p, q = (jnp.asarray(rng.normal(size=(B, K)), jnp.float32) for _ in range(3))
    r = jnp.asarray(rng.random(B), jnp.float32)
    c = jnp.asarray(rng.random(B), jnp.float32)
    got = ops.dmf_grads(u, p, q, r, c, alpha=0.1, beta=0.01, gamma=0.02)
    want = ref.dmf_grads_ref(u, p, q, r, c, 0.1, 0.01, 0.02)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 400), st.integers(1, 40), st.integers(0, 99))
def test_dmf_grads_property(B, K, seed):
    rng = np.random.default_rng(seed)
    u, p, q = (jnp.asarray(rng.normal(size=(B, K)), jnp.float32) for _ in range(3))
    r = jnp.asarray(rng.random(B), jnp.float32)
    c = jnp.asarray(rng.random(B), jnp.float32)
    got = ops.dmf_grads(u, p, q, r, c, alpha=0.3, beta=0.2, gamma=0.1)
    want = ref.dmf_grads_ref(u, p, q, r, c, 0.3, 0.2, 0.1)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("I,F", [(128, 128), (200, 333), (512, 64), (77, 1000)])
def test_gossip_mix_shapes(I, F):
    rng = np.random.default_rng(I + F)
    M = jnp.asarray(rng.normal(size=(I, I)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(I, F)), jnp.float32)
    got = ops.gossip_mix_op(M, X)
    want = ref.gossip_mix_ref(M, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gossip_mix_dtype_bf16_inputs_upcast():
    rng = np.random.default_rng(0)
    M = jnp.asarray(rng.normal(size=(64, 64)), jnp.bfloat16)
    X = jnp.asarray(rng.normal(size=(64, 32)), jnp.bfloat16)
    got = ops.gossip_mix_op(M, X)
    want = ref.gossip_mix_ref(M.astype(jnp.float32), X.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("I,J,K,k", [
    (128, 256, 8, 5), (150, 500, 12, 10), (64, 1000, 15, 16), (256, 256, 5, 1),
])
def test_topk_scores_shapes(I, J, K, k):
    rng = np.random.default_rng(I + J + k)
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(J, K)), jnp.float32)
    mask = jnp.asarray(rng.random((I, J)) < 0.1)
    v1, i1 = ops.recommend_topk(U, V, mask, k)
    v2, i2 = ref.topk_scores_ref(U, V, mask, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5, atol=1e-5)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.999  # ties may differ


@settings(max_examples=8, deadline=None)
@given(st.integers(4, 100), st.integers(8, 300), st.integers(1, 8), st.integers(0, 99))
def test_topk_property_values_sorted_and_unmasked(I, J, k, seed):
    rng = np.random.default_rng(seed)
    U = jnp.asarray(rng.normal(size=(I, 6)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(J, 6)), jnp.float32)
    mask = jnp.asarray(rng.random((I, J)) < 0.2)
    k = min(k, J)
    vals, idx = ops.recommend_topk(U, V, mask, k)
    vals, idx = np.asarray(vals), np.asarray(idx)
    assert (np.diff(vals, axis=1) <= 1e-6).all(), "values sorted desc"
    m = np.asarray(mask)
    for i in range(I):
        valid = idx[i][idx[i] >= 0]
        assert (valid < J).all()
        assert not m[i, valid].any(), "masked (train) item recommended"
