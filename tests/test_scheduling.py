"""Scheduling subsystem: workload generators, SLO admission control,
per-shard independent dispatch, ingest interleaving — and the contract the
whole bench hangs off: every slate the scheduler serves is bit-identical
to a direct `ServingEngine.recommend` of the same user ids."""
import numpy as np
import pytest

from repro.core import dmf, graph, metrics
from repro.data import synthetic_poi
from repro.scheduling import (Scheduler, SchedulerConfig, WorkloadConfig,
                              generate, simulate_lockstep, summarize)
from repro.scheduling import workload as wl
from repro.scheduling.metrics import (EXPIRED, REJECTED_QUEUE_FULL, SERVED,
                                      RequestRecord)
from repro.serving import ServingConfig, ServingEngine, index_from_dataset

pytestmark = pytest.mark.scheduling


def _world(seed=0, epochs=4):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=50, n_ratings=600, n_cities=4, seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                        beta=0.1, gamma=0.01, batch_size=64)
    state = dmf.fit(cfg, ds.train, nbr, epochs=epochs).state
    return ds, nbr, cfg, state


def _engine(state, ds, nbr, cfg, microbatch=8, n_shards=1, **kw):
    return ServingEngine(
        state, index_from_dataset(ds),
        ServingConfig(microbatch=microbatch, k=5, n_shards=n_shards, **kw),
        train=ds.train, nbr=nbr, dmf_cfg=cfg)


# ------------------------------------------------------------------ workload
def test_poisson_arrivals_rate_and_determinism():
    cfg = WorkloadConfig(n_requests=4000, rate_rps=1000.0, seed=5)
    reqs = generate(cfg, n_users=64)
    t = np.asarray([r.arrival for r in reqs])
    assert t[0] == 0.0 and (np.diff(t) >= 0).all()
    rate = (len(t) - 1) / (t[-1] - t[0])
    assert 0.9 * cfg.rate_rps < rate < 1.1 * cfg.rate_rps
    # fully seed-keyed: same config ⇒ same stream; new seed ⇒ a new one
    again = generate(cfg, n_users=64)
    assert [(r.user, r.arrival) for r in again] == \
           [(r.user, r.arrival) for r in reqs]
    other = generate(WorkloadConfig(n_requests=4000, rate_rps=1000.0, seed=6),
                     n_users=64)
    assert [r.arrival for r in other] != [r.arrival for r in reqs]
    assert all(r.deadline == pytest.approx(r.arrival + 0.05) for r in reqs)


def test_onoff_arrivals_keep_mean_rate_but_burst():
    base = WorkloadConfig(n_requests=6000, rate_rps=1000.0, seed=1)
    burst = WorkloadConfig(n_requests=6000, rate_rps=1000.0, process="onoff",
                           burst_factor=4.0, duty_cycle=0.25, seed=1)
    tp = np.asarray([r.arrival for r in generate(base, 8)])
    tb = np.asarray([r.arrival for r in generate(burst, 8)])
    rate_b = (len(tb) - 1) / (tb[-1] - tb[0])
    assert 0.85 * 1000.0 < rate_b < 1.15 * 1000.0   # long-run mean preserved
    # burstiness: inter-arrival CV well above the Poisson CV (≈1)
    cv = lambda t: np.diff(t).std() / np.diff(t).mean()
    assert cv(tb) > cv(tp) * 1.2
    with pytest.raises(AssertionError):             # OFF rate would go < 0
        WorkloadConfig(process="onoff", burst_factor=8.0, duty_cycle=0.5)


def test_powerlaw_users_concentrate_on_head():
    n_users = 256
    cfg = WorkloadConfig(n_requests=8000, users="powerlaw", zipf_s=1.2,
                         seed=2)
    users = np.asarray([r.user for r in generate(cfg, n_users)])
    assert users.min() >= 0 and users.max() < n_users
    counts = np.bincount(users, minlength=n_users)
    top = np.sort(counts)[::-1][: n_users // 10].sum() / len(users)
    assert top > 0.5          # top 10% of users carry most of the traffic
    uni = np.asarray([r.user for r in generate(
        WorkloadConfig(n_requests=8000, seed=2), n_users)])
    cu = np.bincount(uni, minlength=n_users)
    assert np.sort(cu)[::-1][: n_users // 10].sum() / len(uni) < 0.25


def test_replay_and_json_roundtrip(tmp_path):
    reqs = wl.replay([3.0, 3.5, 4.0], [7, 1, 7], slo_ms=20.0,
                     priorities=[0, 2, 1])
    assert [r.arrival for r in reqs] == [0.0, 0.5, 1.0]   # rebased to 0
    assert [r.priority for r in reqs] == [0, 2, 1]
    with pytest.raises(AssertionError):
        wl.replay([1.0, 0.5], [0, 1])                     # unsorted trace
    best_effort = wl.replay([0.0, 1.0], [2, 3], slo_ms=0)
    assert all(np.isinf(r.deadline) for r in best_effort)
    # exact roundtrip on the fields the trace serializes (inf deadline ⇒ null)
    orig = reqs + best_effort
    back = wl.from_json(wl.to_json(orig))
    assert [(r.user, r.arrival, r.deadline, r.priority) for r in back] == \
           [(r.user, r.arrival, r.deadline, r.priority) for r in orig]
    out = tmp_path / "trace.json"
    wl.main(["--n", "16", "--n-users", "8", "--process", "onoff",
             "--burst-factor", "4", "--duty-cycle", "0.25",
             "-o", str(out)])
    assert len(wl.from_json(__import__("json").loads(out.read_text()))) == 16


# ------------------------------------------------------- scheduler contracts
def test_scheduler_slates_bit_identical_to_direct_recommend():
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=8)
    reqs = generate(WorkloadConfig(n_requests=60, rate_rps=500.0,
                                   users="powerlaw", slo_ms=0, seed=3),
                    ds.n_users)
    rep = Scheduler(eng, SchedulerConfig()).run(reqs)
    served = rep.served()
    assert len(served) == len(reqs)        # no SLO ⇒ everything serves
    ref = _engine(state, ds, nbr, cfg, microbatch=8)
    vals, idx, flags = ref.recommend([r.user for r in served],
                                     return_flags=True)
    for j, r in enumerate(served):
        np.testing.assert_array_equal(r.vals, vals[j])
        np.testing.assert_array_equal(r.idx, idx[j])
        assert r.fallback == bool(flags[j])
    s = rep.summary(slo_ms=50.0)
    assert s["n_served"] == len(reqs) and s["goodput_rps"] > 0


@pytest.mark.sharded
def test_scheduler_sharded_bit_identical_and_independent_dispatch():
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=8, n_shards=2)
    eng.serve_microbatch(np.arange(8))     # warm: keep virtual times sane
    reqs = generate(WorkloadConfig(n_requests=48, rate_rps=2000.0, slo_ms=0,
                                   seed=4), ds.n_users)
    rep = Scheduler(eng, SchedulerConfig()).run(reqs)
    served = rep.served()
    assert len(served) == len(reqs)
    # both shards dispatched for themselves — no global wave involved
    assert all(n > 0 for n in rep.n_dispatches_per_shard)
    assert [r.shard for r in served] == \
           [Scheduler(eng).shard_of(r.user) for r in served]
    ref = _engine(state, ds, nbr, cfg, microbatch=8, n_shards=2)
    vals, idx = ref.recommend([r.user for r in served])
    for j, r in enumerate(served):
        np.testing.assert_array_equal(r.vals, vals[j])
        np.testing.assert_array_equal(r.idx, idx[j])


@pytest.mark.sharded
def test_empty_shard_queue_never_stalls_dispatch():
    """All traffic on shard 0: shard 1's empty queue must not delay or
    deadlock anything (the exact hostage situation lockstep creates)."""
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=8, n_shards=2)
    rows = eng._rows
    users = np.arange(24) % rows           # every user routes to shard 0
    reqs = wl.replay(np.linspace(0, 0.01, 24), users, slo_ms=0)
    rep = Scheduler(eng, SchedulerConfig()).run(reqs)
    assert len(rep.served()) == 24
    assert rep.n_dispatches_per_shard[0] > 0
    assert rep.n_dispatches_per_shard[1] == 0


def test_impossible_slo_expires_everything_without_dispatch():
    """SLO far below the coalescing timer with a batch that can never fill:
    admission lets them in (no service estimate yet), batch formation
    expires them all, and the engine is never invoked."""
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=32)
    reqs = wl.replay(np.linspace(0, 0.001, 6), np.arange(6), slo_ms=1e-3)
    rep = Scheduler(eng, SchedulerConfig(max_wait_ms=2.0)).run(reqs)
    assert all(r.status == EXPIRED for r in rep.records)
    assert eng.stats.n_dispatches == 0
    s = rep.summary(slo_ms=1e-3)
    assert s["n_served"] == 0 and s["goodput_rps"] == 0.0
    assert s["expired_frac"] == 1.0 and s["slo_attainment"] == 0.0


def test_burst_beyond_queue_capacity_rejects_overflow():
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=4)
    n, cap = 50, 12
    reqs = wl.replay(np.zeros(n), np.arange(n) % ds.n_users, slo_ms=0)
    rep = Scheduler(eng, SchedulerConfig(queue_cap=cap,
                                         admission="queue_only")).run(reqs)
    by = {}
    for r in rep.records:
        by[r.status] = by.get(r.status, 0) + 1
    assert by[REJECTED_QUEUE_FULL] == n - cap
    assert by[SERVED] == cap
    s = rep.summary()
    assert s["n_rejected_queue_full"] == n - cap
    assert s["rejected_frac"] == pytest.approx((n - cap) / n)


def test_priority_dispatches_before_earlier_arrivals():
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=8)
    eng.serve_microbatch(np.arange(8))     # warm
    n = 16
    times = np.zeros(n)
    users = np.arange(n) % ds.n_users
    pr = np.asarray([0, 1] * (n // 2))     # urgent ones arrive interleaved
    reqs = wl.make_requests(times, users, slo_ms=0, priorities=pr)
    rep = Scheduler(eng, SchedulerConfig(admission="none")).run(reqs)
    served = {r.rid: r for r in rep.served()}
    hi = [served[r.rid].dispatch_start for r in reqs if r.priority == 1]
    lo = [served[r.rid].dispatch_start for r in reqs if r.priority == 0]
    assert max(hi) <= min(lo)              # whole urgent batch fired first


def test_fallback_users_flow_through_admission_and_get_flagged():
    ds, nbr, cfg, state = _world()
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    seen[7] = False                        # cold user
    eng = ServingEngine(state, index_from_dataset(ds),
                        ServingConfig(microbatch=8, k=5), seen=seen,
                        train=ds.train, nbr=nbr, dmf_cfg=cfg)
    users = [7, ds.n_users + 3, -2, 0, 11]
    reqs = wl.replay(np.linspace(0, 0.001, len(users)), users, slo_ms=0)
    rep = Scheduler(eng, SchedulerConfig()).run(reqs)
    served = rep.served()
    assert [r.status for r in rep.records] == [SERVED] * len(users)
    flags = [r.fallback for r in served]
    assert flags == [True, True, True, False, False]
    ref = ServingEngine(state, index_from_dataset(ds),
                        ServingConfig(microbatch=8, k=5), seen=seen)
    pv, pi, pf = ref.recommend(np.asarray(users), return_flags=True)
    for j, r in enumerate(served):
        assert r.fallback == bool(pf[j])
        np.testing.assert_array_equal(r.idx, pi[j])
        np.testing.assert_array_equal(r.vals, pv[j])


def test_ingest_interleaves_into_idle_gap_and_stays_snapshot_exact():
    """Refresh runs between bursts, never blocking a queued request, and
    slates are exact against the matching factor snapshot on both sides."""
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=8)
    rng = np.random.default_rng(9)
    users = rng.integers(0, ds.n_users, 24)
    t = np.concatenate([np.linspace(0, 0.005, 12),
                        60.0 + np.linspace(0, 0.005, 12)])
    reqs = wl.replay(t, users, slo_ms=0)
    events = ds.test[:8].astype(np.int64)
    rep = Scheduler(eng, SchedulerConfig()).run(reqs, ingest_events=[events])
    assert rep.n_ingest_windows == 1
    (t0, t1), = rep.ingest_intervals
    assert 0.005 <= t0 and t1 <= 60.0      # strictly inside the idle gap
    served = rep.served()
    pre = [r for r in served if r.ingest_epoch == 0]
    post = [r for r in served if r.ingest_epoch == 1]
    assert len(pre) == 12 and len(post) == 12
    ref0 = _engine(state, ds, nbr, cfg, microbatch=8)
    v0, i0 = ref0.recommend([r.user for r in pre])
    ref1 = _engine(state, ds, nbr, cfg, microbatch=8)
    ref1.ingest(events)
    v1, i1 = ref1.recommend([r.user for r in post])
    for j, r in enumerate(pre):
        np.testing.assert_array_equal(r.vals, v0[j])
        np.testing.assert_array_equal(r.idx, i0[j])
    for j, r in enumerate(post):
        np.testing.assert_array_equal(r.vals, v1[j])
        np.testing.assert_array_equal(r.idx, i1[j])


def test_lockstep_baseline_serves_everything_fifo():
    ds, nbr, cfg, state = _world()
    eng = _engine(state, ds, nbr, cfg, microbatch=8)
    reqs = generate(WorkloadConfig(n_requests=40, rate_rps=1000.0, slo_ms=0,
                                   seed=8), ds.n_users)
    rep = simulate_lockstep(eng, reqs)
    served = rep.served()
    assert len(served) == len(reqs)        # no admission, no expiry
    # FIFO: completion times are nondecreasing in arrival order
    comp = [r.completion for r in served]
    assert all(a <= b + 1e-12 for a, b in zip(comp, comp[1:]))
    ref = _engine(state, ds, nbr, cfg, microbatch=8)
    vals, idx = ref.recommend([r.user for r in served])
    for j, r in enumerate(served):
        np.testing.assert_array_equal(r.vals, vals[j])
        np.testing.assert_array_equal(r.idx, idx[j])


# ------------------------------------------------------------------- metrics
def test_summarize_empty_and_slo_accounting():
    assert summarize([], [], slo_ms=50.0)["goodput_rps"] == 0.0
    recs = [
        RequestRecord(rid=0, user=0, shard=0, arrival=0.0, deadline=0.010,
                      status=SERVED, dispatch_start=0.0, completion=0.005),
        RequestRecord(rid=1, user=1, shard=0, arrival=0.0, deadline=0.010,
                      status=SERVED, dispatch_start=0.0, completion=0.020),
        RequestRecord(rid=2, user=2, shard=0, arrival=0.001, deadline=0.011,
                      status=EXPIRED),
    ]
    s = summarize(recs, None, slo_ms=10.0)
    assert s["n_served"] == 2 and s["n_expired"] == 1
    # the late request and the expired one both count against attainment
    assert s["slo_attainment"] == pytest.approx(1 / 3)
    # goodput: 1 within-deadline over last_completion - first_arrival
    assert s["goodput_rps"] == pytest.approx(1 / 0.020)
    assert s["p99_slo_met"] is False
    assert s["latency_ms"]["p99_ms"] > 10.0


def test_offered_load_is_gap_mle_and_matches_docstring():
    """Regression: the docstring used to claim n/span while the code
    computed (n-1)/span — the definition is now pinned to the gap MLE.
    3 arrivals over 1 s = 2 inter-arrival gaps = 2 rps, not 3."""
    recs = [
        RequestRecord(rid=i, user=i, shard=0, arrival=0.5 * i,
                      deadline=float("inf"), status=SERVED,
                      dispatch_start=0.5 * i, completion=0.5 * i + 0.01)
        for i in range(3)
    ]
    s = summarize(recs)
    assert s["offered_load_rps"] == pytest.approx(2 / 1.0)
    from repro.scheduling import metrics as sched_metrics
    assert "(n_arrivals - 1)" in sched_metrics.__doc__


def test_offered_load_single_arrival_reports_nonzero():
    """Regression: a 1-request run used to report offered_load_rps == 0.0
    (no arrival span); it now falls back to n / serving horizon."""
    one = [RequestRecord(rid=0, user=0, shard=0, arrival=1.0, deadline=2.0,
                         status=SERVED, dispatch_start=1.0, completion=1.05)]
    s = summarize(one)
    assert s["offered_load_rps"] == pytest.approx(1 / 0.05)
    # simultaneous arrivals (zero span) use the same fallback
    burst = [
        RequestRecord(rid=i, user=i, shard=0, arrival=0.0, deadline=1.0,
                      status=SERVED, dispatch_start=0.0, completion=0.25)
        for i in range(4)
    ]
    assert summarize(burst)["offered_load_rps"] == pytest.approx(4 / 0.25)
    # a single never-served request still degrades to 0.0, not NaN
    lost = [RequestRecord(rid=0, user=0, shard=0, arrival=0.0, deadline=0.1,
                          status=EXPIRED)]
    assert summarize(lost)["offered_load_rps"] == 0.0
