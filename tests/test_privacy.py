"""DP gradient-exchange subsystem (src/repro/privacy/): mechanism
equivalence (Pallas kernel == jnp oracle, disabled == bit-exact identity),
DP-off bit-exactness with the PR 1-3 training paths, DP-on shard-count
invariance of the counter-keyed noise, RDP accountant sanity, and the
leakage audit's noise-kills-the-attack direction."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.kernels import ops, ref
from repro.kernels.dp_noise import gauss_counter
from repro.privacy import (
    GaussianAccountant,
    audit,
    mechanism,
    rdp_subsampled_gaussian,
    rdp_to_epsilon,
    sigma_for_epsilon,
)

pytestmark = pytest.mark.privacy

INF = float("inf")


def _world(n_users=80, n_items=50, n_ratings=600, seed=0):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=n_users, n_items=n_items, n_ratings=n_ratings, n_cities=4,
        seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    return ds, graph.walk_neighbor_table(W, gcfg)


def _cfg(ds, **kw):
    return dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                         batch_size=64, beta=0.1, gamma=0.01, **kw)


# ---------------------------------------------------------------------------
# Mechanism: fused kernel vs oracle, identity, clipping, noise stream
# ---------------------------------------------------------------------------
def test_disabled_mechanism_is_bitexact_identity():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(192, 10)), jnp.float32)
    rid = jnp.arange(192, dtype=jnp.int32)
    out = ops.dp_clip_noise(g, rid, 7, clip=INF, noise_std=0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))
    out_ref = ref.dp_clip_noise_ref(g, rid, 7, INF, 0.0)
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(g))


@pytest.mark.parametrize("B", [256, 300, 64])   # 300: pad-to-256-multiple path
@pytest.mark.parametrize("clip,std", [(1.0, 0.0), (0.5, 0.7), (INF, 0.3)])
def test_kernel_matches_oracle(B, clip, std):
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(B, 10)), jnp.float32)
    rid = jnp.asarray(rng.integers(0, 10_000, B), jnp.int32)
    got = np.asarray(ops.dp_clip_noise(g, rid, 42, clip=clip, noise_std=std))
    want = np.asarray(ref.dp_clip_noise_ref(g, rid, 42, clip, std))
    # noise stream is bit-identical by construction; the clip-norm reduction
    # may differ by padding-dependent reduce order only
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-6)


def test_clip_bounds_row_norms():
    g = jnp.asarray(np.random.default_rng(2).normal(size=(128, 8)) * 5,
                    jnp.float32)
    rid = jnp.arange(128, dtype=jnp.int32)
    out = np.asarray(ops.dp_clip_noise(g, rid, 0, clip=0.5, noise_std=0.0))
    assert np.linalg.norm(out, axis=1).max() <= 0.5 * (1 + 1e-5)
    # rows already under the bound pass through bit-exactly
    small = np.linalg.norm(np.asarray(g), axis=1) <= 0.5
    if small.any():
        np.testing.assert_array_equal(out[small], np.asarray(g)[small])


def test_counter_noise_deterministic_and_seeded():
    rid = jnp.arange(4096, dtype=jnp.int32).reshape(-1, 1)
    z1 = np.asarray(gauss_counter(7, rid, 16))
    z2 = np.asarray(gauss_counter(7, rid, 16))
    z3 = np.asarray(gauss_counter(8, rid, 16))
    np.testing.assert_array_equal(z1, z2)
    assert (z1 != z3).mean() > 0.99
    # moments of a 65k-draw standard normal
    assert abs(z1.mean()) < 0.02 and abs(z1.std() - 1.0) < 0.02
    # disjoint rid ranges draw disjoint streams
    z4 = np.asarray(gauss_counter(7, rid + 4096, 16))
    assert (z1 != z4).mean() > 0.99
    # rows 2^23 apart must NOT recycle draws: the 512-counter block uses
    # the low 23 rid bits, the high bits fold into the per-row stream key
    # (a wrapped uint32 counter would reuse noise, which cancels in update
    # differences and leaks at the millions-of-rows epoch scale)
    z5 = np.asarray(gauss_counter(7, rid + (1 << 23), 16))
    assert (z1 != z5).mean() > 0.99


def test_ldmf_dp_params_are_inert():
    """ldmf exchanges nothing, so there is no mechanism to run and no ε
    claim to make: dp params must not change the trajectory (no seed
    draws), and FitResult.privacy stays None instead of reporting a
    guarantee about releases that never happened."""
    ds, nbr = _world(n_users=60, n_items=40, n_ratings=400, seed=1)
    plain = dmf.fit(_cfg(ds, mode="ldmf"), ds.train, nbr, epochs=3)
    dp = dmf.fit(_cfg(ds, mode="ldmf", dp_sigma=1.0, dp_clip=0.5),
                 ds.train, nbr, epochs=3)
    assert dp.train_losses == plain.train_losses
    assert dp.privacy is None
    assert not dmf.DMFConfig(n_users=4, n_items=4, mode="ldmf",
                             dp_sigma=1.0, dp_clip=0.5).dp



# ---------------------------------------------------------------------------
# Training-path wiring: DP-off bit-exact, DP-on shard-invariant
# ---------------------------------------------------------------------------
def test_dp_off_bitexact_with_existing_paths():
    """σ=0 ∧ clip=∞ IS the default config — the compiled epoch is the
    identical program, so losses and factors match bit-for-bit on the
    sparse path and every shard count (acceptance contract)."""
    ds, nbr = _world()
    ref_fit = dmf.fit(_cfg(ds), ds.train, nbr, epochs=5, test=ds.test)
    for n_shards in (1, 2, 4, 8):
        got = dmf.fit(_cfg(ds, dp_sigma=0.0, dp_clip=INF, n_shards=n_shards),
                      ds.train, nbr, epochs=5, test=ds.test)
        base = dmf.fit(_cfg(ds, n_shards=n_shards), ds.train, nbr, epochs=5,
                       test=ds.test)
        assert got.train_losses == base.train_losses, n_shards
        assert got.test_losses == base.test_losses, n_shards
        np.testing.assert_array_equal(np.asarray(got.state.P),
                                      np.asarray(base.state.P))
        assert got.privacy is None
    # and the single-device DP-off run == the plain reference bitwise
    got1 = dmf.fit(_cfg(ds, dp_sigma=0.0, dp_clip=INF), ds.train, nbr,
                   epochs=5, test=ds.test)
    assert got1.train_losses == ref_fit.train_losses


@pytest.mark.sharded
def test_dp_on_shard_count_invariant():
    """Counter-keyed noise (kernels/dp_noise.py): the noised sharded epoch
    reproduces the noised single-device epoch for every shard count —
    same seeds => same noise, wherever a row is routed."""
    ds, nbr = _world()
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=3)
    ref_fit = dmf.fit(cfg, ds.train, nbr, epochs=5, test=ds.test)
    assert ref_fit.privacy is not None and ref_fit.privacy["eps_max"] > 0
    for n_shards in (2, 4, 8):
        got = dmf.fit(dataclasses.replace(cfg, n_shards=n_shards),
                      ds.train, nbr, epochs=5, test=ds.test)
        np.testing.assert_allclose(ref_fit.train_losses, got.train_losses,
                                   atol=1e-7)
        np.testing.assert_allclose(np.asarray(ref_fit.state.P),
                                   np.asarray(got.state.P), atol=1e-5)
        # accounting is shard-count-independent (same realized stream)
        assert got.privacy["eps_max"] == pytest.approx(
            ref_fit.privacy["eps_max"])


def test_dp_on_changes_trajectory_and_is_seeded():
    ds, nbr = _world()
    plain = dmf.fit(_cfg(ds), ds.train, nbr, epochs=3)
    dp_a = dmf.fit(_cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=1),
                   ds.train, nbr, epochs=3)
    dp_a2 = dmf.fit(_cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=1),
                    ds.train, nbr, epochs=3)
    dp_b = dmf.fit(_cfg(ds, dp_sigma=0.5, dp_clip=1.0, dp_seed=2),
                   ds.train, nbr, epochs=3)
    assert dp_a.train_losses != plain.train_losses      # noise is applied
    assert dp_a.train_losses == dp_a2.train_losses      # and reproducible
    assert dp_a.train_losses != dp_b.train_losses       # and seed-keyed


def test_dp_pallas_matches_jnp_path():
    ds, nbr = _world()
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0)
    a = dmf.fit(cfg, ds.train, nbr, epochs=3)
    b = dmf.fit(dataclasses.replace(cfg, use_pallas=True), ds.train, nbr,
                epochs=3)
    np.testing.assert_allclose(a.train_losses, b.train_losses, atol=1e-7)


def test_dp_message_masks_padded_rows():
    cfg = dmf.DMFConfig(n_users=8, n_items=8, dim=4, dp_sigma=1.0, dp_clip=1.0)
    gp = jnp.zeros((16, 4), jnp.float32)
    valid = jnp.asarray([1.0] * 10 + [0.0] * 6)
    noise = dmf._dp_noise_rows(
        jnp.arange(16, dtype=jnp.int32), jnp.asarray(0, jnp.int32), cfg, 4)
    out = np.asarray(dmf._dp_message(gp, noise, cfg, valid))
    assert (out[:10] != 0).any()            # real rows got noise
    np.testing.assert_array_equal(out[10:], 0.0)   # pad rows stay no-ops


def test_sigma_zero_requires_nothing_but_sigma_needs_finite_clip():
    with pytest.raises(AssertionError):
        dmf.DMFConfig(n_users=4, n_items=4, dp_sigma=1.0)   # clip=inf
    cfg = dmf.DMFConfig(n_users=4, n_items=4, dp_clip=1.0)  # clip-only: OK
    assert cfg.dp and mechanism.noise_std(cfg) == 0.0


# ---------------------------------------------------------------------------
# Accountant
# ---------------------------------------------------------------------------
def test_rdp_reduces_to_gaussian_at_q1():
    alphas = (2, 4, 8)
    got = rdp_subsampled_gaussian(1.0, 2.0, alphas)
    np.testing.assert_allclose(got, [a / (2 * 4.0) for a in alphas])
    assert (rdp_subsampled_gaussian(0.0, 2.0, alphas) == 0).all()


def test_epsilon_monotone_in_sigma_and_steps():
    q, steps = 0.05, 200
    eps = [float(rdp_to_epsilon(steps * rdp_subsampled_gaussian(q, s))[0])
           for s in (0.5, 1.0, 2.0, 4.0)]
    assert eps == sorted(eps, reverse=True) and eps[-1] > 0
    e1 = float(rdp_to_epsilon(100 * rdp_subsampled_gaussian(q, 1.0))[0])
    e2 = float(rdp_to_epsilon(400 * rdp_subsampled_gaussian(q, 1.0))[0])
    assert e2 > e1


def test_sigma_for_epsilon_roundtrip():
    q, steps, delta = 0.02, 500, 1e-5
    for target in (0.5, 2.0, 8.0):
        s = sigma_for_epsilon(target, q, steps, delta)
        eps = float(rdp_to_epsilon(
            steps * rdp_subsampled_gaussian(q, s), delta=delta)[0])
        assert eps <= target * 1.001 and eps >= target * 0.9


def test_accountant_tracks_realized_participation():
    acc = GaussianAccountant(n_users=6, sigma=1.0)
    ui = np.asarray([[0, 0, 1, 2], [0, 3, 3, 3]])   # nb=2 batches of B=4
    acc.observe_epoch(ui)
    assert acc.epochs == 1
    np.testing.assert_array_equal(acc.messages, [3, 1, 1, 3, 0, 0])
    eps, _ = acc.epsilon()
    # learner 0: both batches (q=1, k̄=1.5); learners 1-2: one batch, one
    # row (q=.5, k̄=1); learner 3: one batch, THREE rows (q=.5, k̄=3 — the
    # simultaneous releases compose at σ/√k̄, so 3 > 1); 4-5: never → ε=0
    assert eps[1] == eps[2]
    assert eps[3] > eps[1] > eps[4] == eps[5] == 0
    assert eps[0] > eps[1]
    s = acc.summary()
    assert s["eps_max"] == pytest.approx(float(eps.max()))
    assert s["messages_total"] == 8
    acc.observe_epoch(ui)
    assert acc.eps_trajectory[1] > acc.eps_trajectory[0]


def test_accountant_partial_participation_masking():
    """Churn-path accounting (robustness/faults.py feeds ``valid``): rows
    masked out released nothing and must not be charged."""
    acc_full = GaussianAccountant(n_users=6, sigma=1.0)
    acc_mask = GaussianAccountant(n_users=6, sigma=1.0)
    acc_true = GaussianAccountant(n_users=6, sigma=1.0)
    ui = np.asarray([[0, 0, 1, 2], [0, 3, 3, 3]])
    valid = np.asarray([[True, True, True, False],   # learner 2 offline
                        [False, True, True, True]])  # one of 0's rows masked
    acc_full.observe_epoch(ui)
    acc_mask.observe_epoch(ui, valid=valid)
    acc_true.observe_epoch(ui, valid=np.ones_like(valid))
    ef, _ = acc_full.epsilon()
    em, _ = acc_mask.epsilon()
    et, _ = acc_true.epsilon()
    # all-True mask is literally the unmasked ledger
    np.testing.assert_array_equal(et, ef)
    np.testing.assert_array_equal(acc_true.messages, acc_full.messages)
    # fully-masked learner: zero releases, exactly eps = 0
    assert acc_mask.messages[2] == 0 and em[2] == 0.0
    # epsilon is monotone in realized participation, per learner
    assert (em <= ef).all()
    assert em[0] < ef[0]                     # learner 0 lost a release
    np.testing.assert_array_equal(
        acc_mask.messages, [2, 1, 0, 3, 0, 0])


def test_accountant_epsilon_monotone_as_mask_grows():
    rng = np.random.default_rng(0)
    ui = rng.integers(0, 8, size=(4, 16))
    keep = rng.random((4, 16))
    prev = np.full(8, np.inf)
    for p in (1.0, 0.7, 0.4, 0.0):           # progressively more masking
        acc = GaussianAccountant(n_users=8, sigma=1.0)
        acc.observe_epoch(ui, valid=keep < p)
        eps, _ = acc.epsilon()
        assert (eps <= prev + 1e-12).all(), p
        prev = eps
    assert (prev == 0.0).all()               # nothing released at p=0


# ---------------------------------------------------------------------------
# Audit: noise kills the attacks
# ---------------------------------------------------------------------------
def test_audit_advantage_drops_with_noise():
    ds, nbr = _world(n_users=64, n_items=40, n_ratings=500, seed=2)
    leaky = audit.run_audit(_cfg(ds), ds.train, nbr, ds.n_users, ds.n_items,
                            epochs=1, n_pairs=300)
    noisy = audit.run_audit(_cfg(ds, dp_sigma=4.0, dp_clip=1.0), ds.train,
                            nbr, ds.n_users, ds.n_items, epochs=1, n_pairs=300)
    # un-noised gradients leak ratings nearly perfectly...
    assert leaky["rating_norm_advantage"] > 0.8
    assert leaky["rating_inversion_advantage"] > 0.8
    assert leaky["membership_advantage"] > 0.5
    # ...and heavy noise collapses every attack
    assert noisy["rating_norm_advantage"] < leaky["rating_norm_advantage"] - 0.3
    assert noisy["rating_inversion_advantage"] < (
        leaky["rating_inversion_advantage"] - 0.3)
    assert noisy["membership_advantage"] < leaky["membership_advantage"]
    assert noisy["n_messages"] == leaky["n_messages"] > 0


def test_audit_stream_matches_trained_state():
    """The audit's replayed capture IS the training path: after one epoch
    its evolved factors equal `train_epoch`'s (same rng protocol)."""
    ds, nbr = _world(n_users=64, n_items=40, n_ratings=500, seed=2)
    cfg = _cfg(ds, dp_sigma=0.5, dp_clip=1.0)
    log = audit.observe_messages(cfg, ds.train, nbr, epochs=1)
    rng = np.random.default_rng(cfg.seed)
    state = dmf.init_state(cfg, rng)
    ui, _, _, _ = dmf.sample_epoch(ds.train, cfg, rng)
    n = (len(ui) // cfg.batch_size) * cfg.batch_size
    assert len(log.sender) == n
    np.testing.assert_array_equal(log.sender, ui[:n])
    # messages are clipped (post-mechanism stream, modulo added noise which
    # is bounded in norm for this σ·C with overwhelming margin here)
    assert np.isfinite(log.gp).all()


# ---------------------------------------------------------------------------
# Online refresh: DP applies to the streamed channel too
# ---------------------------------------------------------------------------
@pytest.mark.serving
def test_online_refresh_dp_keeps_locality_and_noises_messages():
    from repro.serving import online as online_lib

    ds, nbr = _world()
    cfg = _cfg(ds)
    res = dmf.fit(cfg, ds.train, nbr, epochs=3)
    rng = np.random.default_rng(5)
    events = np.stack([rng.integers(0, ds.n_users, 12),
                       rng.integers(0, ds.n_items, 12)], 1)

    def copy_state():
        return dmf.DMFState(U=jnp.array(res.state.U), P=jnp.array(res.state.P),
                            Q=jnp.array(res.state.Q))

    cfg_dp = _cfg(ds, dp_sigma=0.5, dp_clip=1.0)
    st_dp, rep = online_lib.online_refresh(
        copy_state(), nbr, events, cfg_dp, rng=np.random.default_rng(7))
    st_plain, _ = online_lib.online_refresh(
        copy_state(), nbr, events, cfg, rng=np.random.default_rng(7))
    # locality contract unchanged under DP: untouched rows bit-identical
    untouched = np.setdiff1d(np.arange(ds.n_users), rep.touched_users)
    np.testing.assert_array_equal(np.asarray(st_dp.P)[untouched],
                                  np.asarray(res.state.P)[untouched])
    # and the refresh messages were actually noised
    assert not np.allclose(np.asarray(st_dp.P)[rep.touched_users],
                           np.asarray(st_plain.P)[rep.touched_users])
