"""Unified observability layer (src/repro/obs/, DESIGN.md §14).

The load-bearing guarantee tested here is the off-is-dead-code /
reductions-only contract: telemetry and tracing change NOTHING about
training — factor trajectories are bit-identical with the full
DP + churn + byzantine stack on, at every shard count. Plus the unit
surface: registry label semantics, the single percentile definition,
span nesting/export schema, the bench-regression gate, and the
roofline's measured-trace rows.
"""
import dataclasses
import json
import logging

import numpy as np
import pytest

from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.obs import metrics as obs_metrics
from repro.obs import trace as trace_lib
from repro.obs.telemetry import TELE_KEYS, TELE_W, device_stats_to_dict
from repro.robustness import ChurnConfig
from repro.robustness.byzantine import AttackConfig, DefenseConfig

EPOCHS = 4


# ---------------------------------------------------------------------------
# shared world (same scale as tests/test_byzantine.py)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def world():
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=50, n_ratings=600, n_cities=4, seed=0))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    return ds, nbr


def _cfg(ds, **kw):
    base = dict(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                batch_size=64, beta=0.1, gamma=0.01)
    base.update(kw)
    return dmf.DMFConfig(**base)


def _full_stack_kwargs(ds):
    """DP + churn + byzantine-with-screening, the hardest telemetry path."""
    return dict(
        epochs=EPOCHS, test=ds.test,
        churn=ChurnConfig(dropout=0.2, delay_classes=(0, 1), seed=4),
        attack=AttackConfig(family="sign_flip", frac=0.2, seed=5),
        defense=DefenseConfig(screen=True, norm_cap=2.0))


def _assert_states_equal(a, b):
    for nm in ("U", "P", "Q"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, nm)), np.asarray(getattr(b, nm)),
            err_msg=f"{nm} diverged")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counter_labels_order_insensitive(self):
        reg = obs_metrics.MetricsRegistry()
        c = reg.counter("msgs")
        c.inc(2, shard=0, path="dense")
        c.inc(3, path="dense", shard=0)
        assert c.value(shard=0, path="dense") == 5.0
        assert c.value(path="dense", shard=0) == 5.0
        assert c.value(shard=1, path="dense") == 0.0

    def test_counter_negative_raises(self):
        reg = obs_metrics.MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("c").inc(-1)

    def test_registration_idempotent_kind_clash_raises(self):
        reg = obs_metrics.MetricsRegistry()
        assert reg.gauge("g") is reg.gauge("g")
        with pytest.raises(ValueError):
            reg.counter("g")

    def test_gauge_set_overwrites(self):
        reg = obs_metrics.MetricsRegistry()
        g = reg.gauge("loss")
        g.set(1.0)
        g.set(0.5)
        assert g.value() == 0.5
        assert np.isnan(g.value(shard=9))

    def test_histogram_snapshot_stats(self):
        reg = obs_metrics.MetricsRegistry()
        h = reg.histogram("lat")
        h.observe_many([0.1, 0.2, 0.3, 0.4], shard=0)
        snap = reg.snapshot()["lat"]
        assert snap["kind"] == "histogram"
        s = snap["values"]["shard=0"]
        assert s["count"] == 4
        assert s["min"] == pytest.approx(0.1)
        assert s["max"] == pytest.approx(0.4)
        assert s["mean"] == pytest.approx(0.25)
        assert s["p50"] == pytest.approx(0.25)

    def test_write_jsonl(self, tmp_path):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("c").inc(7)
        p = tmp_path / "m.jsonl"
        reg.write_jsonl(p, event="e1")
        reg.write_jsonl(p, event="e2")
        lines = [json.loads(l) for l in p.read_text().splitlines()]
        assert [l["event"] for l in lines] == ["e1", "e2"]
        assert lines[0]["metrics"]["c"]["values"][""] == 7.0


# ---------------------------------------------------------------------------
# one percentile definition everywhere (satellite 1)
# ---------------------------------------------------------------------------
class TestPercentileDedup:
    FIXTURE = [0.010, 0.020, 0.030, 0.050, 0.080, 0.130, 0.210, 0.340]

    def test_three_call_sites_pinned_equal(self):
        from repro.scheduling import metrics as sched_metrics
        from repro.serving.engine import EngineStats

        want = obs_metrics.latency_percentiles(self.FIXTURE)
        # pinned ground truth so every implementation must match it, not
        # just each other
        assert want["p50_ms"] == pytest.approx(
            float(np.percentile(np.asarray(self.FIXTURE) * 1e3, 50)))
        assert sched_metrics.latency_percentiles(self.FIXTURE) == want
        st = EngineStats(request_seconds=list(self.FIXTURE),
                         dispatch_seconds=list(self.FIXTURE))
        assert st.latency_percentiles() == want
        assert st.dispatch_latency_percentiles() == want
        # histograms share it too
        h = obs_metrics.MetricsRegistry().histogram("h")
        h.observe_many(self.FIXTURE)
        assert h.percentiles() == want

    def test_generator_input_and_empty(self):
        gen = (x for x in self.FIXTURE)
        assert (obs_metrics.latency_percentiles(gen)
                == obs_metrics.latency_percentiles(self.FIXTURE))
        empty = obs_metrics.latency_percentiles(())
        assert set(empty) == {"p50_ms", "p95_ms", "p99_ms"}
        assert all(np.isnan(v) for v in empty.values())


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------
class TestTrace:
    def test_nesting_depth_and_parent(self):
        tr = trace_lib.Tracer(enabled=True)
        with tr.span("outer"):
            with tr.span("inner", item=3):
                pass
        evs = {e["name"]: e for e in tr.events()}
        assert evs["outer"]["args"]["depth"] == 0
        assert "parent" not in evs["outer"]["args"]
        assert evs["inner"]["args"] == {
            "depth": 1, "parent": "outer", "item": 3}
        # inner completes first, fits inside outer
        assert evs["inner"]["dur"] <= evs["outer"]["dur"]

    def test_chrome_trace_schema_and_json_valid(self, tmp_path):
        tr = trace_lib.Tracer(enabled=True)
        with tr.span("a"):
            pass
        tr.instant("marker", section="x")
        p = tmp_path / "trace.json"
        tr.export_chrome_trace(p)
        doc = json.loads(p.read_text())     # valid JSON round-trip
        assert doc["displayTimeUnit"] == "ms"
        x = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]
        for key in ("name", "ph", "ts", "dur", "pid", "tid", "args"):
            assert key in x
        i = [e for e in doc["traceEvents"] if e["ph"] == "i"][0]
        assert i["args"] == {"section": "x"}

    def test_decorator_and_span_stats(self):
        tr = trace_lib.Tracer(enabled=True)

        @tr.traced("work")
        def f(x):
            return x + 1

        assert f(1) == 2
        assert f(2) == 3
        st = tr.span_stats()["work"]
        assert st["count"] == 2
        assert st["total_s"] >= st["max_s"] >= st["mean_s"] > 0

    def test_disabled_records_nothing_and_is_null_context(self):
        tr = trace_lib.Tracer(enabled=False)
        with tr.span("x"):
            pass
        tr.instant("y")
        assert tr.events() == []
        # module-level span: shared null context while the global tracer
        # is off — the zero-cost hot-path guarantee
        assert not trace_lib.get_tracer().enabled
        assert trace_lib.span("anything") is trace_lib._NULL

    def test_configure_global(self):
        tracer = trace_lib.configure_tracing(True)
        try:
            with trace_lib.span("global-span"):
                pass
            assert any(e["name"] == "global-span" for e in tracer.events())
        finally:
            trace_lib.configure_tracing(False)
            tracer.clear()


# ---------------------------------------------------------------------------
# telemetry: the bit-exactness contract
# ---------------------------------------------------------------------------
class TestTelemetryBitExact:
    def test_single_device_full_stack(self, world):
        ds, nbr = world
        cfg = _cfg(ds, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
        kw = _full_stack_kwargs(ds)
        off = dmf.fit(cfg, ds.train, nbr, **kw)
        on = dmf.fit(cfg, ds.train, nbr, telemetry=True, **kw)
        _assert_states_equal(off.state, on.state)
        assert off.train_losses == on.train_losses
        assert off.test_losses == on.test_losses
        assert off.telemetry is None
        assert len(on.telemetry) == EPOCHS

    def test_single_device_plain(self, world):
        ds, nbr = world
        cfg = _cfg(ds)
        off = dmf.fit(cfg, ds.train, nbr, epochs=3)
        on = dmf.fit(cfg, ds.train, nbr, epochs=3, telemetry=True)
        _assert_states_equal(off.state, on.state)
        assert off.train_losses == on.train_losses

    @pytest.mark.sharded
    @pytest.mark.parametrize("n_shards", [2, 4, 8])
    def test_sharded_full_stack(self, world, n_shards):
        ds, nbr = world
        cfg = _cfg(ds, n_shards=n_shards,
                   dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
        kw = _full_stack_kwargs(ds)
        off = dmf.fit(cfg, ds.train, nbr, **kw)
        on = dmf.fit(cfg, ds.train, nbr, telemetry=True, **kw)
        _assert_states_equal(off.state, on.state)
        assert off.train_losses == on.train_losses
        ev = on.telemetry[0]
        assert len(ev["messages_per_shard"]) == n_shards
        assert sum(ev["messages_per_shard"]) == ev["n_messages"]

    @pytest.mark.sharded
    def test_sharded_no_byz_path(self, world):
        ds, nbr = world
        cfg = _cfg(ds, n_shards=2, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
        kw = dict(epochs=3, test=ds.test,
                  churn=ChurnConfig(dropout=0.2, delay_classes=(0, 1),
                                    seed=4))
        off = dmf.fit(cfg, ds.train, nbr, **kw)
        on = dmf.fit(cfg, ds.train, nbr, telemetry=True, **kw)
        _assert_states_equal(off.state, on.state)
        assert off.train_losses == on.train_losses

    @pytest.mark.sharded
    def test_message_count_shard_invariant(self, world):
        """Delivered-message counts are a property of the fault schedule,
        not the partitioning — identical at every shard count."""
        ds, nbr = world
        kw = _full_stack_kwargs(ds)
        counts = {}
        for ns in (1, 2, 4):
            cfg = _cfg(ds, n_shards=ns, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
            res = dmf.fit(cfg, ds.train, nbr, telemetry=True, **kw)
            counts[ns] = [ev["n_messages"] for ev in res.telemetry]
        assert counts[1] == counts[2] == counts[4]


class TestTelemetryContent:
    def test_event_fields_full_stack(self, world, tmp_path):
        ds, nbr = world
        cfg = _cfg(ds, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
        out = tmp_path / "tele.jsonl"
        res = dmf.fit(cfg, ds.train, nbr, telemetry_out=out,
                      **_full_stack_kwargs(ds))
        assert len(res.telemetry) == EPOCHS
        eps = [ev["dp_eps"] for ev in res.telemetry]
        assert eps == sorted(eps) and eps[0] > 0
        for t, ev in enumerate(res.telemetry):
            assert ev["epoch"] == t
            assert 0 < ev["n_online"] <= ds.n_users
            assert ev["ring_occupancy"] >= 0
            assert ev["screen_accept"] + ev["screen_reject"] >= 0
            assert ev["n_messages"] == ev["messages_per_shard"][0]
            assert np.isfinite(ev["train_loss"])
            assert np.isfinite(ev["test_loss"])
            assert ev["wall_s"] > 0
        # the JSONL stream carries exactly the in-memory events
        lines = [json.loads(l) for l in out.read_text().splitlines()]
        assert lines == res.telemetry

    def test_screen_counts_absent_without_byz(self, world):
        ds, nbr = world
        cfg = _cfg(ds, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
        res = dmf.fit(cfg, ds.train, nbr, epochs=2, telemetry=True,
                      churn=ChurnConfig(dropout=0.2, seed=4))
        for ev in res.telemetry:
            assert "screen_accept" not in ev
            assert "screen_reject" not in ev
            assert "n_messages" in ev

    def test_device_stats_to_dict_shapes(self):
        one = np.arange(TELE_W, dtype=np.float64)
        d1 = device_stats_to_dict(one)
        d2 = device_stats_to_dict(np.stack([one, one]))
        assert d1["u_update_norm"] == pytest.approx(0.0)
        assert d2["n_messages"] == 2 * d1["n_messages"]
        assert d2["messages_per_shard"] == [int(one[4])] * 2
        assert len(TELE_KEYS) == TELE_W

    def test_log_every(self, world, caplog):
        ds, nbr = world
        cfg = _cfg(ds, dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
        with caplog.at_level(logging.INFO, logger="repro.dmf"):
            dmf.fit(cfg, ds.train, nbr, epochs=3, test=ds.test, log_every=1)
        msgs = [r.message for r in caplog.records
                if r.name == "repro.dmf"]
        assert len(msgs) == 3
        assert "epoch 1/3" in msgs[0]
        assert "train_loss=" in msgs[0]
        assert "eps=" in msgs[0]       # DP is on → ε-so-far in the line


# ---------------------------------------------------------------------------
# publish() bridges
# ---------------------------------------------------------------------------
class TestPublish:
    def test_engine_stats_publish(self):
        from repro.serving.engine import EngineStats
        reg = obs_metrics.MetricsRegistry()
        st = EngineStats(n_requests=10, n_dispatches=2,
                         dispatch_seconds=[0.1, 0.2],
                         request_seconds=[0.1] * 10)
        st.publish(registry=reg)
        assert reg.gauge("serving_n_requests").value() == 10
        assert reg.histogram("serving_dispatch_seconds").values() == [0.1, 0.2]
        # re-publish replaces, not re-accumulates
        st.publish(registry=reg)
        assert reg.histogram("serving_request_seconds").values() == [0.1] * 10

    def test_scheduler_report_publish(self):
        from repro.scheduling.metrics import SERVED, RequestRecord
        from repro.scheduling.scheduler import SchedulerReport
        reg = obs_metrics.MetricsRegistry()
        recs = [RequestRecord(rid=i, user=i, shard=0, arrival=0.0,
                              deadline=1.0, status=SERVED,
                              completion=0.05 * (i + 1))
                for i in range(4)]
        rep = SchedulerReport(records=recs, gauges=[],
                              n_dispatches_per_shard=[4],
                              ingest_intervals=[], ingest_reports=[])
        s = rep.publish(registry=reg)
        assert s["n_served"] == 4
        assert reg.gauge("scheduler_n_served").value() == 4.0
        assert reg.gauge("scheduler_slo_attainment").value() == 1.0
        assert len(reg.histogram("scheduler_request_seconds").values()) == 4


# ---------------------------------------------------------------------------
# bench-regression gate (benchmarks/compare.py)
# ---------------------------------------------------------------------------
class TestCompare:
    BASE = {"epochs_per_sec": {"sparse_scan": 100.0},
            "latency_ms": {"p99_ms": 10.0},
            "config": {"n_users": 80},
            "overhead_vs_base": -0.01}

    def _dirs(self, tmp_path, fresh):
        b, f = tmp_path / "base", tmp_path / "fresh"
        b.mkdir()
        f.mkdir()
        (b / "BENCH_x.json").write_text(json.dumps(self.BASE))
        (f / "BENCH_x.json").write_text(json.dumps(fresh))
        return b, f

    def test_identical_passes(self, tmp_path, capsys):
        from benchmarks import compare
        b, f = self._dirs(tmp_path, self.BASE)
        rc = compare.main(["--baseline-dir", str(b), "--fresh-dir", str(f)])
        assert rc == 0
        assert "0 regressed" in capsys.readouterr().out

    def test_throughput_drop_fails(self, tmp_path, capsys):
        from benchmarks import compare
        fresh = json.loads(json.dumps(self.BASE))
        fresh["epochs_per_sec"]["sparse_scan"] = 50.0       # -50% < -25%
        b, f = self._dirs(tmp_path, fresh)
        rc = compare.main(["--baseline-dir", str(b), "--fresh-dir", str(f)])
        assert rc == 1
        assert "REGRESSED" in capsys.readouterr().out

    def test_latency_rise_fails_and_threshold_loosens(self, tmp_path):
        from benchmarks import compare
        fresh = json.loads(json.dumps(self.BASE))
        fresh["latency_ms"]["p99_ms"] = 14.0                # +40%
        b, f = self._dirs(tmp_path, fresh)
        assert compare.main(
            ["--baseline-dir", str(b), "--fresh-dir", str(f)]) == 1
        assert compare.main(
            ["--baseline-dir", str(b), "--fresh-dir", str(f),
             "--threshold", "0.5"]) == 0

    def test_untracked_and_negative_leaves_do_not_gate(self, tmp_path):
        from benchmarks import compare
        fresh = json.loads(json.dumps(self.BASE))
        fresh["config"]["n_users"] = 9999     # untracked config echo
        fresh["overhead_vs_base"] = -0.0125   # negative baseline, tiny move
        b, f = self._dirs(tmp_path, fresh)
        assert compare.main(
            ["--baseline-dir", str(b), "--fresh-dir", str(f)]) == 0

    def test_nothing_to_compare(self, tmp_path):
        from benchmarks import compare
        (tmp_path / "b").mkdir()
        (tmp_path / "f").mkdir()
        assert compare.main(["--baseline-dir", str(tmp_path / "b"),
                             "--fresh-dir", str(tmp_path / "f")]) == 2

    def test_committed_baselines_pass(self):
        """The gate must be green on the repo's own committed artifacts
        (fresh mirror == baseline by construction of save_json)."""
        from benchmarks import compare
        rows, _ = compare.run()
        assert rows, "no BENCH_* baselines found"
        bad = [r for r in rows if r["regressed"]]
        assert not bad, bad


# ---------------------------------------------------------------------------
# roofline measured-trace rows
# ---------------------------------------------------------------------------
class TestRooflineMeasured:
    def test_measured_rows_from_trace(self, tmp_path):
        from benchmarks import roofline
        tr = trace_lib.Tracer(enabled=True)
        with tr.span("fit.epoch"):
            pass
        with tr.span("fit.epoch"):
            pass
        p = tmp_path / "trace.json"
        tr.export_chrome_trace(p)
        rows = roofline.measured_rows(p)
        assert len(rows) == 1
        r = rows[0]
        assert r["arch"] == "measured"
        assert r["shape"] == "fit.epoch"
        assert r["span_count"] == 2
        assert r["collective_source"] == "measured_trace"
        assert r["timing_source"] == "measured"
        assert r["t_compute_s"] > 0
        # run.py's roofline printer needs these keys on every row
        for key in ("t_compute_s", "t_memory_s", "t_collective_s",
                    "dominant", "useful_ratio", "collective_source"):
            assert key in r

    def test_missing_or_garbage_trace_is_empty(self, tmp_path):
        from benchmarks import roofline
        assert roofline.measured_rows(tmp_path / "nope.json") == []
        bad = tmp_path / "bad.json"
        bad.write_text("{not json")
        assert roofline.measured_rows(bad) == []

    def test_analytic_fallback_still_present(self, tmp_path):
        from benchmarks import roofline
        rows = roofline.main(trace_path=tmp_path / "nope.json")
        assert rows
        assert all(r.get("timing_source") != "measured" for r in rows)
