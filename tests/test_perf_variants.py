"""§Perf optimization variants: numerics must match the baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import attention as A
from tests.conftest import run_in_subprocess_with_devices


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([64, 128, 256]), st.integers(0, 99))
def test_triangular_matches_blockwise(S, seed):
    rng = np.random.default_rng(seed)
    B, H, KV, hd = 2, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    base = A.blockwise_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    tri = A.triangular_attention(q, k, v, q_chunk=32)
    np.testing.assert_allclose(np.asarray(tri), np.asarray(base),
                               rtol=2e-4, atol=2e-4)


def test_triangular_dispatch_through_config():
    from repro.configs import registry
    from repro.models import config as mc, transformer
    cfg = mc.reduced(registry.get_config("qwen1.5-4b"), attn_chunk=32)
    cfg_tri = dataclasses.replace(cfg, triangular_attention=True)
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 64)),
                      jnp.int32)
    h1, _, _ = transformer.forward(params, tok, cfg)
    h2, _, _ = transformer.forward(params, tok, cfg_tri)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3, atol=2e-3)


def test_weight_stationary_moe_matches_local_on_mesh():
    run_in_subprocess_with_devices("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.models import moe
from repro.models.config import LayerSpec, ModelConfig
cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=0, vocab_size=64, n_routed_experts=8, n_shared_experts=1,
    moe_top_k=2, moe_d_ff=32, period=(LayerSpec(kind="attn", moe=True),),
    compute_dtype="float32", capacity_factor=8.0)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params, _ = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, 64)), jnp.float32)
y_loc, _ = moe.moe_ffn_local(params, x, cfg, jnp.float32)
y_ws, _ = jax.jit(lambda p, x: moe.moe_ffn_sharded(
    p, x, cfg, jnp.float32, mesh, weight_stationary=True))(params, x)
np.testing.assert_allclose(np.asarray(y_loc), np.asarray(y_ws), rtol=2e-3, atol=2e-3)
# batch=1 (long-context decode): tokens replicated, weights still F-sharded
x1 = x[:1]
y_loc1, _ = moe.moe_ffn_local(params, x1, cfg, jnp.float32)
y_ws1, _ = jax.jit(lambda p, x: moe.moe_ffn_sharded(
    p, x, cfg, jnp.float32, mesh, weight_stationary=True))(params, x1)
np.testing.assert_allclose(np.asarray(y_loc1), np.asarray(y_ws1), rtol=2e-3, atol=2e-3)
print("OK")
""")


def test_serve_ws_shardings_resident():
    """SERVE_WS_OVERRIDES: no data axis on embed dims; expert_ff -> data."""
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.sharding import rules
    # AbstractMesh wants ((name, size), ...) pairs on jax 0.4.x; newer jax
    # accepts (sizes, names) — same shim as tests/test_sharding.py
    try:
        mesh = AbstractMesh((("data", 2), ("model", 4)))
    except TypeError:
        mesh = AbstractMesh((2, 4), ("data", "model"))
    spec = rules.resolve_spec(("experts", "embed", "expert_ff"), (8, 64, 32),
                              mesh, overrides=rules.SERVE_WS_OVERRIDES)
    assert spec == P("model", None, "data")
    spec2 = rules.resolve_spec(("embed", "heads", None), (64, 8, 16),
                               mesh, overrides=rules.SERVE_WS_OVERRIDES)
    assert spec2 == P(None, "model", None)


def test_sliding_window_decode_matches_banded_forward():
    """yi-34b-swa carve-in: ring-buffer windowed decode == full forward with
    the band mask (the long_500k-enabling path for a dense arch)."""
    import dataclasses
    from repro.configs import registry
    from repro.models import config as mc, transformer
    from repro.models.config import LayerSpec
    cfg = mc.reduced(registry.get_config("yi-34b"), remat=False, attn_chunk=512)
    Wn = 8
    cfg_swa = dataclasses.replace(
        cfg, period=(LayerSpec(kind="attn", sliding_window=Wn),))
    assert cfg_swa.supports_long_context_decode
    params, _ = transformer.init_params(cfg_swa, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 1, 20
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    h, _, _ = transformer.forward(params, tokens, cfg_swa)
    full = jnp.einsum("bd,dv->bv", h[:, -1], params["lm_head"].astype(h.dtype))
    cache = transformer.init_cache(cfg_swa, B, S)
    assert cache["0"]["k"].shape[2] == Wn  # O(window) memory
    for t in range(S):
        logits, cache = transformer.decode_step(
            params, cache, tokens[:, t:t + 1], jnp.asarray(t, jnp.int32), cfg_swa)
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full),
                               rtol=5e-3, atol=5e-4)


def test_blockwise_window_mask_matches_dense():
    rng = np.random.default_rng(4)
    B, S, H, KV, hd, Wn = 1, 96, 4, 2, 16, 24
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    got = A.blockwise_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32,
                                window=Wn)
    # dense banded reference
    import math
    G = H // KV
    kf = np.repeat(np.asarray(k), G, 2)
    vf = np.repeat(np.asarray(v), G, 2)
    s = np.einsum("bqhd,bshd->bhqs", np.asarray(q), kf) / math.sqrt(hd)
    qpos = np.arange(S)
    mask = (qpos[:, None] >= qpos[None, :]) & ((qpos[:, None] - qpos[None, :]) < Wn)
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True)); p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqs,bshd->bqhd", p, vf)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
