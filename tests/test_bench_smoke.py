"""Benchmark-harness wiring smoke: every `benchmarks.run` section stays
importable/callable, and the headline BENCH_* artifacts keep their schema
(keys present, numbers finite, root + benchmarks/results mirror identical) —
so bench wiring can't silently rot between perf-focused PRs.

The two BENCH_* producers run end-to-end at toy sizes (their ``tiny``
mode); the remaining sections are checked at the wiring level (module
imports, `main` callable with the flags run.py passes). Marked ``slow``:
deselect with -m "not slow".
"""
import importlib
import inspect
import json
import math
import pathlib

import pytest

pytestmark = pytest.mark.slow

REPO = pathlib.Path(__file__).resolve().parents[1]

# every `--only` section run.py dispatches, with the module it lazily imports
RUN_SECTIONS = {
    "paper_tables": "benchmarks.paper_tables",
    "convergence": "benchmarks.convergence",
    "reg_sweep": "benchmarks.reg_sweep",
    "walk_sweep": "benchmarks.walk_sweep",
    "dmf_train": "benchmarks.dmf_train_bench",
    "serving": "benchmarks.serving_bench",
    "complexity": "benchmarks.complexity",
    "gossip_ablation": "benchmarks.gossip_ablation",
    "perf_report": "benchmarks.perf_report",
    "kernels": "benchmarks.kernels_bench",
    "roofline": "benchmarks.roofline",
}


def _assert_finite(obj, path="$"):
    """Every numeric leaf in a BENCH_* artifact must be finite."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _assert_finite(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _assert_finite(v, f"{path}[{i}]")
    elif isinstance(obj, float):
        assert math.isfinite(obj), f"non-finite number at {path}: {obj}"


@pytest.fixture()
def bench_outdir(tmp_path, monkeypatch):
    """Redirect `common.save_json` to a scratch tree so toy-size smoke runs
    never clobber the committed headline BENCH_* artifacts."""
    from benchmarks import common

    monkeypatch.setattr(common, "RESULTS", tmp_path / "results")
    monkeypatch.setattr(common, "ROOT", tmp_path)
    return tmp_path


def _assert_mirrored(name: str, root_dir: pathlib.Path):
    root = json.loads((root_dir / f"{name}.json").read_text())
    results = json.loads((root_dir / "results" / f"{name}.json").read_text())
    assert root == results, f"{name}: root and results mirror diverged"
    return root


def test_run_sections_exist_and_match_dispatcher():
    """The section names run.py dispatches all resolve to modules with a
    callable entry point, and this table can't drift from run.py silently."""
    run_src = (REPO / "benchmarks" / "run.py").read_text()
    for section, module in RUN_SECTIONS.items():
        assert f'want("{section}")' in run_src, (
            f"run.py lost its `{section}` section")
        mod = importlib.import_module(module)
        assert callable(getattr(mod, "main", None) or getattr(mod, "render")), module
    # and no section in run.py that this smoke doesn't know about
    import re
    for m in re.findall(r'want\("(\w+)"\)', run_src):
        assert m in RUN_SECTIONS, f"run.py gained unsmoked section {m!r}"


def test_bench_dmf_train_tiny_schema(bench_outdir):
    from benchmarks import dmf_train_bench

    res = dmf_train_bench.main(tiny=True, n_timed=1, n_check=2)
    for key in ("config", "epochs_per_sec", "speedup_sparse_vs_dense",
                "train_loss_max_diff_sparse", "train_loss_max_diff_pallas",
                "train_losses_dense", "train_losses_sparse", "sharded"):
        assert key in res, key
    for path in ("dense_per_batch", "sparse_scan", "sparse_scan_pallas"):
        assert res["epochs_per_sec"][path] > 0
    assert res["train_loss_max_diff_sparse"] <= 1e-4
    sh = res["sharded"]
    assert set(sh) >= {"config", "epochs_per_sec",
                       "train_loss_max_diff_vs_sparse"}
    ran = {k: v for k, v in sh["epochs_per_sec"].items() if v is not None}
    assert ran, "no sharded entries ran (device provisioning broke)"
    for k, eps in ran.items():
        assert eps > 0
        assert sh["train_loss_max_diff_vs_sparse"][k] <= 1e-5, k
    _assert_finite(res)
    assert _assert_mirrored("BENCH_dmf_train", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_serving_tiny_schema(bench_outdir):
    from benchmarks import serving_bench

    res = serving_bench.main(tiny=True)
    for key in ("config", "requests_per_sec", "latency_ms",
                "speedup_pruned_vs_loop",
                "pruned_dense_topk_agreement_where_in_bucket", "sharded"):
        assert key in res, key
    for path in ("loop_per_request", "batched_dense", "batched_pruned"):
        assert res["requests_per_sec"][path] > 0
    sh = res["sharded"]
    ran = {k: v for k, v in sh["requests_per_sec"].items() if v is not None}
    assert ran, "no sharded serving entries ran"
    for k, rps in ran.items():
        assert rps > 0
        assert sh["exact_match_vs_single_shard"][k] == 1.0, k
    _assert_finite(res)
    assert _assert_mirrored("BENCH_serving", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_mains_accept_full_flag():
    """run.py calls every section main(full=...) (or main() for the
    flag-less ones) — pin the signatures it relies on."""
    for section, module in RUN_SECTIONS.items():
        mod = importlib.import_module(module)
        fn = getattr(mod, "main", None)
        if fn is None:
            continue
        params = inspect.signature(fn).parameters
        if section in ("paper_tables", "convergence", "reg_sweep",
                       "walk_sweep", "dmf_train", "serving", "complexity"):
            assert "full" in params, f"{module}.main lost full="
