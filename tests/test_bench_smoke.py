"""Benchmark-harness wiring smoke: every `benchmarks.run` section stays
importable/callable, and the headline BENCH_* artifacts keep their schema
(keys present, numbers finite, root + benchmarks/results mirror identical) —
so bench wiring can't silently rot between perf-focused PRs.

The two BENCH_* producers run end-to-end at toy sizes (their ``tiny``
mode); the remaining sections are checked at the wiring level (module
imports, `main` callable with the flags run.py passes). Marked ``slow``:
deselect with -m "not slow".
"""
import importlib
import inspect
import json
import math
import pathlib

import pytest

pytestmark = pytest.mark.slow

REPO = pathlib.Path(__file__).resolve().parents[1]

# every `--only` section run.py dispatches, with the module it lazily imports
RUN_SECTIONS = {
    "paper_tables": "benchmarks.paper_tables",
    "convergence": "benchmarks.convergence",
    "reg_sweep": "benchmarks.reg_sweep",
    "walk_sweep": "benchmarks.walk_sweep",
    "dmf_train": "benchmarks.dmf_train_bench",
    "serving": "benchmarks.serving_bench",
    "scheduler": "benchmarks.scheduler_bench",
    "privacy": "benchmarks.privacy_bench",
    "robustness": "benchmarks.churn_bench",
    "byzantine": "benchmarks.byzantine_bench",
    "complexity": "benchmarks.complexity",
    "gossip_ablation": "benchmarks.gossip_ablation",
    "perf_report": "benchmarks.perf_report",
    "kernels": "benchmarks.kernels_bench",
    "roofline": "benchmarks.roofline",
}


def _assert_finite(obj, path="$"):
    """Every numeric leaf in a BENCH_* artifact must be finite."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _assert_finite(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _assert_finite(v, f"{path}[{i}]")
    elif isinstance(obj, float):
        assert math.isfinite(obj), f"non-finite number at {path}: {obj}"


@pytest.fixture()
def bench_outdir(tmp_path, monkeypatch):
    """Redirect `common.save_json` to a scratch tree so toy-size smoke runs
    never clobber the committed headline BENCH_* artifacts."""
    from benchmarks import common

    monkeypatch.setattr(common, "RESULTS", tmp_path / "results")
    monkeypatch.setattr(common, "ROOT", tmp_path)
    return tmp_path


def _assert_mirrored(name: str, root_dir: pathlib.Path):
    root = json.loads((root_dir / f"{name}.json").read_text())
    results = json.loads((root_dir / "results" / f"{name}.json").read_text())
    assert root == results, f"{name}: root and results mirror diverged"
    return root


def test_run_sections_exist_and_match_dispatcher():
    """The section names run.py dispatches all resolve to modules with a
    callable entry point, and this table can't drift from run.py silently."""
    run_src = (REPO / "benchmarks" / "run.py").read_text()
    for section, module in RUN_SECTIONS.items():
        assert f'want("{section}")' in run_src, (
            f"run.py lost its `{section}` section")
        mod = importlib.import_module(module)
        assert callable(getattr(mod, "main", None) or getattr(mod, "render")), module
    # and no section in run.py that this smoke doesn't know about
    import re
    for m in re.findall(r'want\("(\w+)"\)', run_src):
        assert m in RUN_SECTIONS, f"run.py gained unsmoked section {m!r}"


def test_bench_dmf_train_tiny_schema(bench_outdir):
    from benchmarks import dmf_train_bench

    res = dmf_train_bench.main(tiny=True, n_timed=1, n_check=2)
    for key in ("config", "epochs_per_sec", "speedup_sparse_vs_dense",
                "train_loss_max_diff_sparse", "train_loss_max_diff_pallas",
                "train_losses_dense", "train_losses_sparse", "sharded"):
        assert key in res, key
    for path in ("dense_per_batch", "sparse_scan", "sparse_scan_pallas"):
        assert res["epochs_per_sec"][path] > 0
    assert res["train_loss_max_diff_sparse"] <= 1e-4
    sh = res["sharded"]
    assert set(sh) >= {"config", "epochs_per_sec",
                       "train_loss_max_diff_vs_sparse"}
    ran = {k: v for k, v in sh["epochs_per_sec"].items() if v is not None}
    assert ran, "no sharded entries ran (device provisioning broke)"
    for k, eps in ran.items():
        assert eps > 0
        assert sh["train_loss_max_diff_vs_sparse"][k] <= 1e-5, k
    _assert_finite(res)
    assert _assert_mirrored("BENCH_dmf_train", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_serving_tiny_schema(bench_outdir):
    from benchmarks import serving_bench

    res = serving_bench.main(tiny=True)
    for key in ("config", "requests_per_sec", "latency_ms",
                "speedup_pruned_vs_loop",
                "pruned_dense_topk_agreement_where_in_bucket", "sharded",
                "tiled_kernel_bit_identical_vs_slab", "million"):
        assert key in res, key
    for path in ("loop_per_request", "batched_dense", "batched_pruned"):
        assert res["requests_per_sec"][path] > 0
    assert res["tiled_kernel_bit_identical_vs_slab"] is True
    sh = res["sharded"]
    ran = {k: v for k, v in sh["requests_per_sec"].items() if v is not None}
    assert ran, "no sharded serving entries ran"
    for k, rps in ran.items():
        assert rps > 0
        assert sh["exact_match_vs_single_shard"][k] == 1.0, k
    # million-user tiled-store section (toy-scale under tiny): exactness
    # flags and quantization deltas are contractual fields
    mil = res["million"]
    for key in ("config", "index", "build_seconds", "resident_gb",
                "requests_per_sec", "fallback_frac", "exact"):
        assert key in mil, key
    assert mil["exact"]["fp32_bitwise_vs_dense_engine"] is True
    for mode in ("fp32", "int8", "bf16"):
        assert mil["requests_per_sec"][mode] > 0
    for mode in ("int8", "bf16"):
        q = mil["exact"][mode]
        assert q["max_abs_score_delta"] <= q["analytic_bound_max"] + 1e-6
        assert 0.0 <= q["topk_overlap_vs_fp32"] <= 1.0
    _assert_finite(res)
    assert _assert_mirrored("BENCH_serving", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_scheduler_tiny_schema(bench_outdir):
    from benchmarks import scheduler_bench

    res = scheduler_bench.main(tiny=True)
    for key in ("config", "single_shard_capacity_rps", "grid",
                "max_shards_measured", "p50_ms_at_max_shards",
                "scheduler_beats_lockstep_p50_at_max_shards",
                "ingest_interleave"):
        assert key in res, key
    assert res["single_shard_capacity_rps"] > 0
    ran = {k: v for k, v in res["grid"].items() if "skipped" not in v}
    assert ran, "no shard entries ran (device provisioning broke)"
    for key, entry in ran.items():
        assert len(entry["loads"]) == len(
            res["config"]["load_fracs_of_capacity"])
        for row in entry["loads"]:
            for side in ("scheduler", "lockstep"):
                s = row[side]
                assert s["n_requests"] == res["config"]["n_requests"]
                assert 0.0 <= s["slo_attainment"] <= 1.0
                assert s["goodput_rps"] >= 0.0
                assert "p99_slo_met" in s and "latency_ms" in s
            # lockstep has no admission control: it serves everything
            assert row["lockstep"]["n_served"] == res["config"]["n_requests"]
        # the headline correctness contract, checked on a live run
        assert entry["bit_identical_vs_direct"] is True, key
    ing = res["ingest_interleave"]
    assert ing["n_windows_run"] == 1
    assert ing["ingest_ran_in_idle_gap"] is True
    assert ing["pre_ingest_bit_identical_to_no_ingest"] is True
    assert ing["post_ingest_bit_identical_to_ingested_snapshot"] is True
    _assert_finite(res)
    assert _assert_mirrored("BENCH_scheduler", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_privacy_tiny_schema(bench_outdir):
    from benchmarks import privacy_bench

    res = privacy_bench.main(tiny=True, n_timed=1)
    for key in ("config", "frontier", "epochs_per_sec",
                "attack_advantage_monotone_nonincreasing",
                "dp_overhead_fused_vs_pallas_base", "dp_overhead_jnp_vs_base"):
        assert key in res, key
    fr = res["frontier"]
    assert fr[0]["dp_sigma"] == 0 and fr[0]["eps"] is None   # DP-off anchor
    eps_vals = [r["eps"] for r in fr[1:]]
    assert all(e > 0 for e in eps_vals)
    assert eps_vals == sorted(eps_vals, reverse=True)        # σ up ⇒ ε down
    for r in fr:
        for m in ("P@5", "R@10", "rating_inversion_advantage",
                  "membership_advantage", "n_messages"):
            assert m in r, m
    # the acceptance direction: attack advantage falls as ε falls
    assert res["attack_advantage_monotone_nonincreasing"]
    adv = [r["rating_inversion_advantage"] for r in fr]
    assert adv[0] > 0.5 and adv[-1] < adv[0] - 0.3
    for k in ("sparse_scan", "dp_jnp", "dp_fused_pallas",
              "sparse_scan_pallas"):
        assert res["epochs_per_sec"][k] > 0
    _assert_finite(res)
    assert _assert_mirrored("BENCH_privacy", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_churn_tiny_schema(bench_outdir):
    from benchmarks import churn_bench

    res = churn_bench.main(tiny=True, n_timed=1, epochs=4)
    for key in ("config", "grid", "late_join", "resume", "epochs_per_sec",
                "churn_overhead_vs_base", "checkpoint_overhead_vs_base"):
        assert key in res, key
    grid = res["grid"]
    assert len(grid) == (len(res["config"]["dropout_grid"])
                         * len(res["config"]["staleness_grid"]))
    # the (0, 0) anchor runs the trivial-plan churn path: exactly fault-free
    anchor = grid[0]
    assert anchor["dropout"] == 0 and anchor["k_max"] == 0
    assert anchor["participation_rate"] == 1.0
    assert anchor["loss_gap_vs_faultfree"] == 0.0, (
        "trivial churn plan drifted from the plain run")
    for row in grid:
        for m in ("participation_rate", "train_loss_final",
                  "test_loss_final", "P@5", "R@10", "loss_gap_vs_faultfree"):
            assert m in row, m
        assert 0.0 < row["participation_rate"] <= 1.0
    # dropout really reduced realized participation along the grid
    assert grid[-1]["participation_rate"] < grid[0]["participation_rate"]
    assert res["late_join"]["late_frac"] == 0.25
    # acceptance: crash-resume with DP on is bit-identical
    assert res["resume"]["bit_identical_with_dp"] is True
    for k in ("sparse_scan", "churn_path", "checkpoint_every_epoch"):
        assert res["epochs_per_sec"][k] > 0
    _assert_finite(res)
    assert _assert_mirrored("BENCH_churn", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_bench_byzantine_tiny_schema(bench_outdir):
    from benchmarks import byzantine_bench

    res = byzantine_bench.main(tiny=True, n_timed=1, epochs=5)
    for key in ("config", "anchor", "grid", "headline", "epochs_per_sec",
                "screening_overhead_vs_base", "robust_agg_overhead_vs_base",
                "dp_interaction"):
        assert key in res, key
    # the live wiring check: byz kwargs off IS the plain run
    assert res["anchor"]["byz_off_gap"] == 0.0, (
        "attack=None/defense=None drifted from the plain fit")
    grid = res["grid"]
    assert len(grid) == len(res["config"]["families"]) * 3
    for row in grid:
        for m in ("family", "defense", "frac", "final_train_loss",
                  "loss_ratio_vs_faultfree", "nonfinite", "halted_at"):
            assert m in row, m
        # a collapsed run reports null loss, never NaN in the artifact
        if row["nonfinite"]:
            assert row["final_train_loss"] is None
        else:
            assert row["final_train_loss"] > 0
    h = res["headline"]
    assert h["undefended_collapsed"] is True
    assert h["defended_within_1p5x"] is True
    assert not any(r["nonfinite"] for r in grid if r["defense"] != "undefended")
    for k in ("sparse_scan", "screen", "screen_trim"):
        assert res["epochs_per_sec"][k] > 0
    dp = res["dp_interaction"]
    assert dp["honest_pass_rate"] >= 0.999
    assert dp["tau_calibrated"] > dp["dp_clip"]
    assert dp["defended_nonfinite"] is False
    _assert_finite(res)
    assert _assert_mirrored("BENCH_byzantine", bench_outdir) == json.loads(
        json.dumps(res, default=float))


def test_run_only_parsing_validates_sections():
    from benchmarks import run as run_mod

    assert run_mod.parse_only("") is None
    assert run_mod.parse_only(" privacy , kernels ") == {"privacy", "kernels"}
    assert set(RUN_SECTIONS) == set(run_mod.SECTIONS)
    with pytest.raises(SystemExit):
        run_mod.parse_only("privacy,nope")


def test_legacy_benches_save_bench_artifacts():
    """Satellite contract: the migrated legacy sections own their BENCH_*
    save (root + results mirror via common.save_json) instead of run.py
    side-saving unmirrored names."""
    for mod in ("convergence", "walk_sweep", "gossip_ablation"):
        src = (REPO / "benchmarks" / f"{mod}.py").read_text()
        assert f'common.save_json("BENCH_{mod}"' in src, mod
    run_src = (REPO / "benchmarks" / "run.py").read_text()
    for legacy in ('save_json("convergence"', 'save_json("walk_sweep"',
                   'save_json("gossip_ablation"'):
        assert legacy not in run_src
    # the gossip subprocess hands results back via file, not stdout parsing
    gossip_src = (REPO / "benchmarks" / "gossip_ablation.py").read_text()
    assert "print(json.dumps(out))" not in gossip_src


def test_bench_mains_accept_full_flag():
    """run.py calls every section main(full=...) (or main() for the
    flag-less ones) — pin the signatures it relies on."""
    for section, module in RUN_SECTIONS.items():
        mod = importlib.import_module(module)
        fn = getattr(mod, "main", None)
        if fn is None:
            continue
        params = inspect.signature(fn).parameters
        if section in ("paper_tables", "convergence", "reg_sweep",
                       "walk_sweep", "dmf_train", "serving", "scheduler",
                       "privacy", "robustness", "byzantine", "complexity"):
            assert "full" in params, f"{module}.main lost full="
