"""Optimizers built from scratch: behavioural checks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


def _quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


def _fit(opt, steps=200):
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(_quad_loss)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    return params


def test_sgd_matches_closed_form():
    opt = optim.sgd(0.1)
    params = {"w": jnp.zeros((1,)), "b": jnp.zeros((1,))}
    state = opt.init(params)
    g = jax.grad(_quad_loss)(params)
    upd, state = opt.update(g, state, params)
    params = optim.apply_updates(params, upd)
    # w1 = 0 - 0.1 * 2(0-3) = 0.6
    np.testing.assert_allclose(np.asarray(params["w"]), [0.6], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(params["b"]), [-0.2], rtol=1e-6)


def test_adamw_converges_quadratic():
    p = _fit(optim.adamw(0.05), steps=400)
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=1e-2)
    np.testing.assert_allclose(np.asarray(p["b"]), -1.0, atol=1e-2)


def test_momentum_converges():
    p = _fit(optim.momentum(0.02, 0.9), steps=300)
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=1e-2)


def test_grad_clip_bounds_update():
    opt = optim.adamw(1.0, grad_clip_norm=1e-3)
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    state = opt.init(params)
    g = jax.tree_util.tree_map(lambda x: x + 1e6, params)
    upd, _ = opt.update(g, state, params)
    # clipped grads -> first-step Adam update magnitude ~ lr regardless,
    # but moments must be finite and small
    assert np.isfinite(np.asarray(upd["w"])).all()


def test_weight_decay_mask():
    def no_decay(path):
        return not str(path[-1].key).startswith("b")

    opt = optim.adamw(0.1, weight_decay=0.5, mask=no_decay, grad_clip_norm=None)
    params = {"w": jnp.ones((2,)), "b": jnp.ones((2,))}
    state = opt.init(params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    upd, _ = opt.update(zero_g, state, params)
    assert float(jnp.abs(upd["w"]).max()) > 0      # decayed
    np.testing.assert_allclose(np.asarray(upd["b"]), 0.0)  # masked out


def test_schedules():
    s = optim.linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) < 1e-6
    c = optim.cosine_decay(2.0, 50, floor=0.5)
    assert abs(float(c(jnp.asarray(0))) - 2.0) < 1e-6
    assert abs(float(c(jnp.asarray(50))) - 0.5) < 1e-6
