"""Serving subsystem: candidate index, fused serve kernel, engine == dense
oracle, microbatcher, and online refresh (locality + tracking)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dmf, graph, metrics
from repro.data import synthetic_poi
from repro.kernels import ops, ref
from repro.serving import (OnlineConfig, ServingConfig, ServingEngine,
                           build_candidate_index, index_from_dataset,
                           online_refresh)

pytestmark = pytest.mark.serving


def _world(seed=0, epochs=6):
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=50, n_ratings=600, n_cities=4, seed=seed))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                        beta=0.1, gamma=0.01, batch_size=64)
    res = dmf.fit(cfg, ds.train, nbr, epochs=epochs)
    return ds, nbr, cfg, res.state


# --------------------------------------------------------------- candidates
def test_candidate_index_structure():
    ds, *_ = _world(epochs=0)
    idx = index_from_dataset(ds)
    assert idx.cap % 128 == 0
    assert idx.bucket_items.shape == (idx.n_buckets, idx.cap)
    for c in range(idx.n_buckets):
        row = idx.bucket_items[c]
        items = row[row >= 0]
        # exactly the city's items, ascending, padding all -1 at the tail
        np.testing.assert_array_equal(items, np.flatnonzero(ds.item_city == c))
        assert (row[len(items):] == -1).all()
    assert idx.n_truncated_buckets == 0
    assert idx.user_fits().all()
    # eligibility oracle rows match the buckets
    elig = idx.eligible_mask(np.arange(ds.n_users))
    for u in range(ds.n_users):
        np.testing.assert_array_equal(
            np.flatnonzero(elig[u]), np.flatnonzero(ds.item_city == ds.user_city[u]))


def test_candidate_index_truncation_priority():
    item_city = np.zeros(300, np.int64)      # one city of 300 > cap=128
    user_city = np.zeros(4, np.int64)
    pop = np.arange(300)                     # priority = item id
    idx = build_candidate_index(item_city, user_city, cap=128,
                                item_priority=pop)
    assert idx.cap == 128
    assert idx.n_truncated_buckets == 1
    assert not idx.user_fits().any()
    kept = idx.bucket_items[0]
    # highest-priority 128 items survive, re-sorted ascending (contractual)
    np.testing.assert_array_equal(kept, np.arange(300 - 128, 300))


# ------------------------------------------------------------- serve kernel
def _random_candidates(rng, R, J, Cw):
    cand = np.full((R, Cw), -1, np.int32)
    for r in range(R):
        n = rng.integers(0, min(J, Cw) + 1)
        cand[r, :n] = np.sort(rng.choice(J, size=n, replace=False))
    return cand


@pytest.mark.parametrize("R,J,K,Cw,k", [
    (13, 90, 10, 37, 7),     # nothing aligned: exercises all pads
    (8, 128, 8, 128, 5),     # fully aligned
    (3, 300, 6, 260, 10),    # J and Cw span multiple item tiles
])
def test_serve_topk_matches_oracle_exactly(R, J, K, Cw, k):
    rng = np.random.default_rng(R + J + k)
    U = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(R, J, K)), jnp.float32)
    seen = jnp.asarray(rng.random((R, J)) < 0.3)
    cand = jnp.asarray(_random_candidates(rng, R, J, Cw))
    vals, idx = ops.serve_topk(U, V, cand, seen, k)
    v_ref, i_ref = ref.serve_topk_ref(U, V, cand, seen, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(v_ref))


def test_serve_topk_exact_ties_break_by_lowest_id():
    # zero item factors -> every candidate scores exactly 0.0; the kernel
    # must resolve ties like lax.top_k: lowest item id first
    rng = np.random.default_rng(0)
    R, J, K, k = 5, 60, 4, 6
    U = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    V = jnp.zeros((R, J, K), jnp.float32)
    seen = jnp.zeros((R, J), bool)
    cand = jnp.asarray(_random_candidates(rng, R, J, 40))
    vals, idx = ops.serve_topk(U, V, cand, seen, k)
    v_ref, i_ref = ref.serve_topk_ref(U, V, cand, seen, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(v_ref))


def test_serve_topk_k_exceeds_bucket_size():
    rng = np.random.default_rng(1)
    R, J, K, k = 6, 50, 5, 10
    U = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(R, J, K)), jnp.float32)
    seen = jnp.zeros((R, J), bool)
    cand = np.full((R, 16), -1, np.int32)
    for r in range(R):                       # buckets of size 0..5 < k
        cand[r, : r] = np.arange(r) * 7
    vals, idx = ops.serve_topk(U, V, jnp.asarray(cand), seen, k)
    v_ref, i_ref = ref.serve_topk_ref(U, V, jnp.asarray(cand), seen, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(v_ref))
    for r in range(R):                       # exactly bucket-size slots fill
        assert (np.asarray(idx)[r] >= 0).sum() == r


def test_serve_topk_all_seen_users():
    rng = np.random.default_rng(2)
    R, J, K, k = 4, 40, 6, 5
    U = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(R, J, K)), jnp.float32)
    cand = jnp.asarray(_random_candidates(rng, R, J, 24))
    seen = jnp.ones((R, J), bool)
    vals, idx = ops.serve_topk(U, V, cand, seen, k)
    assert (np.asarray(idx) == -1).all()
    assert (np.asarray(vals) <= ref.NEG_INF).all()
    v_ref, i_ref = ref.serve_topk_ref(U, V, cand, seen, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))


# --------------------------------------------- peruser kernel edge coverage
def _peruser_oracle(U, V, mask, k):
    vals, idx = ref.topk_scores_peruser_ref(U, V, mask, k)
    return ref.masked_topk_finalize(jnp.where(jnp.isneginf(vals),
                                              ref.NEG_INF, vals), idx)


def test_recommend_topk_peruser_j_not_tile_divisible():
    rng = np.random.default_rng(3)
    I, J, K, k = 20, 130, 7, 5         # J % 128 != 0 -> wrapper pads items
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    mask = jnp.asarray(rng.random((I, J)) < 0.2)
    vals, idx = ops.recommend_topk_peruser(U, V, mask, k)
    v_ref, i_ref = _peruser_oracle(U, V, mask, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(idx) < J).all(), "padded item column recommended"


def test_recommend_topk_peruser_k_exceeds_unseen():
    rng = np.random.default_rng(4)
    I, J, K, k = 8, 30, 5, 16
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    mask = np.ones((I, J), bool)
    mask[:, :4] = False                   # only 4 unseen items, k=16
    vals, idx = ops.recommend_topk_peruser(U, V, jnp.asarray(mask), k)
    v_ref, i_ref = _peruser_oracle(U, V, jnp.asarray(mask), k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    assert ((np.asarray(idx)[:, 4:]) == -1).all()


def test_recommend_topk_peruser_all_seen():
    rng = np.random.default_rng(5)
    I, J, K, k = 6, 64, 4, 5
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    mask = jnp.ones((I, J), bool)
    vals, idx = ops.recommend_topk_peruser(U, V, mask, k)
    assert (np.asarray(idx) == -1).all()
    assert (np.asarray(vals) <= ref.NEG_INF).all()


# ------------------------------------------------------------------- engine
def test_engine_pruned_matches_serve_oracle_exactly():
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    # fallback=False: this is the raw factor-scoring kernel oracle — cold
    # users must go through the same path (fallback exactness is covered by
    # the dedicated fallback suite below)
    eng = ServingEngine(state, index,
                        ServingConfig(microbatch=16, k=5, fallback=False),
                        train=ds.train)
    users = np.random.default_rng(7).integers(0, ds.n_users, 53)
    vals, idx = eng.recommend(users)
    v_ref, i_ref = ref.serve_topk_ref(
        jnp.asarray(state.U[users]),
        jnp.asarray((state.P + state.Q)[users]),
        jnp.asarray(index.bucket_items[index.user_bucket[users]]),
        jnp.asarray(np.asarray(eng.seen)[users]), 5)
    np.testing.assert_array_equal(idx, np.asarray(i_ref))
    np.testing.assert_array_equal(vals, np.asarray(v_ref))
    assert eng.stats.n_requests == 53
    assert eng.stats.n_dispatches == 4       # ceil(53 / 16) fixed-shape batches


def test_engine_equals_full_dense_oracle_where_topk_in_bucket():
    """Acceptance: engine top-k == dense scores() + mask + top_k, exactly
    (indices and values), for users whose dense top-k fits the bucket."""
    ds, nbr, cfg, state = _world(epochs=10)
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index,
                        ServingConfig(microbatch=32, k=5, fallback=False),
                        train=ds.train)
    users = np.arange(ds.n_users)
    vals, idx = eng.recommend(users)
    # dense full-J oracle, same score contraction as scores(): u · (p + q)
    V = state.P + state.Q
    full_cand = jnp.broadcast_to(jnp.arange(ds.n_items, dtype=jnp.int32),
                                 (ds.n_users, ds.n_items))
    dv, di = ref.serve_topk_ref(
        jnp.asarray(state.U), jnp.asarray(V), full_cand,
        jnp.asarray(np.asarray(eng.seen)), 5)
    dv, di = np.asarray(dv), np.asarray(di)
    in_bucket = np.array([
        np.isin(di[u][di[u] >= 0],
                index.bucket_items[index.user_bucket[u]]).all()
        for u in range(ds.n_users)])
    assert in_bucket.any(), "no user's dense top-k fits their bucket"
    np.testing.assert_array_equal(idx[in_bucket], di[in_bucket])
    np.testing.assert_array_equal(vals[in_bucket], dv[in_bucket])


def test_engine_dense_path_matches_peruser_kernel():
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index,
                        ServingConfig(microbatch=16, k=5, prune=False),
                        train=ds.train)
    users = np.random.default_rng(8).integers(0, ds.n_users, 20)
    _, idx = eng.recommend(users)
    _, i_ref = ops.recommend_topk_peruser(
        jnp.asarray(state.U[users]),
        jnp.asarray((state.P + state.Q)[users]),
        jnp.asarray(np.asarray(eng.seen)[users]), 5)
    np.testing.assert_array_equal(idx, np.asarray(i_ref))


def test_engine_never_recommends_seen_or_out_of_city():
    """Serving contract under the default config: factor-scored users never
    get a seen or out-of-city item; only cold users (no train interactions,
    so no meaningful factors AND nothing 'seen') may receive the flagged
    popularity slate, which is city-agnostic by design."""
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=10),
                        train=ds.train)
    train_mask = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    users = np.arange(ds.n_users)
    _, idx, flags = eng.recommend(users, return_flags=True)
    cold = ~train_mask.any(axis=1)
    np.testing.assert_array_equal(flags, cold)     # only cold users degrade
    for u in users:
        rec = idx[u][idx[u] >= 0]
        assert not train_mask[u, rec].any(), "seen item recommended"
        if not flags[u]:
            assert (ds.item_city[rec] == ds.user_city[u]).all(), "out-of-city rec"


# ----------------------------------------------------------- online refresh
def test_online_refresh_decreases_loss_on_streamed_checkins():
    ds, nbr, cfg, state = _world(epochs=4)
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                        train=ds.train, nbr=nbr, dmf_cfg=cfg)
    events = ds.test[: min(30, len(ds.test))]
    before = dmf.test_loss(eng.state, events)
    report = eng.ingest(events, OnlineConfig(batch_cap=128, steps=3))
    after = dmf.test_loss(eng.state, events)
    assert after < before, (before, after)
    assert report.n_events == len(events)
    # served view and seen-filter track the refresh
    np.testing.assert_allclose(
        np.asarray(eng.V), np.asarray(eng.state.P + eng.state.Q), atol=0)
    assert np.asarray(eng.seen)[events[:, 0], events[:, 1]].all()


def test_online_refresh_touches_only_neighbor_table_receivers():
    """Acceptance: a refresh writes U/Q only for affected users and P only
    for their neighbor-table receivers; everyone else is bit-identical."""
    ds, nbr, cfg, state = _world(epochs=2)
    U0 = np.asarray(state.U).copy()
    P0 = np.asarray(state.P).copy()
    Q0 = np.asarray(state.Q).copy()
    events = ds.test[:12]
    new_state, report = online_refresh(
        state, nbr, events, cfg, OnlineConfig(batch_cap=64, steps=2))
    affected = set(report.affected_users.tolist())
    touched = set(report.touched_users.tolist())
    assert affected == set(np.unique(events[:, 0]).tolist())
    assert affected <= touched
    # receivers come from the positive-weight neighbor table rows
    wall = np.asarray(nbr.wgt)
    iall = np.asarray(nbr.idx)
    expect_recv = set()
    for u in affected:
        expect_recv |= set(iall[u][wall[u] > 0].tolist())
    assert touched == affected | expect_recv
    dU = np.flatnonzero(np.abs(np.asarray(new_state.U) - U0).max(1) > 0)
    dQ = np.flatnonzero(np.abs(np.asarray(new_state.Q) - Q0).max((1, 2)) > 0)
    dP = np.flatnonzero(np.abs(np.asarray(new_state.P) - P0).max((1, 2)) > 0)
    assert set(dU.tolist()) <= affected
    assert set(dQ.tolist()) <= affected
    assert set(dP.tolist()) <= touched
    # untouched rows are bit-identical, not just close
    untouched = sorted(set(range(ds.n_users)) - touched)
    np.testing.assert_array_equal(np.asarray(new_state.P)[untouched],
                                  P0[untouched])


def test_online_refresh_empty_events_noop():
    ds, nbr, cfg, state = _world(epochs=1)
    new_state, report = online_refresh(
        state, nbr, np.empty((0, 2), np.int64), cfg)
    assert report.n_events == 0 and report.n_batches == 0
    np.testing.assert_array_equal(np.asarray(new_state.U), np.asarray(state.U))


def test_engine_ingest_duplicate_events_in_one_window():
    """The same (user, item) check-in repeated inside one refresh window:
    the refresh treats each occurrence as an event (order-free sum of
    per-rating SGD contributions — heavier pull, same receivers), the
    seen-filter sets once, and the engine never recommends the item again."""
    ds, nbr, cfg, state = _world(epochs=4)
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                        train=ds.train, nbr=nbr, dmf_cfg=cfg)
    base = ds.test[:4]
    events = np.concatenate([base, base, base[:2]])   # dups in one window
    report = eng.ingest(events, OnlineConfig(batch_cap=64, steps=1))
    assert report.n_events == len(events)
    np.testing.assert_array_equal(
        report.affected_users, np.unique(base[:, 0]))
    # served view stays consistent with the refreshed factors
    np.testing.assert_array_equal(
        np.asarray(eng.V), np.asarray(eng.state.P + eng.state.Q))
    assert np.asarray(eng.seen)[base[:, 0], base[:, 1]].all()
    _, recs = eng.recommend(np.unique(base[:, 0]))
    for row, u in zip(recs, np.unique(base[:, 0])):
        own = base[base[:, 0] == u, 1]
        assert not set(own.tolist()) & set(row[row >= 0].tolist())


def test_engine_ingest_empty_event_stream():
    ds, nbr, cfg, state = _world(epochs=2)
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                        train=ds.train, nbr=nbr, dmf_cfg=cfg)
    V0 = np.asarray(eng.V).copy()
    seen0 = np.asarray(eng.seen).copy()
    report = eng.ingest(np.empty((0, 2), np.int64))
    assert report.n_events == 0 and report.n_batches == 0
    assert len(report.affected_users) == 0
    np.testing.assert_array_equal(np.asarray(eng.V), V0)
    np.testing.assert_array_equal(np.asarray(eng.seen), seen0)
    vals, recs = eng.recommend(np.arange(8))          # still serves
    assert recs.shape == (8, 5)


def test_engine_ingest_user_in_truncated_bucket_keeps_index_intact():
    """Events for users whose city bucket is AT CAPACITY (city > cap,
    priority-truncated): ingest must refresh factors/seen only — the
    candidate index is immutable and must come out bit-identical, and
    recommendations stay inside the truncated bucket and unseen."""
    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=60, n_items=300, n_ratings=900, n_cities=2, seed=5))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=2)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=6,
                        beta=0.1, gamma=0.01, batch_size=64)
    res = dmf.fit(cfg, ds.train, nbr, epochs=3)
    index = index_from_dataset(ds, cap=128)           # both cities > 128
    assert index.n_truncated_buckets >= 1
    full_users = np.flatnonzero(~index.user_fits())
    assert len(full_users) > 0
    items0 = index.bucket_items.copy()
    sizes0 = index.bucket_size.copy()
    eng = ServingEngine(res.state, index, ServingConfig(microbatch=16, k=5),
                        train=ds.train, nbr=nbr, dmf_cfg=cfg)
    rng = np.random.default_rng(9)
    u = full_users[: 6]
    events = np.stack([u, rng.integers(0, ds.n_items, len(u))], 1)
    eng.ingest(events, OnlineConfig(batch_cap=64, steps=2))
    # the index is untouched — capacity pressure cannot corrupt it
    np.testing.assert_array_equal(eng.index.bucket_items, items0)
    np.testing.assert_array_equal(eng.index.bucket_size, sizes0)
    assert eng.index.cap == 128
    # and serving those users stays bucket-constrained and seen-filtered
    _, recs = eng.recommend(u)
    seen = np.asarray(eng.seen)
    for row, uu in zip(recs, u):
        bucket = set(items0[index.user_bucket[uu]].tolist()) - {-1}
        got = row[row >= 0]
        assert set(got.tolist()) <= bucket
        assert not seen[uu, got].any()


# --------------------------------------------- graceful degradation fallback
def _pop_slate(seen, k):
    counts = np.asarray(seen).astype(bool).sum(axis=0)
    items = np.argsort(-counts, kind="stable")[:k].astype(np.int32)
    vals = (counts[items] / max(int(counts.max()), 1)).astype(np.float32)
    return vals, items


def test_fallback_unknown_and_cold_users_get_popularity_slate():
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    cold = 7
    seen[cold] = False                       # a user with zero interactions
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                        seen=seen)
    normal = int(np.flatnonzero(seen.any(1))[0])
    users = np.asarray([cold, ds.n_users + 5, -1, normal])
    vals, idx, flags = eng.recommend(users, return_flags=True)
    np.testing.assert_array_equal(flags, [True, True, True, False])
    pv, pi = _pop_slate(seen, 5)
    for r in range(3):                       # flagged rows: popularity slate
        np.testing.assert_array_equal(idx[r], pi)
        np.testing.assert_array_equal(vals[r], pv)
    assert eng.stats.n_fallbacks == 3
    # the unflagged row is served from factors, identical to a clean batch
    v1, i1 = eng.recommend(np.asarray([normal]))
    np.testing.assert_array_equal(idx[3], i1[0])
    np.testing.assert_array_equal(vals[3], v1[0])


def test_fallback_empty_candidate_bucket():
    """A user whose home city has no POIs: the pruned path has nothing to
    score — fallback serves popularity; the dense (prune=False) path can
    still score full-J and must NOT flag such users."""
    ds, nbr, cfg, state = _world()
    item_city = np.where(np.arange(ds.n_items) % 2 == 0, 0, 2)  # city 1 empty
    user_city = np.zeros(ds.n_users, np.int64)
    user_city[3] = 1
    index = build_candidate_index(item_city, user_city, cap=128)
    assert (np.asarray(index.bucket_items[1]) == -1).all()
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                        train=ds.train)
    vals, idx, flags = eng.recommend(np.asarray([3, 0]), return_flags=True)
    np.testing.assert_array_equal(flags, [True, False])
    pv, pi = _pop_slate(np.asarray(eng.seen), 5)
    np.testing.assert_array_equal(idx[0], pi)
    dense = ServingEngine(state, index,
                          ServingConfig(microbatch=16, k=5, prune=False),
                          train=ds.train)
    _, _, dflags = dense.recommend(np.asarray([3, 0]), return_flags=True)
    np.testing.assert_array_equal(dflags, [False, False])


def test_fallback_disabled_serves_factors_unflagged():
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    cold = 7
    seen[cold] = False
    eng = ServingEngine(state, index,
                        ServingConfig(microbatch=16, k=5, fallback=False),
                        seen=seen)
    vals, idx, flags = eng.recommend(np.asarray([cold, 1]), return_flags=True)
    assert not flags.any() and eng.stats.n_fallbacks == 0
    # the cold row went through the factor path (whatever it scores), not
    # the popularity slate
    _, pi = _pop_slate(seen, 5)
    on = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                       seen=seen)
    ov, oi, oflags = on.recommend(np.asarray([cold, 1]), return_flags=True)
    np.testing.assert_array_equal(oflags, [True, False])
    np.testing.assert_array_equal(oi[0], pi)
    np.testing.assert_array_equal(oi[1], idx[1])   # unflagged rows identical


def test_ingest_clears_cold_status_and_tracks_popularity():
    ds, nbr, cfg, state = _world(epochs=4)
    index = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    cold = 7
    seen[cold] = False
    eng = ServingEngine(state, index, ServingConfig(microbatch=16, k=5),
                        seen=seen, nbr=nbr, dmf_cfg=cfg)
    assert eng._fallback_mask(np.asarray([cold]))[0]
    counts0 = eng._item_counts.copy()
    j = int(np.asarray(index.bucket_items[index.user_bucket[cold]]).max())
    eng.ingest(np.asarray([[cold, j]], np.int64))
    # first check-in: no longer cold, served from factors now
    _, _, flags = eng.recommend(np.asarray([cold]), return_flags=True)
    assert not flags[0]
    # popularity ledger tracked the stream
    assert eng._item_counts[j] == counts0[j] + 1
    assert eng._item_counts.sum() == counts0.sum() + 1


@pytest.mark.sharded
def test_fallback_sharded_matches_single_shard():
    """Unknown ids are clamped to row 0 BEFORE dispatch (an out-of-range id
    would route to no shard) — sharded fallback == single-shard fallback."""
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    seen = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    seen[7] = False
    users = np.asarray([7, ds.n_users + 3, 0, 11, -2, 5])
    e1 = ServingEngine(state, index, ServingConfig(microbatch=8, k=5),
                       seen=seen)
    e2 = ServingEngine(state, index,
                       ServingConfig(microbatch=8, k=5, n_shards=2),
                       seen=seen)
    v1, i1, f1 = e1.recommend(users, return_flags=True)
    v2, i2, f2 = e2.recommend(users, return_flags=True)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(v1, v2)
    assert e2.stats.n_fallbacks == int(f1.sum()) > 0


def test_online_refresh_padded_rows_are_exact_noops():
    """batch_cap >> n_events: padded conf=0/valid=0 rows must contribute
    exactly nothing (regularizer pulls masked too)."""
    ds, nbr, cfg, state = _world(epochs=1, seed=3)
    # host copies: the refresh step donates its U/P/Q buffers
    U0, P0, Q0 = (np.asarray(x).copy() for x in (state.U, state.P, state.Q))
    events = ds.test[:5]

    def run(cap, seed=11):
        st = dmf.DMFState(jnp.asarray(U0), jnp.asarray(P0), jnp.asarray(Q0))
        new, _ = online_refresh(st, nbr, events, cfg,
                                OnlineConfig(batch_cap=cap, steps=1),
                                rng=np.random.default_rng(seed))
        return new

    sa, sb = run(cap=32), run(cap=512)   # same negative draws, 16x more pad
    np.testing.assert_array_equal(np.asarray(sa.U), np.asarray(sb.U))
    np.testing.assert_array_equal(np.asarray(sa.P), np.asarray(sb.P))
    np.testing.assert_array_equal(np.asarray(sa.Q), np.asarray(sb.Q))


# ------------------------------------------------- stream order & latency
@pytest.mark.sharded
def test_serve_stream_ordered_and_unordered_pinned():
    """Sharded serve_stream has two documented yield orders: the default
    follows the shard drain (per dispatch: shard 0's batch, then shard 1's),
    ordered=True reassembles strict arrival order. Pin BOTH, and pin every
    slate bitwise against the single-shard engine. fallback=False engines:
    the raw stream never applies popularity overwrites."""
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    users = np.random.default_rng(2).integers(0, ds.n_users, 37)
    ref = ServingEngine(state, index,
                        ServingConfig(microbatch=8, k=5, fallback=False),
                        train=ds.train)
    v_ref, i_ref = ref.recommend(users)
    slate = {int(u): j for j, u in enumerate(users)}   # user -> a ref row

    eng = ServingEngine(state, index,
                        ServingConfig(microbatch=8, k=5, n_shards=2,
                                      fallback=False), train=ds.train)
    got = list(eng.serve_stream(users, ordered=True))
    np.testing.assert_array_equal(
        np.concatenate([u for u, _, _ in got]), users)
    np.testing.assert_array_equal(
        np.concatenate([v for _, v, _ in got]), v_ref)
    np.testing.assert_array_equal(
        np.concatenate([i for _, _, i in got]), i_ref)

    eng2 = ServingEngine(state, index,
                         ServingConfig(microbatch=8, k=5, n_shards=2,
                                       fallback=False), train=ds.train)
    rows = eng2._rows
    flat_u, flat_v, flat_i = [], [], []
    for u, v, i in eng2.serve_stream(users):
        flat_u.extend(int(x) for x in u)
        flat_v.append(v)
        flat_i.append(i)
    # the default order is exactly the shard-queue drain order
    queues = [[int(u) for u in users if u // rows == d] for d in range(2)]
    offs, expected = [0, 0], []
    while any(o < len(q) for o, q in zip(offs, queues)):
        for d in range(2):
            take = queues[d][offs[d]:offs[d] + 8]
            offs[d] += len(take)
            expected.extend(take)
    assert flat_u == expected
    flat_v, flat_i = np.concatenate(flat_v), np.concatenate(flat_i)
    for j, u in enumerate(flat_u):       # same user => identical slate
        np.testing.assert_array_equal(flat_v[j], v_ref[slate[u]])
        np.testing.assert_array_equal(flat_i[j], i_ref[slate[u]])


def test_latency_accounting_is_request_level():
    """EngineStats charges arrival->completion per REQUEST: a request in the
    w-th microbatch of a drain pays for every dispatch before it. The old
    per-dispatch numbers survive as the dispatch_* diagnostics."""
    ds, nbr, cfg, state = _world()
    index = index_from_dataset(ds)
    eng = ServingEngine(state, index, ServingConfig(microbatch=8, k=5),
                        train=ds.train)
    eng.recommend(np.arange(24) % ds.n_users)
    st = eng.stats
    assert st.n_requests == 24 and len(st.request_seconds) == 24
    assert st.n_dispatches == 3 and len(st.dispatch_seconds) == 3
    # the last microbatch's requests paid for all three dispatches
    assert max(st.request_seconds) >= sum(st.dispatch_seconds)
    assert st.request_seconds == sorted(st.request_seconds)
    p, d = st.latency_percentiles(), st.dispatch_latency_percentiles()
    assert set(p) == {"p50_ms", "p95_ms", "p99_ms"} == set(d)
    assert p["p99_ms"] >= d["p99_ms"]

    eng2 = ServingEngine(state, index, ServingConfig(microbatch=8, k=5),
                        train=ds.train)
    *_, dt = eng2.serve_microbatch(np.arange(5))
    assert eng2.stats.request_seconds == [dt] * 5
    assert eng2.stats.n_requests == 5 and eng2.stats.n_dispatches == 1
