import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.asarray(3)},
        "list": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)],
    }
    ckpt.save(tmp_path / "step_3", tree, step=3)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    out = ckpt.restore(tmp_path / "step_3", like)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(tmp_path) == 3


def test_raw_dtype_scalar_and_noncontiguous_roundtrip(tmp_path):
    """bf16 leaves numpy can't type natively save as flat bytes: 0-d
    scalars and non-contiguous views must both survive (the shaped
    .view(uint8) save rejected 0-d and strided arrays)."""
    base = jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6)
    tree = {
        "scalar": jnp.asarray(1.5, jnp.bfloat16),
        "strided": base[:, ::2],
        "full": base,
    }
    ckpt.save(tmp_path / "step_1", tree, step=1)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = ckpt.restore(tmp_path / "step_1", like)
    for k in tree:
        got = out[k]
        assert got.dtype == tree[k].dtype, k
        assert got.shape == tree[k].shape, k
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(tree[k], np.float32))


def test_dmf_state_is_a_checkpointable_pytree(tmp_path):
    """DMFState is a registered dataclass pytree: it saves/restores
    directly (the recovery layer relies on this), dtypes and shapes
    intact — including a padded learner axis as the sharded path pads."""
    from repro.core import dmf

    rng = np.random.default_rng(0)
    I, J, K, pad = 10, 7, 4, 16          # padded rows like shards do
    state = dmf.DMFState(
        U=jnp.asarray(rng.normal(size=(pad, K)), jnp.float32),
        P=jnp.asarray(rng.normal(size=(pad, J, K)), jnp.float32),
        Q=jnp.asarray(rng.normal(size=(pad, J, K)), jnp.float32),
    )
    leaves = jax.tree_util.tree_leaves(state)
    assert len(leaves) == 3, "DMFState must flatten to exactly U/P/Q"
    ckpt.save(tmp_path / "step_2", state, step=2)
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    out = ckpt.restore(tmp_path / "step_2", like)
    assert isinstance(out, dmf.DMFState)
    for name in ("U", "P", "Q"):
        a, b = getattr(state, name), getattr(out, name)
        assert b.dtype == a.dtype and b.shape == a.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(tmp_path) == 2
    # unused padded tail really was preserved bit-for-bit, not re-zeroed
    np.testing.assert_array_equal(np.asarray(out.U)[I:],
                                  np.asarray(state.U)[I:])


def test_restore_into_model_params(tmp_path):
    from repro.configs import registry
    from repro.models import config as mc, transformer
    cfg = mc.reduced(registry.get_config("qwen1.5-4b"))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(tmp_path / "step_1", params, step=1)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    back = ckpt.restore(tmp_path / "step_1", zeros)
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(back)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
