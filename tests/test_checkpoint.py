import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.asarray(3)},
        "list": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)],
    }
    ckpt.save(tmp_path / "step_3", tree, step=3)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    out = ckpt.restore(tmp_path / "step_3", like)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(tmp_path) == 3


def test_restore_into_model_params(tmp_path):
    from repro.configs import registry
    from repro.models import config as mc, transformer
    cfg = mc.reduced(registry.get_config("qwen1.5-4b"))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(tmp_path / "step_1", params, step=1)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    back = ckpt.restore(tmp_path / "step_1", zeros)
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(back)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
