import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.asarray(3)},
        "list": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)],
    }
    ckpt.save(tmp_path / "step_3", tree, step=3)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    out = ckpt.restore(tmp_path / "step_3", like)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(tmp_path) == 3


def test_raw_dtype_scalar_and_noncontiguous_roundtrip(tmp_path):
    """bf16 leaves numpy can't type natively save as flat bytes: 0-d
    scalars and non-contiguous views must both survive (the shaped
    .view(uint8) save rejected 0-d and strided arrays)."""
    base = jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6)
    tree = {
        "scalar": jnp.asarray(1.5, jnp.bfloat16),
        "strided": base[:, ::2],
        "full": base,
    }
    ckpt.save(tmp_path / "step_1", tree, step=1)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = ckpt.restore(tmp_path / "step_1", like)
    for k in tree:
        got = out[k]
        assert got.dtype == tree[k].dtype, k
        assert got.shape == tree[k].shape, k
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), np.asarray(tree[k], np.float32))


def test_dmf_state_is_a_checkpointable_pytree(tmp_path):
    """DMFState is a registered dataclass pytree: it saves/restores
    directly (the recovery layer relies on this), dtypes and shapes
    intact — including a padded learner axis as the sharded path pads."""
    from repro.core import dmf

    rng = np.random.default_rng(0)
    I, J, K, pad = 10, 7, 4, 16          # padded rows like shards do
    state = dmf.DMFState(
        U=jnp.asarray(rng.normal(size=(pad, K)), jnp.float32),
        P=jnp.asarray(rng.normal(size=(pad, J, K)), jnp.float32),
        Q=jnp.asarray(rng.normal(size=(pad, J, K)), jnp.float32),
    )
    leaves = jax.tree_util.tree_leaves(state)
    assert len(leaves) == 3, "DMFState must flatten to exactly U/P/Q"
    ckpt.save(tmp_path / "step_2", state, step=2)
    like = jax.tree_util.tree_map(jnp.zeros_like, state)
    out = ckpt.restore(tmp_path / "step_2", like)
    assert isinstance(out, dmf.DMFState)
    for name in ("U", "P", "Q"):
        a, b = getattr(state, name), getattr(out, name)
        assert b.dtype == a.dtype and b.shape == a.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.latest_step(tmp_path) == 2
    # unused padded tail really was preserved bit-for-bit, not re-zeroed
    np.testing.assert_array_equal(np.asarray(out.U)[I:],
                                  np.asarray(state.U)[I:])


def test_restore_detects_corruption(tmp_path):
    """A flipped byte on disk must surface as CorruptCheckpointError, not
    as silently-wrong factors (ISSUE 9 integrity satellite)."""
    tree = {"a": jnp.arange(16, dtype=jnp.float32),
            "b": jnp.ones((3, 2), jnp.float32)}
    path = tmp_path / "step_1"
    ckpt.save(path, tree, step=1)
    assert ckpt.verify(path) is True
    f = path / "a.npy"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    assert ckpt.verify(path) is False
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(path, like)
    # a missing leaf is corruption too
    f.unlink()
    assert ckpt.verify(path) is False
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(path, like)


def test_verify_passes_prechecksum_manifests(tmp_path):
    """Manifests written before checksums existed (no sha256 key) must
    keep restoring — integrity is opt-in by manifest version."""
    import json
    tree = {"a": jnp.arange(4, dtype=jnp.float32)}
    path = tmp_path / "step_1"
    ckpt.save(path, tree, step=1)
    mf = path / "manifest.json"
    manifest = json.loads(mf.read_text())
    for info in manifest["leaves"].values():
        del info["sha256"]
    mf.write_text(json.dumps(manifest))
    assert ckpt.verify(path) is True
    out = ckpt.restore(path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_steps_lists_ascending(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (7, 1, 12):
        ckpt.save(tmp_path / f"step_{s}", tree, step=s)
    assert ckpt.steps(tmp_path) == [1, 7, 12]
    assert ckpt.latest_step(tmp_path) == 12
    assert ckpt.steps(tmp_path / "nowhere") == []


def test_resume_falls_back_to_newest_valid_snapshot(tmp_path):
    """fit(resume_from=<root>) with a corrupted latest snapshot must warn
    and resume from the newest intact one — and still reproduce the
    uninterrupted run bit-for-bit from there."""
    from repro.core import dmf, graph
    from repro.data import synthetic_poi
    from repro.robustness import recovery

    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=60, n_items=40, n_ratings=400, n_cities=3, seed=0))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=4,
                        batch_size=64, beta=0.1, gamma=0.01)
    full = dmf.fit(cfg, ds.train, nbr, epochs=4,
                   checkpoint_dir=tmp_path, checkpoint_every=1)
    assert ckpt.steps(tmp_path) == [1, 2, 3, 4]
    # corrupt the two newest snapshots: fall back to step_2
    for s in (3, 4):
        leaf = sorted((tmp_path / f"step_{s}").glob("*.npy"))[0]
        raw = bytearray(leaf.read_bytes())
        raw[len(raw) // 2] ^= 0xFF
        leaf.write_bytes(bytes(raw))
    with pytest.warns(RuntimeWarning, match="falling back to step_2"):
        assert recovery.resolve_step_dir(tmp_path).name == "step_2"
    with pytest.warns(RuntimeWarning):
        resumed = dmf.fit(cfg, ds.train, nbr, epochs=4,
                          resume_from=tmp_path)
    assert resumed.train_losses == full.train_losses
    for n in ("U", "P", "Q"):
        np.testing.assert_array_equal(
            np.asarray(getattr(full.state, n)),
            np.asarray(getattr(resumed.state, n)), err_msg=n)
    # an explicitly named corrupt step dir still fails loudly
    with pytest.raises(ckpt.CorruptCheckpointError):
        dmf.fit(cfg, ds.train, nbr, epochs=4,
                resume_from=tmp_path / "step_4")
    # every snapshot corrupt -> CorruptCheckpointError, not silent restart
    for s in (1, 2):
        leaf = sorted((tmp_path / f"step_{s}").glob("*.npy"))[0]
        raw = bytearray(leaf.read_bytes())
        raw[0] ^= 0xFF
        leaf.write_bytes(bytes(raw))
    with pytest.raises(ckpt.CorruptCheckpointError):
        recovery.resolve_step_dir(tmp_path)


def test_restore_into_model_params(tmp_path):
    from repro.configs import registry
    from repro.models import config as mc, transformer
    cfg = mc.reduced(registry.get_config("qwen1.5-4b"))
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(tmp_path / "step_1", params, step=1)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    back = ckpt.restore(tmp_path / "step_1", zeros)
    a = jax.tree_util.tree_leaves(params)[0]
    b = jax.tree_util.tree_leaves(back)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
