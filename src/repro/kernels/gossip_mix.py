"""Pallas TPU kernel: random-walk propagation mixing  Y = M @ X.

Alg. 1 lines 13-15 vectorized: M (I, I) is the walk-propagation matrix
(graph.walk_propagation_matrix), X (I, F) the flattened per-learner global
state (or a batch of gradient messages). This is the MXU workload of the
paper's communication step — a classic tiled matmul with an accumulator
tile resident in VMEM and a K-loop over I.

Grid: (I/bm, F/bn, I/bk); the (bm, bn) f32 accumulator lives in the output
block (revisited across the k dimension — Pallas guarantees grid-minor
revisiting order, k is the innermost axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mix_kernel(m_ref, x_ref, y_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        m_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def gossip_mix_kernel_call(M, X, *, block_m: int = 128, block_n: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """M: (I, I) f32, X: (I, F) f32 -> (I, F). Dims must be multiples of the
    MXU-aligned block sizes (the ops.py wrapper pads)."""
    I, I2 = M.shape
    _, F = X.shape
    assert I == I2 and I % block_m == 0 and I % block_k == 0 and F % block_n == 0
    grid = (I // block_m, F // block_n, I // block_k)
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((I, F), jnp.float32),
        interpret=interpret,
    )(M, X)
