"""Pallas TPU kernel: fused per-row L2 clip + Gaussian noise for DP-SGD
gradient messages.

The differential-privacy mechanism on the gradient-exchange hot path
(privacy/mechanism.py): every global-factor gradient message gp leaving a
learner is L2-clipped to norm ≤ C and perturbed with N(0, (σC)²) noise
*before* it is scattered to (or routed across shards toward) any receiver.
Unfused this is three elementwise dispatches over the (B, K) message block
— norm reduction, scale multiply, noise add — each a full VMEM round-trip;
here it is one pass: read gp, reduce the row norm, generate the noise
in-register from a counter-based PRNG, write the noised clipped message.

Counter-based noise (the decentralization requirement): the Gaussian draw
for message-row ``rid``, column ``k`` is a pure function of
``(seed, rid, k)`` — no stateful PRNG, no carried key. The learner-sharded
path routes the same minibatch rows to different shards depending on the
mesh width, so noise keyed by *batch position on a shard* would change
with the shard count; keyed by the row's global stream id it is
shard-count-invariant by construction (tests/test_privacy.py). Stream
layout: counters ``rid*2*KMAX + 2k`` / ``+1`` feed a SplitMix-style 32-bit
hash, two uniforms Box-Muller into one standard normal. ``KMAX = 256``
caps the factor dim (same bound as the other kernels' VMEM-resident K).

Block layout: (Bt, K) tiles of gp in VMEM; rid as a (Bt, 1) int32 column;
seed as a (1, 1) int32 block (replicated to every grid step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

KMAX = 256                 # max factor dim the counter layout supports
_STRIDE = 2 * KMAX         # uint32 counters per message row

# numpy scalars, NOT jnp arrays: jnp constants at module scope become traced
# captures inside the Pallas kernel body (pallas_call rejects them)
_M1 = np.uint32(0x21F0AAAD)    # SplitMix32/lowbias32 mixing constants
_M2 = np.uint32(0x735A2D97)
_GOLDEN = np.uint32(0x9E3779B9)


def _mix32(x):
    """Low-bias 32-bit avalanche hash (uint32 in, uint32 out)."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 15)
    return x


def gauss_counter(seed, rid, n_cols: int):
    """Standard-normal draws as a pure function of (seed, rid, column).

    seed: uint32/int32 scalar; rid: (B, 1) int32 global message-row ids.
    Returns (B, n_cols) f32 ~ N(0, 1): counters 2·(rid·KMAX+k) and +1 are
    hashed to two uniforms, Box-Muller'd to one normal. The SINGLE
    definition of the DP noise stream — the Pallas kernel body and the
    `ref.dp_clip_noise_ref` oracle both call it, so by-spec (not by-luck)
    they perturb with bit-identical noise.
    """
    B = rid.shape[0]
    s = _mix32(jnp.asarray(seed).astype(jnp.uint32))
    col = jax.lax.broadcasted_iota(jnp.uint32, (B, n_cols), 1)
    # the 23 low rid bits index the 512-counter block; the high bits fold
    # into a per-row stream key, so the uint32 counter never wraps — rows
    # 2^23 apart draw from distinct streams, not recycled noise (epochs
    # beyond 8.4M message rows would otherwise reuse draws, and reused
    # noise cancels in update differences)
    rid32 = rid.astype(jnp.uint32)
    s_row = _mix32(s ^ ((rid32 >> np.uint32(23)) * _GOLDEN + np.uint32(1)))
    base = ((rid32 & np.uint32(0x7FFFFF)) * np.uint32(_STRIDE)
            + col * np.uint32(2))
    h1 = _mix32(base ^ s_row)
    h2 = _mix32((base + np.uint32(1)) ^ (s_row * _GOLDEN))
    # 24 high bits -> (0, 1] so log() is finite; [0, 1) for the angle
    u1 = ((h1 >> np.uint32(8)) + np.uint32(1)).astype(jnp.float32) * (2.0**-24)
    u2 = (h2 >> np.uint32(8)).astype(jnp.float32) * (2.0**-24)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos((2.0 * jnp.pi) * u2)


def padded_noise(seed, rid, n_real: int, n_cols: int):
    """(B, n_cols) noise block with draws only for the ``n_real`` live
    columns, zero on the K-padding — the padded lanes are sliced off by the
    wrappers anyway, and the transcendentals (log/cos) dominate the
    mechanism's cost, so generating 128-lane noise for a K=10 factor would
    be ~13x wasted work per batch (felt acutely in interpret mode)."""
    z = gauss_counter(seed, rid, n_real)
    if n_cols > n_real:
        z = jnp.pad(z, ((0, 0), (0, n_cols - n_real)))
    return z


def _dp_clip_noise_kernel(g_ref, rid_ref, seed_ref, out_ref,
                          *, clip, noise_std, n_real, n_cols):
    g = g_ref[...]                                       # (Bt, K)
    nrm = jnp.sqrt(jnp.sum(g * g, axis=-1, keepdims=True))
    scale = jnp.minimum(1.0, clip / nrm)                 # inf/0 -> 1 (no-op)
    out = g * scale
    if noise_std > 0.0:
        z = padded_noise(seed_ref[0, 0], rid_ref[...], n_real, n_cols)
        out = out + noise_std * z
    out_ref[...] = out


def dp_clip_noise_kernel_call(g, rid, seed, *, clip: float, noise_std: float,
                              n_real: int | None = None, block_b: int = 256,
                              interpret: bool = True):
    """g: (B, K) f32 messages (K lane-aligned by the wrapper); rid: (B,)
    int32 global row ids; seed: (1, 1) int32; ``n_real``: live columns
    (noise is only generated for those — the rest is K-padding the wrapper
    slices off). Padded K columns must be zero (they then contribute
    nothing to the row norm).
    """
    B, K = g.shape
    assert B % block_b == 0, (B, block_b)
    assert K <= KMAX, (K, KMAX)
    n_real = K if n_real is None else n_real
    rid2 = rid.reshape(B, 1)
    grid = (B // block_b,)
    bspec_mat = pl.BlockSpec((block_b, K), lambda i: (i, 0))
    bspec_col = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    bspec_seed = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kern = functools.partial(
        _dp_clip_noise_kernel, clip=clip, noise_std=noise_std, n_real=n_real,
        n_cols=K)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[bspec_mat, bspec_col, bspec_seed],
        out_specs=bspec_mat,
        out_shape=jax.ShapeDtypeStruct((B, K), g.dtype),
        interpret=interpret,
    )(g, rid2, seed)
