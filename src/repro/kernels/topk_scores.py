"""Pallas TPU kernel: serving-time recommendation — masked scores + top-k.

Computes scores = U @ V^T with training items masked to -inf, maintaining a
per-user running top-k across item tiles *inside the kernel*, so the (I, J)
score matrix never hits HBM (the paper's J is small, but a production
recommender has J in the millions — this is the memory-roofline win).

Grid: (I/bi, J/bj) with j innermost; carry (bi, k) value/index buffers in
the output blocks (revisited across j). Top-k per tile via k rounds of
max-extract (k ≤ 16; the paper evaluates k ∈ {5, 10}).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _merge_tile_topk(scores, col, vals, idxs, k):
    """Merge a (bi, bj) tile of candidate scores/indices into the running
    (bi, k) top-k buffers (descending order), via k rounds of extract-max.
    Shared by the shared-V and per-user-V kernels."""
    bi, bj = scores.shape
    for slot in range(k):
        cur_max = jnp.max(scores, axis=-1, keepdims=True)          # (bi,1)
        cur_arg = jnp.argmax(scores, axis=-1)                      # (bi,)
        cur_idx = jnp.take_along_axis(col, cur_arg[:, None], axis=1)  # (bi,1)
        # compare against current slot; if better, shift-insert
        slot_val = vals[:, slot : slot + 1]
        better = cur_max[:, 0] > slot_val[:, 0]
        # insert by swapping: new slot value is max(slot, cur); displaced
        # value continues to compete for later slots
        new_slot_val = jnp.where(better, cur_max[:, 0], slot_val[:, 0])
        new_slot_idx = jnp.where(better, cur_idx[:, 0], idxs[:, slot])
        displaced_val = jnp.where(better, slot_val[:, 0], cur_max[:, 0])
        displaced_idx = jnp.where(better, idxs[:, slot], cur_idx[:, 0])
        vals = vals.at[:, slot].set(new_slot_val)
        idxs = idxs.at[:, slot].set(new_slot_idx)
        # remove the consumed max from the tile and reinject the displaced
        # candidate so it can fill later slots
        consumed = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1) == cur_arg[:, None]
        scores = jnp.where(consumed, displaced_val[:, None], scores)
        col = jnp.where(consumed, displaced_idx[:, None], col)
    return vals, idxs


def _topk_kernel(u_ref, v_ref, mask_ref, vals_ref, idx_ref, *, k, block_j):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    scores = jnp.dot(u_ref[...], v_ref[...].T, preferred_element_type=jnp.float32)
    scores = jnp.where(mask_ref[...] != 0, NEG_INF, scores)   # (bi, bj)
    bi, bj = scores.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1) + j * block_j
    vals, idxs = _merge_tile_topk(scores, col, vals_ref[...], idx_ref[...], k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def _topk_peruser_kernel(u_ref, v_ref, mask_ref, vals_ref, idx_ref, *, k, block_j):
    """DMF serving variant: every user has his *own* item factors (v^i =
    p^i + q^i), so V is laid out (I, K, J) and score is a per-user
    contraction over K (VPU reduce over the sublane dim), not one shared
    matmul. The (I, J) score matrix still never leaves VMEM."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    u = u_ref[...]                                            # (bi, K)
    v = v_ref[...]                                            # (bi, K, bj)
    scores = jnp.sum(u[:, :, None] * v, axis=1)               # (bi, bj)
    scores = jnp.where(mask_ref[...] != 0, NEG_INF, scores)
    bi, bj = scores.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1) + j * block_j
    vals, idxs = _merge_tile_topk(scores, col, vals_ref[...], idx_ref[...], k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def topk_scores_kernel_call(U, V, train_mask, k: int, *, block_i: int = 128,
                            block_j: int = 256, interpret: bool = True):
    """U: (I, K), V: (J, K), train_mask: (I, J) int8/bool. Returns
    (vals (I, k), idx (I, k)) — per-user top-k unseen items."""
    I, K = U.shape
    J = V.shape[0]
    assert I % block_i == 0 and J % block_j == 0, (I, J, block_i, block_j)
    grid = (I // block_i, J // block_j)
    kern = functools.partial(_topk_kernel, k=k, block_j=block_j)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, K), lambda i, j: (j, 0)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((I, k), jnp.float32),
            jax.ShapeDtypeStruct((I, k), jnp.int32),
        ],
        interpret=interpret,
    )(U, V, train_mask.astype(jnp.int8))
    return vals, idx


def topk_scores_peruser_kernel_call(U, Vt, train_mask, k: int, *,
                                    block_i: int = 128, block_j: int = 128,
                                    interpret: bool = True):
    """U: (I, K), Vt: (I, K, J) per-user item factors (K-major so the lane
    dim is J), train_mask: (I, J). Returns (vals (I, k), idx (I, k))."""
    I, K = U.shape
    J = Vt.shape[2]
    assert Vt.shape[:2] == (I, K), (Vt.shape, U.shape)
    assert I % block_i == 0 and J % block_j == 0, (I, J, block_i, block_j)
    grid = (I // block_i, J // block_j)
    kern = functools.partial(_topk_peruser_kernel, k=k, block_j=block_j)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, K, block_j), lambda i, j: (i, 0, j)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((I, k), jnp.float32),
            jax.ShapeDtypeStruct((I, k), jnp.int32),
        ],
        interpret=interpret,
    )(U, Vt, train_mask.astype(jnp.int8))
    return vals, idx
