"""jit'd public wrappers around the Pallas kernels (padding + dispatch).

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for correctness); on TPU pass
``interpret=False`` for the compiled path. All wrappers pad to MXU/lane
alignment (128) and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dmf_update, gossip_mix, topk_scores

LANE = 128


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "gamma", "interpret"))
def dmf_grads(u, p, q, r, conf, *, alpha: float, beta: float, gamma: float,
              interpret: bool = True):
    """Fused Eqs. 9-11. u/p/q: (B, K); r/conf: (B,)."""
    B, K = u.shape
    block_b = 256 if B % 256 == 0 else (B if B <= 256 else None)
    if block_b is None:
        # pad batch to a multiple of 256; padded rows have conf=0 (no-op grads
        # except the regularizer on zero factors = 0)
        u, p, q = (_pad_to(x, 256, 0) for x in (u, p, q))
        r = _pad_to(r, 256, 0)
        conf = _pad_to(conf, 256, 0)
        block_b = 256
    Bp = u.shape[0]
    uP, pP, qP = (_pad_to(x, LANE, 1) for x in (u, p, q))
    gu, gp, gq = dmf_update.dmf_grads_kernel_call(
        uP, pP, qP, r, conf, alpha=alpha, beta=beta, gamma=gamma,
        block_b=block_b, interpret=interpret,
    )
    return gu[:B, :K], gp[:B, :K], gq[:B, :K]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_op(M, X, *, interpret: bool = True):
    """Y = M @ X with MXU tiling. M: (I, I); X: (I, F)."""
    I, F = X.shape
    Mp = _pad_to(_pad_to(M.astype(jnp.float32), LANE, 0), LANE, 1)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), LANE, 0), LANE, 1)
    Y = gossip_mix.gossip_mix_kernel_call(Mp, Xp, interpret=interpret)
    return Y[:I, :F]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def recommend_topk(U, V, train_mask, k: int, *, interpret: bool = True):
    """Masked top-k recommendation; never materializes (I, J) in HBM."""
    I, K = U.shape
    J = V.shape[0]
    Up = _pad_to(_pad_to(U.astype(jnp.float32), LANE, 0), LANE, 1)
    Vp = _pad_to(_pad_to(V.astype(jnp.float32), 256, 0), LANE, 1)
    # padded users: mask=0 rows are fine (garbage rows sliced off);
    # padded items must be masked out
    mp = _pad_to(_pad_to(train_mask.astype(jnp.int8), 256, 1), LANE, 0)
    if mp.shape[1] > J:
        mp = mp.at[:, J:].set(1)
    vals, idx = topk_scores.topk_scores_kernel_call(
        Up, Vp, mp, k, interpret=interpret,
    )
    return vals[:I], idx[:I]
