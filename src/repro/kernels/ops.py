"""jit'd public wrappers around the Pallas kernels (padding + dispatch).

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python for correctness); on TPU pass
``interpret=False`` for the compiled path. All wrappers pad to MXU/lane
alignment (128) and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dmf_update, dp_noise, gossip_mix, topk_scores
from repro.kernels import serve_topk as serve_topk_lib

LANE = 128


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "gamma", "interpret"))
def dmf_grads(u, p, q, r, conf, *, alpha: float, beta: float, gamma: float,
              interpret: bool = True):
    """Fused Eqs. 9-11. u/p/q: (B, K); r/conf: (B,)."""
    B, K = u.shape
    block_b = 256 if B % 256 == 0 else (B if B <= 256 else None)
    if block_b is None:
        # pad batch to a multiple of 256; padded rows have conf=0 (no-op grads
        # except the regularizer on zero factors = 0)
        u, p, q = (_pad_to(x, 256, 0) for x in (u, p, q))
        r = _pad_to(r, 256, 0)
        conf = _pad_to(conf, 256, 0)
        block_b = 256
    Bp = u.shape[0]
    uP, pP, qP = (_pad_to(x, LANE, 1) for x in (u, p, q))
    gu, gp, gq = dmf_update.dmf_grads_kernel_call(
        uP, pP, qP, r, conf, alpha=alpha, beta=beta, gamma=gamma,
        block_b=block_b, interpret=interpret,
    )
    return gu[:B, :K], gp[:B, :K], gq[:B, :K]


@functools.partial(jax.jit, static_argnames=("theta", "alpha", "beta", "gamma",
                                             "interpret"))
def dmf_fused_step(u, p, q, r, conf, *, theta: float, alpha: float, beta: float,
                   gamma: float, interpret: bool = True):
    """Fused Alg. 1 step: Eqs. 9-11 grads, lr-scaled u/q deltas, raw p
    message, batch loss — one kernel pass. u/p/q: (B, K); r/conf: (B,).
    Returns (du, gp, dq, loss_scalar)."""
    B, K = u.shape
    block_b = 256 if B % 256 == 0 else (B if B <= 256 else None)
    if block_b is None:
        # pad batch to a multiple of 256; padded rows carry conf=0 and zero
        # factors, so grads, deltas and loss contributions are all exactly 0
        u, p, q = (_pad_to(x, 256, 0) for x in (u, p, q))
        r = _pad_to(r, 256, 0)
        conf = _pad_to(conf, 256, 0)
        block_b = 256
    uP, pP, qP = (_pad_to(x, LANE, 1) for x in (u, p, q))
    du, gp, dq, loss = dmf_update.dmf_fused_step_kernel_call(
        uP, pP, qP, r, conf, theta=theta, alpha=alpha, beta=beta, gamma=gamma,
        block_b=block_b, interpret=interpret,
    )
    return du[:B, :K], gp[:B, :K], dq[:B, :K], loss[0, 0]


@functools.partial(jax.jit, static_argnames=("theta", "alpha", "beta", "gamma",
                                             "clip", "interpret"))
def dmf_fused_step_dp(u, p, q, r, conf, z, *, theta: float, alpha: float,
                      beta: float, gamma: float, clip: float,
                      interpret: bool = True):
    """`dmf_fused_step` with the DP mechanism folded into the SAME kernel
    pass: the returned gp message is already clipped to ``clip`` and
    perturbed with ``z`` — the batch's pre-scaled σC noise block from the
    counter-keyed stream (generated once per epoch, see core/dmf.py). The
    DP training hot path keeps the un-noised path's dispatch count — one
    fused kernel per minibatch."""
    B, K = u.shape
    block_b = 256 if B % 256 == 0 else (B if B <= 256 else None)
    if block_b is None:
        # padded rows carry conf=0 + zero factors + zero noise:
        # grads/deltas/loss are 0 and the clip scale is 1
        u, p, q, z = (_pad_to(x, 256, 0) for x in (u, p, q, z))
        r = _pad_to(r, 256, 0)
        conf = _pad_to(conf, 256, 0)
        block_b = 256
    uP, pP, qP, zP = (_pad_to(x, LANE, 1) for x in (u, p, q, z))
    du, gp, dq, loss = dmf_update.dmf_fused_step_dp_kernel_call(
        uP, pP, qP, r, conf, zP, theta=theta, alpha=alpha, beta=beta,
        gamma=gamma, clip=clip, block_b=block_b, interpret=interpret,
    )
    return du[:B, :K], gp[:B, :K], dq[:B, :K], loss[0, 0]


@functools.partial(jax.jit, static_argnames=("clip", "noise_std", "interpret"))
def dp_clip_noise(g, rid, seed, *, clip: float, noise_std: float,
                  interpret: bool = True):
    """Fused DP mechanism for gradient messages: per-row L2 clip to
    ``clip`` + additive N(0, noise_std²) counter-keyed Gaussian noise, one
    kernel pass (kernels/dp_noise.py). g: (B, K) f32; rid: (B,) int32
    global message-row ids; seed: int32 scalar (traced — changing the
    per-epoch seed does not recompile). ``clip=inf`` scales by exactly 1.0
    and ``noise_std=0`` compiles the noise path out entirely, so the
    disabled mechanism is bit-exact identity."""
    B, K = g.shape
    block_b = 256 if B % 256 == 0 else (B if B <= 256 else None)
    if block_b is None:
        # padded rows carry g=0 (clip scale 1) and their noise is sliced off
        g = _pad_to(g, 256, 0)
        rid = _pad_to(rid, 256, 0)
        block_b = 256
    gP = _pad_to(g, LANE, 1)      # zero K-pad: row norms unchanged
    seed2 = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    out = dp_noise.dp_clip_noise_kernel_call(
        gP, rid.astype(jnp.int32), seed2, clip=clip, noise_std=noise_std,
        n_real=K, block_b=block_b, interpret=interpret,
    )
    return out[:B, :K]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gossip_mix_op(M, X, *, interpret: bool = True):
    """Y = M @ X with MXU tiling. M: (I, I); X: (I, F)."""
    I, F = X.shape
    Mp = _pad_to(_pad_to(M.astype(jnp.float32), LANE, 0), LANE, 1)
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), LANE, 0), LANE, 1)
    Y = gossip_mix.gossip_mix_kernel_call(Mp, Xp, interpret=interpret)
    return Y[:I, :F]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def recommend_topk(U, V, train_mask, k: int, *, interpret: bool = True):
    """Masked top-k recommendation; never materializes (I, J) in HBM."""
    I, K = U.shape
    J = V.shape[0]
    Up = _pad_to(_pad_to(U.astype(jnp.float32), LANE, 0), LANE, 1)
    Vp = _pad_to(_pad_to(V.astype(jnp.float32), 256, 0), LANE, 1)
    # padded users: mask=0 rows are fine (garbage rows sliced off);
    # padded items must be masked out
    mp = _pad_to(_pad_to(train_mask.astype(jnp.int8), 256, 1), LANE, 0)
    if mp.shape[1] > J:
        mp = mp.at[:, J:].set(1)
    vals, idx = topk_scores.topk_scores_kernel_call(
        Up, Vp, mp, k, interpret=interpret,
    )
    return vals[:I], idx[:I]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def serve_topk(U, V, cand, seen, k: int, *, interpret: bool = True):
    """Geo-pruned batched serving: per-request candidate gather + scores +
    running top-k fused (kernels/serve_topk.py). U: (R, K); V: (R, J, K)
    per-request item factors; cand: (R, Cw) int32 candidate item ids, -1
    padded; seen: (R, J) bool/int8 seen-filter. Returns (vals, idx) (R, k),
    idx = global item ids, -1 in unfilled slots.

    *Compute* per request is O(Cw·K), not O(J·K) — the grid tiles the
    candidate dim. Memory staging on this interpret-mode container is still
    O(J·K) per request (the user's full item slab is handed to the kernel
    as the gather source); the compiled-TPU design keeps V in HBM and DMAs
    only the candidate rows, making the traffic O(Cw·K) too. Padding: R to
    the request block, K to the f32 sublane quantum, J to the lane (never
    gathered: cand ids < J), Cw to the candidate block with -1 (masked
    inside the kernel)."""
    R, K = U.shape
    J = V.shape[1]
    BI, BJ = 8, 128
    Up = _pad_to(_pad_to(U.astype(jnp.float32), BI, 0), 8, 1)
    Vt = jnp.transpose(V.astype(jnp.float32), (0, 2, 1))   # (R, K, J)
    Vt = _pad_to(_pad_to(_pad_to(Vt, BI, 0), 8, 1), LANE, 2)
    sp = _pad_to(_pad_to(seen.astype(jnp.int8), LANE, 1), BI, 0)
    cp = jnp.pad(cand.astype(jnp.int32),
                 [(0, (-R) % BI), (0, (-cand.shape[1]) % BJ)],
                 constant_values=-1)
    vals, idx = serve_topk_lib.serve_topk_kernel_call(
        Up, Vt, sp, cp, k, block_i=BI, block_j=BJ, interpret=interpret,
    )
    return vals[:R], idx[:R]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def serve_topk_window(U, Vw, cand, seen_w, k: int, *, interpret: bool = True):
    """Tiled geo-pruned serving over pre-gathered candidate windows — the
    million-scale replacement for `serve_topk`'s per-request full item slab.
    U: (R, K); Vw: (R, Cw, K) the candidate windows' item factors (row r is
    the user's v^i at exactly the `cand[r]` ids, any values in padded
    slots); cand: (R, Cw) int32 candidate ids, -1 padded; seen_w: (R, Cw)
    bool/int8 seen bits aligned to `cand`. Returns (vals, idx) (R, k),
    idx = global item ids, -1 in unfilled slots.

    Both compute AND staging are O(Cw·K) per request: the kernel's grid
    streams (8, K, 128) window tiles, never touching J, so the factor
    source (the (I, cap, K) store slab, or V rows) stays HBM-resident.
    Bitwise identical to `serve_topk` on the same candidates: identical
    block sizes (8, 128), K zero-padding, K-major contraction and
    running-top-k carry — pinned by tests on tie-heavy inputs."""
    R, K = U.shape
    Cw = cand.shape[1]
    BI, BJ = 8, 128
    Up = _pad_to(_pad_to(U.astype(jnp.float32), BI, 0), 8, 1)
    Vt = jnp.transpose(Vw.astype(jnp.float32), (0, 2, 1))   # (R, K, Cw)
    Vt = _pad_to(_pad_to(_pad_to(Vt, BI, 0), 8, 1), LANE, 2)
    sp = _pad_to(_pad_to(seen_w.astype(jnp.int8), LANE, 1), BI, 0)
    cp = jnp.pad(cand.astype(jnp.int32),
                 [(0, (-R) % BI), (0, (-Cw) % BJ)],
                 constant_values=-1)
    vals, idx = serve_topk_lib.serve_topk_window_kernel_call(
        Up, Vt, sp, cp, k, block_i=BI, block_j=BJ, interpret=interpret,
    )
    return vals[:R], idx[:R]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def serve_topk_window_quant(U, Vq, scale, cand, seen_w, k: int, *,
                            interpret: bool = True):
    """Quantized `serve_topk_window`: candidate windows as int8 codes with a
    per-request dequant scale (codes·scale ≈ v), or bf16 factors with
    scale = 1.0. Vq: (R, Cw, K) int8/bf16; scale: (R,) f32. Dequantization
    runs in-VMEM per tile; everything downstream (contraction, masking,
    top-k carry, tie contract) matches the fp32 window kernel on the
    dequantized values bitwise."""
    R, K = U.shape
    Cw = cand.shape[1]
    BI, BJ = 8, 128
    Up = _pad_to(_pad_to(U.astype(jnp.float32), BI, 0), 8, 1)
    Vt = jnp.transpose(Vq, (0, 2, 1))                       # (R, K, Cw)
    Vt = _pad_to(_pad_to(_pad_to(Vt, BI, 0), 8, 1), LANE, 2)
    # padded requests dequant with scale 1.0 (their rows are sliced off)
    sc = jnp.pad(scale.astype(jnp.float32).reshape(-1, 1),
                 [(0, (-R) % BI), (0, 0)], constant_values=1.0)
    sp = _pad_to(_pad_to(seen_w.astype(jnp.int8), LANE, 1), BI, 0)
    cp = jnp.pad(cand.astype(jnp.int32),
                 [(0, (-R) % BI), (0, (-Cw) % BJ)],
                 constant_values=-1)
    vals, idx = serve_topk_lib.serve_topk_window_quant_kernel_call(
        Up, Vt, sc, sp, cp, k, block_i=BI, block_j=BJ, interpret=interpret,
    )
    return vals[:R], idx[:R]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def recommend_topk_peruser(U, V, train_mask, k: int, *, interpret: bool = True):
    """DMF serving eval: per-user item factors V (I, J, K) — each learner
    scores only his own copy v^i = p^i + q^i. Streams item tiles through a
    running top-k; the (I, J) score matrix never materializes.

    V is transposed to (I, K, J) so the lane dim is J (tiled by 128) and K
    sits on sublanes (padded to the f32 sublane quantum, 8), avoiding a
    16x lane-padding blowup of K."""
    I, K = U.shape
    J = V.shape[1]
    BI, BJ = 128, 128
    Up = _pad_to(_pad_to(U.astype(jnp.float32), BI, 0), 8, 1)
    Vt = jnp.transpose(V.astype(jnp.float32), (0, 2, 1))   # (I, K, J)
    Vt = _pad_to(_pad_to(_pad_to(Vt, BI, 0), 8, 1), BJ, 2)
    # padded users: mask=0 rows score garbage but are sliced off; padded
    # item columns must be masked out so they never enter anyone's top-k
    mp = _pad_to(_pad_to(train_mask.astype(jnp.int8), BJ, 1), BI, 0)
    if mp.shape[1] > J:
        mp = mp.at[:, J:].set(1)
    vals, idx = topk_scores.topk_scores_peruser_kernel_call(
        Up, Vt, mp, k, block_i=BI, block_j=BJ, interpret=interpret,
    )
    return vals[:I], idx[:I]
