"""Pallas TPU kernel: geo-pruned batched serving — candidate gather +
per-user scores + masked running top-k, fused.

A microbatch of R requests arrives with each learner's own factors
(u_i (K,), v^i = p^i + q^i (J, K) — the decentralized per-user item view)
and a per-request candidate row from the city bucket index
(`serving/candidates.py`, (R, Cw) global item ids, -1 padded). The kernel
fuses, per (request, candidate) tile in one VMEM pass:

    gather v^i at the candidate ids  →  scores u_i · v^i_cand
    →  seen/pad masking  →  merge into the running per-request top-k.

Only O(Cw·K) *compute* is done per request instead of O(J·K): the grid's
inner axis tiles the *candidate* dim, not the item dim — that is the
geo-pruning (paper Fig. 2: check-ins concentrate in the home city). The
gather source (the request's item slab) is still staged whole on this
container — see `ops.serve_topk` for the HBM/DMA shape of the compiled
design.

The candidate gather is a per-row `take_along_axis` over the request's own
item slab held in VMEM; the output index buffer carries global item ids
directly (no position→id remap pass afterwards). Unfilled slots (fewer
unseen candidates than k, incl. all-seen users) stay at (NEG_INF, -1).

Layout mirrors `topk_scores._topk_peruser_kernel`: V comes in as (R, K, J)
so the lane dim is J and K sits on sublanes. On this CPU container the
kernel runs interpret=True; on real TPU the per-request slab would be
DMA'd from HBM per candidate window instead of staged whole — the compute
and the top-k carry are identical.

Two kernel families live here:

* ``_serve_topk_kernel`` — the original whole-slab kernel: every request
  hands its FULL item slab (R, K, J) to the kernel and the candidate gather
  happens inside. Kept as the staging reference; physically impossible at
  J=100k (a 64-request microbatch would stage 64·J·K floats).
* ``_serve_topk_window_kernel`` / ``_serve_topk_window_quant_kernel`` — the
  tiled million-scale path: the candidate windows (R, K, Cw) are gathered
  OUTSIDE the kernel from the HBM-resident factor store (`serving/store.py`
  slab, or row-gathers of V/P/Q in the engine dispatches), and the grid's
  inner axis streams (block_i, K, block_j) window tiles through VMEM — the
  staged working set is O(R·Cw·K) regardless of J. Scores, masking and the
  `_merge_tile_topk` carry are byte-for-byte the same computation as the
  whole-slab kernel, so the two are bitwise identical on shared inputs.
  The quant variant takes int8 codes (+ a per-request f32 dequant scale) or
  bf16 factors and dequantizes in-VMEM before the identical score/merge.

Tie contract (load-bearing for the exact-equality guarantee): candidate
rows are in ascending item-id order and `_merge_tile_topk` only displaces
on strictly-greater scores, so equal scores resolve to the lowest item id
— the same tie-break as `jax.lax.top_k` on dense scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk_scores import NEG_INF, _merge_tile_topk


def _serve_topk_kernel(u_ref, v_ref, seen_ref, cand_ref, vals_ref, idx_ref, *, k):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    u = u_ref[...]                                            # (bi, K)
    cand = cand_ref[...]                                      # (bi, bj) ids
    safe = jnp.maximum(cand, 0)                               # pad-safe gather
    v = v_ref[...]                                            # (bi, K, J)
    vc = jnp.take_along_axis(v, safe[:, None, :], axis=2)     # (bi, K, bj)
    scores = jnp.sum(u[:, :, None] * vc, axis=1)              # (bi, bj)
    seen = jnp.take_along_axis(seen_ref[...], safe, axis=1)   # (bi, bj)
    scores = jnp.where((cand < 0) | (seen != 0), NEG_INF, scores)
    vals, idxs = _merge_tile_topk(scores, cand, vals_ref[...], idx_ref[...], k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def serve_topk_kernel_call(U, Vt, seen, cand, k: int, *, block_i: int = 8,
                           block_j: int = 128, interpret: bool = True):
    """U: (R, K), Vt: (R, K, J) per-request item factors, seen: (R, J) int8,
    cand: (R, Cw) int32 global item ids (-1 = padded slot). Returns
    (vals (R, k), idx (R, k)) with idx holding global item ids, -1 where
    fewer than k unseen candidates exist."""
    R, K = U.shape
    J = Vt.shape[2]
    Cw = cand.shape[1]
    assert Vt.shape[:2] == (R, K), (Vt.shape, U.shape)
    assert seen.shape == (R, J), (seen.shape, R, J)
    assert R % block_i == 0 and Cw % block_j == 0, (R, Cw, block_i, block_j)
    assert k <= block_j, (k, block_j)
    grid = (R // block_i, Cw // block_j)
    kern = functools.partial(_serve_topk_kernel, k=k)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, K, J), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_i, J), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), jnp.float32),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=interpret,
    )(U, Vt, seen.astype(jnp.int8), cand)
    return vals, idx


def _serve_topk_window_kernel(u_ref, v_ref, seen_ref, cand_ref, vals_ref,
                              idx_ref, *, k):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    u = u_ref[...]                                            # (bi, K)
    cand = cand_ref[...]                                      # (bi, bj) ids
    vc = v_ref[...]                                           # (bi, K, bj)
    scores = jnp.sum(u[:, :, None] * vc, axis=1)              # (bi, bj)
    scores = jnp.where((cand < 0) | (seen_ref[...] != 0), NEG_INF, scores)
    vals, idxs = _merge_tile_topk(scores, cand, vals_ref[...], idx_ref[...], k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def serve_topk_window_kernel_call(U, Vw, seen_w, cand, k: int, *,
                                  block_i: int = 8, block_j: int = 128,
                                  interpret: bool = True):
    """Tiled serving over pre-gathered candidate windows. U: (R, K);
    Vw: (R, K, Cw) the requests' candidate-window item factors (K-major, the
    same layout the slab kernel produces internally from its gather);
    seen_w: (R, Cw) int8 seen bits aligned to `cand`; cand: (R, Cw) int32
    global item ids, -1 padded. The grid's inner axis streams (bi, K, bj)
    window tiles — per-step VMEM is independent of J, so the factor source
    can stay HBM-resident at million-user scale. Bitwise identical to
    `serve_topk_kernel_call` when Vw/seen_w hold the slab-gathered values:
    same block sizes, same K-major contraction, same `_merge_tile_topk`
    carry, same tie contract."""
    R, K = U.shape
    Cw = cand.shape[1]
    assert Vw.shape == (R, K, Cw), (Vw.shape, (R, K, Cw))
    assert seen_w.shape == (R, Cw), (seen_w.shape, (R, Cw))
    assert R % block_i == 0 and Cw % block_j == 0, (R, Cw, block_i, block_j)
    assert k <= block_j, (k, block_j)
    grid = (R // block_i, Cw // block_j)
    kern = functools.partial(_serve_topk_window_kernel, k=k)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, K, block_j), lambda i, j: (i, 0, j)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), jnp.float32),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=interpret,
    )(U, Vw, seen_w.astype(jnp.int8), cand)
    return vals, idx


def _serve_topk_window_quant_kernel(u_ref, v_ref, scale_ref, seen_ref,
                                    cand_ref, vals_ref, idx_ref, *, k):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        vals_ref[...] = jnp.full_like(vals_ref, NEG_INF)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    u = u_ref[...]                                            # (bi, K)
    cand = cand_ref[...]                                      # (bi, bj) ids
    scale = scale_ref[...]                                    # (bi, 1)
    # in-VMEM dequant: int8 codes × per-request scale (bf16 rides the same
    # path with scale=1 — the upcast IS the dequant), then the identical
    # K-major contraction + merge as the fp32 window kernel
    vc = v_ref[...].astype(jnp.float32) * scale[:, :, None]   # (bi, K, bj)
    scores = jnp.sum(u[:, :, None] * vc, axis=1)              # (bi, bj)
    scores = jnp.where((cand < 0) | (seen_ref[...] != 0), NEG_INF, scores)
    vals, idxs = _merge_tile_topk(scores, cand, vals_ref[...], idx_ref[...], k)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def serve_topk_window_quant_kernel_call(U, Vq, scale, seen_w, cand, k: int, *,
                                        block_i: int = 8, block_j: int = 128,
                                        interpret: bool = True):
    """Quantized tiled serving: `serve_topk_window_kernel_call` with the
    candidate windows carried as int8 codes (plus a per-request f32 dequant
    scale, (R, 1)) or bf16 factors (scale = 1.0). Dequantization happens
    per (bi, K, bj) tile in VMEM — HBM traffic shrinks by the quant ratio
    (4x for int8, 2x for bf16). On real TPU int8 windows obey the (32, 128)
    tile minimum; interpret mode does not enforce it. Score error is
    bounded per request by ||u||₁ · scale/2 (int8, round-to-nearest codes)
    resp. Σ_k |u_k·v_k|·2⁻⁸ (bf16) — measured in BENCH_serving."""
    R, K = U.shape
    Cw = cand.shape[1]
    assert Vq.shape == (R, K, Cw), (Vq.shape, (R, K, Cw))
    assert scale.shape == (R, 1), scale.shape
    assert seen_w.shape == (R, Cw), (seen_w.shape, (R, Cw))
    assert R % block_i == 0 and Cw % block_j == 0, (R, Cw, block_i, block_j)
    assert k <= block_j, (k, block_j)
    grid = (R // block_i, Cw // block_j)
    kern = functools.partial(_serve_topk_window_quant_kernel, k=k)
    vals, idx = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, K, block_j), lambda i, j: (i, 0, j)),
            pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
            pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_i, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), jnp.float32),
            jax.ShapeDtypeStruct((R, k), jnp.int32),
        ],
        interpret=interpret,
    )(U, Vq, scale, seen_w.astype(jnp.int8), cand)
    return vals, idx
