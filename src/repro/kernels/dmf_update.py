"""Pallas TPU kernel: fused DMF per-rating gradients (paper Eqs. 9-11).

The paper's hot inner loop — for a minibatch of gathered factors, compute
the confidence-weighted residual and all three gradients in one pass. On
TPU this is a VPU-bound fusion: one read of (u, p, q), residual reduction,
three FMA writes — vs. 4 separate HBM round-trips in the naive op-by-op
form. Batch dim is tiled over a grid; K stays resident in VMEM (K ≤ 256
for any MF workload — the paper uses K ∈ {5, 10, 15}, padded to the
128-lane boundary by the wrapper).

Block layout: (Bt, K) tiles of u/p/q in VMEM; r/conf as (Bt, 1) columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dmf_grads_kernel(u_ref, p_ref, q_ref, r_ref, c_ref,
                      gu_ref, gp_ref, gq_ref, *, alpha, beta, gamma):
    u = u_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    r = r_ref[...]          # (Bt, 1)
    c = c_ref[...]          # (Bt, 1)
    v = p + q
    pred = jnp.sum(u * v, axis=-1, keepdims=True)       # (Bt, 1)
    err = c * (r - pred)                                # (Bt, 1)
    gu_ref[...] = -err * v + alpha * u
    gp_ref[...] = -err * u + beta * p
    gq_ref[...] = -err * u + gamma * q


def dmf_grads_kernel_call(u, p, q, r, conf, *, alpha, beta, gamma,
                          block_b: int = 256, interpret: bool = True):
    """u/p/q: (B, K) f32; r/conf: (B,). K should be lane-aligned (wrapper
    pads). Returns (gu, gp, gq)."""
    B, K = u.shape
    assert B % block_b == 0, (B, block_b)
    r2 = r.reshape(B, 1)
    c2 = conf.reshape(B, 1)
    grid = (B // block_b,)
    bspec_mat = pl.BlockSpec((block_b, K), lambda i: (i, 0))
    bspec_col = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((B, K), u.dtype)] * 3
    kern = functools.partial(_dmf_grads_kernel, alpha=alpha, beta=beta, gamma=gamma)
    gu, gp, gq = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[bspec_mat, bspec_mat, bspec_mat, bspec_col, bspec_col],
        out_specs=[bspec_mat, bspec_mat, bspec_mat],
        out_shape=out_shape,
        interpret=interpret,
    )(u, p, q, r2, c2)
    return gu, gp, gq


def _dmf_fused_step_kernel(u_ref, p_ref, q_ref, r_ref, c_ref,
                           du_ref, gp_ref, dq_ref, loss_ref,
                           *, theta, alpha, beta, gamma):
    """Fused training step body: residual → Eqs. 9-11 grads → lr-scaled
    deltas for the sender's own state, plus the raw global-factor gradient
    gp (the *message* — receivers scale it by their own walk weight) and
    the batch loss, all in one VMEM pass. The loss block is revisited by
    every grid step and accumulated in place (grid is sequential on TPU)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        loss_ref[...] = jnp.zeros_like(loss_ref)

    u = u_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    r = r_ref[...]          # (Bt, 1)
    c = c_ref[...]          # (Bt, 1)
    v = p + q
    raw = r - jnp.sum(u * v, axis=-1, keepdims=True)    # (Bt, 1)
    err = c * raw
    gu = -err * v + alpha * u
    gp = -err * u + beta * p
    gq = -err * u + gamma * q
    du_ref[...] = -theta * gu
    gp_ref[...] = gp
    dq_ref[...] = -theta * gq
    loss_ref[...] += 0.5 * jnp.sum(c * raw * raw)


def _dmf_fused_step_dp_kernel(u_ref, p_ref, q_ref, r_ref, c_ref, z_ref,
                              du_ref, gp_ref, dq_ref, loss_ref,
                              *, theta, alpha, beta, gamma, clip):
    """The fused step WITH the DP mechanism folded in: Eqs. 9-11, lr-scaled
    deltas, batch loss, AND the per-row L2 clip + noise add on the outgoing
    gp message — still ONE VMEM pass, so the DP path keeps the un-noised
    path's one-kernel-per-minibatch dispatch count. ``z`` is the
    pre-scaled noise block for this batch: drawn from the counter-keyed
    stream (`dp_noise.gauss_counter`, keyed by global stream row id) in ONE
    vectorized epoch-level pass and streamed in per batch — generating
    in-kernel per batch pays the transcendental dispatch cost 70x per
    epoch for the same bits (the standalone `dp_noise` kernel keeps the
    in-kernel generation as the self-contained mechanism op)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        loss_ref[...] = jnp.zeros_like(loss_ref)

    u = u_ref[...]
    p = p_ref[...]
    q = q_ref[...]
    r = r_ref[...]          # (Bt, 1)
    c = c_ref[...]          # (Bt, 1)
    v = p + q
    raw = r - jnp.sum(u * v, axis=-1, keepdims=True)    # (Bt, 1)
    err = c * raw
    gu = -err * v + alpha * u
    gp = -err * u + beta * p
    gq = -err * u + gamma * q
    nrm = jnp.sqrt(jnp.sum(gp * gp, axis=-1, keepdims=True))
    gp = gp * jnp.minimum(1.0, clip / nrm)              # inf/0 -> 1 (no-op)
    du_ref[...] = -theta * gu
    gp_ref[...] = gp + z_ref[...]
    dq_ref[...] = -theta * gq
    loss_ref[...] += 0.5 * jnp.sum(c * raw * raw)


def dmf_fused_step_dp_kernel_call(u, p, q, r, conf, z, *, theta, alpha, beta,
                                  gamma, clip, block_b: int = 256,
                                  interpret: bool = True):
    """DP variant of `dmf_fused_step_kernel_call`: extra input z (B, K) —
    the pre-scaled σC-Gaussian noise for this batch's messages (zero on
    padded rows/columns). Returns (du, g̃p, dq, loss) with g̃p the
    clipped+noised message."""
    B, K = u.shape
    assert B % block_b == 0, (B, block_b)
    r2 = r.reshape(B, 1)
    c2 = conf.reshape(B, 1)
    grid = (B // block_b,)
    bspec_mat = pl.BlockSpec((block_b, K), lambda i: (i, 0))
    bspec_col = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    bspec_loss = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kern = functools.partial(
        _dmf_fused_step_dp_kernel, theta=theta, alpha=alpha, beta=beta,
        gamma=gamma, clip=clip)
    du, gp, dq, loss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[bspec_mat, bspec_mat, bspec_mat, bspec_col, bspec_col,
                  bspec_mat],
        out_specs=[bspec_mat, bspec_mat, bspec_mat, bspec_loss],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), u.dtype),
            jax.ShapeDtypeStruct((B, K), u.dtype),
            jax.ShapeDtypeStruct((B, K), u.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(u, p, q, r2, c2, z)
    return du, gp, dq, loss


def dmf_fused_step_kernel_call(u, p, q, r, conf, *, theta, alpha, beta, gamma,
                               block_b: int = 256, interpret: bool = True):
    """u/p/q: (B, K) f32 (K lane-aligned by the wrapper); r/conf: (B,).
    Returns (du, gp, dq, loss): the -θ·grad deltas for u and q, the raw
    propagation gradient for p, and the summed batch loss (1, 1)."""
    B, K = u.shape
    assert B % block_b == 0, (B, block_b)
    r2 = r.reshape(B, 1)
    c2 = conf.reshape(B, 1)
    grid = (B // block_b,)
    bspec_mat = pl.BlockSpec((block_b, K), lambda i: (i, 0))
    bspec_col = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    bspec_loss = pl.BlockSpec((1, 1), lambda i: (0, 0))
    kern = functools.partial(
        _dmf_fused_step_kernel, theta=theta, alpha=alpha, beta=beta, gamma=gamma
    )
    du, gp, dq, loss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[bspec_mat, bspec_mat, bspec_mat, bspec_col, bspec_col],
        out_specs=[bspec_mat, bspec_mat, bspec_mat, bspec_loss],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), u.dtype),
            jax.ShapeDtypeStruct((B, K), u.dtype),
            jax.ShapeDtypeStruct((B, K), u.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(u, p, q, r2, c2)
    return du, gp, dq, loss
