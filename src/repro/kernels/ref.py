"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dmf_grads_ref(u, p, q, r, conf, alpha, beta, gamma):
    """Fused DMF per-rating gradients (paper Eqs. 9-11), confidence-weighted.

    u, p, q: (B, K); r, conf: (B,). Returns (gu, gp, gq) each (B, K).
    """
    v = p + q
    err = conf * (r - jnp.sum(u * v, axis=-1))
    gu = -err[:, None] * v + alpha * u
    gp = -err[:, None] * u + beta * p
    gq = -err[:, None] * u + gamma * q
    return gu, gp, gq


def dmf_fused_step_ref(u, p, q, r, conf, theta, alpha, beta, gamma):
    """Fused Alg. 1 step oracle: (du, gp, dq, loss) = lr-scaled deltas for
    the sender's u/q, raw global-factor gradient message, batch loss."""
    gu, gp, gq = dmf_grads_ref(u, p, q, r, conf, alpha, beta, gamma)
    raw = r - jnp.sum(u * (p + q), axis=-1)
    loss = 0.5 * jnp.sum(conf * raw * raw)
    return -theta * gu, gp, -theta * gq, loss


def topk_scores_peruser_ref(U, V, train_mask, k):
    """Per-user-factor serving oracle. U: (I, K), V: (I, J, K)."""
    scores = jnp.einsum("ik,ijk->ij", U, V)
    scores = jnp.where(train_mask, -jnp.inf, scores)
    return jax.lax.top_k(scores, k)


# single source of the dead-slot sentinel: the oracle must use the exact
# value the kernels fill unmerged slots with, or bitwise-equality breaks
from repro.kernels.topk_scores import NEG_INF  # noqa: E402


def masked_topk_finalize(vals, idx):
    """Normalize a dense `lax.top_k` result to the streaming-kernel contract:
    slots whose score is masked-out (≤ NEG_INF, incl. -inf) become
    (NEG_INF, -1) — `top_k` otherwise reports arbitrary indices there."""
    dead = vals <= NEG_INF
    return jnp.where(dead, NEG_INF, vals), jnp.where(dead, -1, idx)


def serve_topk_ref(U, V, cand, seen, k):
    """Geo-pruned serving oracle: dense per-request scores, masked to the
    candidate bucket and the seen-filter, then `lax.top_k`.

    U: (R, K); V: (R, J, K); cand: (R, Cw) int32 item ids (-1 pad);
    seen: (R, J) bool/int8. Returns (vals (R, k), idx (R, k)) with -1/NEG_INF
    in unfilled slots — the exact-equality target for `ops.serve_topk`.
    """
    R, J, _ = V.shape
    # K-major contraction (not einsum): reduction grouping over K is then
    # invariant to sublane padding, so the Pallas kernel matches *bitwise*
    # (einsum picks a different association, off by ~1 ulp).
    scores = jnp.sum(U[:, :, None] * jnp.transpose(V, (0, 2, 1)), axis=1)
    elig = jnp.zeros((R, J), bool).at[
        jnp.arange(R)[:, None], jnp.maximum(cand, 0)
    ].max(cand >= 0)
    scores = jnp.where(elig & (seen == 0), scores, NEG_INF)
    vals, idx = jax.lax.top_k(scores, k)
    return masked_topk_finalize(vals, idx)


def serve_topk_window_ref(U, Vw, cand, seen_w, k):
    """Tiled-serving oracle over pre-gathered candidate windows: window
    scores, pad/seen masking, dense `lax.top_k` over window positions, then
    position→item-id remap — the exact-equality target for
    `ops.serve_topk_window` (and, on dequantized windows, for
    `ops.serve_topk_window_quant`).

    U: (R, K); Vw: (R, Cw, K); cand: (R, Cw) int32 item ids (-1 pad);
    seen_w: (R, Cw) bool/int8 aligned to cand. Candidate rows are ascending
    in item id (index contract), so `top_k`'s lowest-position tie-break is
    the same lowest-item-id tie-break the streaming kernel implements.
    """
    # K-major contraction (not einsum) — see serve_topk_ref
    scores = jnp.sum(U[:, :, None] * jnp.transpose(Vw, (0, 2, 1)), axis=1)
    scores = jnp.where((cand < 0) | (seen_w != 0), NEG_INF, scores)
    vals, pos = jax.lax.top_k(scores, k)
    idx = jnp.take_along_axis(jnp.maximum(cand, 0), pos, axis=1)
    return masked_topk_finalize(vals, idx)


def dp_clip_noise_ref(g, rid, seed, clip, noise_std):
    """DP gradient-message mechanism oracle: per-row L2 clip to ``clip``
    then additive N(0, noise_std²) noise.

    g: (B, K) f32; rid: (B,) int32 global message-row ids; seed: int32.
    The noise stream itself is spec'd as `dp_noise.gauss_counter` — a pure
    function of (seed, rid, column) — so the oracle draws the *identical*
    perturbation the fused kernel applies (the mechanism is deterministic
    by design; only the clip-norm reduction is re-derived independently).
    """
    from repro.kernels.dp_noise import gauss_counter

    B, K = g.shape
    nrm = jnp.sqrt(jnp.sum(g * g, axis=-1, keepdims=True))
    out = g * jnp.minimum(1.0, clip / nrm)
    if noise_std > 0.0:
        out = out + noise_std * gauss_counter(seed, rid.reshape(B, 1), K)
    return out


def gossip_mix_ref(M, X):
    """Propagation mixing: (I, I) walk matrix times flattened learner state
    (I, F) — Alg. 1 line 15 vectorized over receivers."""
    return jnp.einsum("ij,jf->if", M, X)


def topk_scores_ref(U, V, train_mask, k):
    """Serving: masked preference scores + per-user top-k.

    U: (I, K), V: (J, K), train_mask: (I, J) bool. Returns (vals, idx)."""
    scores = U @ V.T
    scores = jnp.where(train_mask, -jnp.inf, scores)
    return jax.lax.top_k(scores, k)
