"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dmf_grads_ref(u, p, q, r, conf, alpha, beta, gamma):
    """Fused DMF per-rating gradients (paper Eqs. 9-11), confidence-weighted.

    u, p, q: (B, K); r, conf: (B,). Returns (gu, gp, gq) each (B, K).
    """
    v = p + q
    err = conf * (r - jnp.sum(u * v, axis=-1))
    gu = -err[:, None] * v + alpha * u
    gp = -err[:, None] * u + beta * p
    gq = -err[:, None] * u + gamma * q
    return gu, gp, gq


def dmf_fused_step_ref(u, p, q, r, conf, theta, alpha, beta, gamma):
    """Fused Alg. 1 step oracle: (du, gp, dq, loss) = lr-scaled deltas for
    the sender's u/q, raw global-factor gradient message, batch loss."""
    gu, gp, gq = dmf_grads_ref(u, p, q, r, conf, alpha, beta, gamma)
    raw = r - jnp.sum(u * (p + q), axis=-1)
    loss = 0.5 * jnp.sum(conf * raw * raw)
    return -theta * gu, gp, -theta * gq, loss


def topk_scores_peruser_ref(U, V, train_mask, k):
    """Per-user-factor serving oracle. U: (I, K), V: (I, J, K)."""
    scores = jnp.einsum("ik,ijk->ij", U, V)
    scores = jnp.where(train_mask, -jnp.inf, scores)
    return jax.lax.top_k(scores, k)


def gossip_mix_ref(M, X):
    """Propagation mixing: (I, I) walk matrix times flattened learner state
    (I, F) — Alg. 1 line 15 vectorized over receivers."""
    return jnp.einsum("ij,jf->if", M, X)


def topk_scores_ref(U, V, train_mask, k):
    """Serving: masked preference scores + per-user top-k.

    U: (I, K), V: (J, K), train_mask: (I, J) bool. Returns (vals, idx)."""
    scores = U @ V.T
    scores = jnp.where(train_mask, -jnp.inf, scores)
    return jax.lax.top_k(scores, k)
