"""Differentially-private gradient exchange for DMF.

The paper's privacy argument is structural: only derived gradients of the
global item factor ever leave a learner (Alg. 1 lines 13-15). This package
hardens and *measures* that channel:

  * `mechanism`  — per-message L2 clip + Gaussian noise applied to every
                   propagated P-gradient before it reaches any receiver
                   (folded into the fused step kernel on the Pallas hot
                   path — `ops.dmf_fused_step_dp`; standalone fused op:
                   `ops.dp_clip_noise`);
  * `accountant` — Rényi-DP accounting for the subsampled Gaussian
                   mechanism, per-learner ε(δ) from realized minibatch
                   participation, plus the σ-for-ε solver;
  * `audit`      — empirical leakage harness: gradient-inversion rating
                   reconstruction and membership inference run against the
                   observed outbox stream, reported as attack advantage.

Wiring: `DMFConfig(dp_clip=…, dp_sigma=…, dp_seed=…)` turns the mechanism
on for the sparse scan epoch, the learner-sharded SPMD epoch (noise added
*before* the `all_to_all`), and the serving-engine online refresh. With
``dp_sigma=0`` and ``dp_clip=inf`` every path is bit-exact with the
un-noised code (DESIGN.md §9).
"""
from repro.privacy.accountant import (  # noqa: F401
    GaussianAccountant,
    rdp_subsampled_gaussian,
    rdp_to_epsilon,
    sigma_for_epsilon,
)
from repro.privacy.mechanism import (  # noqa: F401
    dp_enabled,
    epoch_noise_seed,
    noise_std,
    screening_threshold,
)
