"""Rényi-DP accounting for the subsampled Gaussian gradient-exchange.

Mechanism model: each minibatch step, a learner's participating message
rows are L2-clipped to C and noised with N(0, (σC)²) before leaving
(privacy/mechanism.py) — the classic DP-SGD release with noise multiplier
σ and add/remove sensitivity C. Per step this is the *subsampled* Gaussian
mechanism: a given rating row of learner i participates in a step with
probability qᵢ, estimated from the REALIZED minibatch stream (how many of
the epoch's nb batches actually carried one of i's rows) rather than an
idealized Poisson rate — the "realized participation" the launcher and
`dmf.fit` feed in via `observe_epoch`.

RDP of the subsampled Gaussian at integer order α (Wang, Balle &
Kasiviswanathan 2019 upper bound, Poisson sampling):

    ε(α) = log( Σ_{j=0..α} C(α,j) (1-q)^{α-j} q^j · exp(j(j-1)/(2σ²)) ) / (α-1)

composed additively over steps, then converted to (ε, δ)-DP with the
standard  ε = min_α [ ε_RDP(α) + log(1/δ)/(α-1) ].

Caveats (DESIGN.md §9): q is realized-frequency, not true Poisson sampling
(shuffled minibatching is approximated as sampled). Learner-level ε
composes over ALL of a learner's rows: a participating batch's k
simultaneous per-row releases (each clipped to C, noised σC) are
accounted as one √k·C-sensitivity release — effective multiplier σ/√k̄
with k̄ the learner's realized mean rows per participating batch, rounded
up (`observe_epoch`). Conservative for a neighbor that only observes some
hops; sized for the strongest (first-hop) observer.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

DEFAULT_ALPHAS = tuple(range(2, 33)) + (40, 48, 64, 96, 128, 192, 256)


def _log_comb(n: int, k: np.ndarray) -> np.ndarray:
    return (math.lgamma(n + 1)
            - np.vectorize(math.lgamma)(k + 1.0)
            - np.vectorize(math.lgamma)(n - k + 1.0))


def rdp_subsampled_gaussian(q, sigma: float, alphas=DEFAULT_ALPHAS) -> np.ndarray:
    """Per-step RDP ε(α) of the q-subsampled Gaussian with noise multiplier
    ``sigma``, for integer orders ``alphas``. ``q`` may be a scalar or an
    (N,) array of sampling rates in [0, 1]; returns (N, len(alphas))
    (or (len(alphas),) for scalar q). q=0 rows cost exactly 0; q=1 rows
    reduce to the unsubsampled Gaussian ε(α) = α/(2σ²).
    """
    scalar = np.ndim(q) == 0
    q = np.atleast_1d(np.asarray(q, np.float64))
    assert ((q >= 0) & (q <= 1)).all(), "sampling rates must be in [0, 1]"
    assert sigma > 0, "accounting needs dp_sigma > 0"
    out = np.zeros((len(q), len(alphas)), np.float64)
    full = q >= 1.0
    mid = (q > 0.0) & ~full
    qm = q[mid]
    for a_ix, alpha in enumerate(alphas):
        assert int(alpha) == alpha and alpha >= 2, alpha
        alpha = int(alpha)
        out[full, a_ix] = alpha / (2.0 * sigma * sigma)
        if qm.size:
            j = np.arange(alpha + 1, dtype=np.float64)
            log_terms = (
                _log_comb(alpha, j)[None, :]
                + (alpha - j)[None, :] * np.log1p(-qm)[:, None]
                + j[None, :] * np.log(qm)[:, None]
                + (j * (j - 1) / (2.0 * sigma * sigma))[None, :]
            )
            m = log_terms.max(axis=1, keepdims=True)
            lse = m[:, 0] + np.log(np.exp(log_terms - m).sum(axis=1))
            out[mid, a_ix] = np.maximum(lse, 0.0) / (alpha - 1)
    return out[0] if scalar else out


def rdp_to_epsilon(rdp: np.ndarray, alphas=DEFAULT_ALPHAS,
                   delta: float = 1e-5) -> tuple[np.ndarray, np.ndarray]:
    """(ε, δ)-DP from accumulated RDP: ε = min_α [rdp(α) + log(1/δ)/(α-1)].
    ``rdp``: (..., len(alphas)). Returns (eps (...,), best alpha (...,)).
    All-zero RDP rows (a learner that never released anything) convert to
    exactly ε = 0, not the log(1/δ)/(α-1) conversion floor."""
    rdp = np.asarray(rdp, np.float64)
    alphas = np.asarray(alphas, np.float64)
    cand = rdp + math.log(1.0 / delta) / (alphas - 1.0)
    best = cand.argmin(axis=-1)
    eps = np.where((rdp == 0.0).all(axis=-1), 0.0, cand.min(axis=-1))
    return eps, alphas[best]


def sigma_for_epsilon(eps_target: float, q: float, steps: int,
                      delta: float = 1e-5, alphas=DEFAULT_ALPHAS,
                      lo: float = 0.05, hi: float = 200.0,
                      rows_per_step: float = 1.0) -> float:
    """Smallest noise multiplier σ meeting ε(δ) ≤ eps_target after
    ``steps`` compositions at sampling rate ``q`` (the `--dp-epsilon`
    target mode: ε in, σ out). ``rows_per_step`` = expected message rows
    per participating step (k): a participating step's k simultaneous
    releases compose like one release at multiplier σ/√k, matching
    `GaussianAccountant.observe_epoch`. Bisection on the monotone ε(σ)."""
    assert eps_target > 0 and steps >= 1 and rows_per_step >= 1

    def eps_at(sigma: float) -> float:
        rdp = steps * rdp_subsampled_gaussian(
            q, sigma / math.sqrt(rows_per_step), alphas)
        return float(rdp_to_epsilon(rdp, alphas, delta)[0])

    if eps_at(hi) > eps_target:
        raise ValueError(
            f"eps_target={eps_target} unreachable even at sigma={hi}")
    if eps_at(lo) <= eps_target:
        return lo
    for _ in range(60):
        mid = math.sqrt(lo * hi)
        if eps_at(mid) > eps_target:
            lo = mid
        else:
            hi = mid
    return hi


@dataclasses.dataclass
class GaussianAccountant:
    """Per-learner RDP ledger across epochs.

    Feed each epoch's realized minibatch stream (the (nb, B) user-id
    array the scan consumes) to `observe_epoch`; read ε(δ) any time via
    `epsilon()` / `summary()`. `dmf.fit` owns one when the config enables
    DP and surfaces `summary()` as `FitResult.privacy`.
    """

    n_users: int
    sigma: float
    delta: float = 1e-5
    alphas: tuple = DEFAULT_ALPHAS

    def __post_init__(self):
        self._rdp = np.zeros((self.n_users, len(self.alphas)), np.float64)
        self.messages = np.zeros(self.n_users, np.int64)
        self.epochs = 0
        self.eps_trajectory: list[float] = []

    def observe_epoch(self, ui_batches: np.ndarray, valid=None) -> None:
        """Account one epoch from its realized stream: ``ui_batches`` is
        the (nb, B) per-batch sender ids actually dispatched. Learner i's
        sampling rate this epoch is (their participating batches)/nb, and
        the epoch composes nb subsampled-Gaussian steps at that rate.

        ``valid`` (optional (nb, B) bool) masks rows that did NOT release —
        the churn path's offline senders (robustness/faults.py): an offline
        learner's rows are zeroed before dispatch, so they must not be
        charged. ε is therefore monotone in realized participation: fewer
        valid rows ⇒ lower q and fewer compositions ⇒ no more privacy loss.

        Multi-row participation: a participating batch usually carries
        SEVERAL of learner i's rows (each rating spawns 1+m messages),
        each independently clipped to C and noised with σC. k simultaneous
        such releases equal ONE release of the concatenated vector with
        sensitivity √k·C at per-block noise σC — i.e. effective noise
        multiplier σ/√k. The ledger uses each learner's realized mean rows
        per participating batch (rounded UP to an eighth, conservative)
        as k, so per-batch accounting cannot under-state a heavy
        learner's loss."""
        ui = np.asarray(ui_batches)
        assert ui.ndim == 2, ui.shape
        nb = ui.shape[0]
        # O(stream) counting via unique (batch, user) pair keys — a dense
        # (nb, n_users) matrix would be O(batches · users) host memory,
        # which the million-learner target cannot afford
        keys = (np.repeat(np.arange(nb, dtype=np.int64), ui.shape[1])
                * self.n_users + ui.reshape(-1))
        if valid is not None:
            keys = keys[np.asarray(valid).reshape(-1).astype(bool)]
        uniq, counts = np.unique(keys, return_counts=True)
        users = (uniq % self.n_users).astype(np.int64)
        msgs = np.bincount(users, weights=counts,
                           minlength=self.n_users).astype(np.int64)
        self.messages += msgs
        part = np.bincount(users, minlength=self.n_users)
        q = np.minimum(part / nb, 1.0)
        kbar = np.ceil(8.0 * msgs / np.maximum(part, 1)) / 8.0  # round up
        for k in np.unique(kbar[part > 0]):
            sel = (kbar == k) & (part > 0)
            self._rdp[sel] += nb * rdp_subsampled_gaussian(
                q[sel], self.sigma / math.sqrt(k), self.alphas)
        self.epochs += 1
        self.eps_trajectory.append(float(self.epsilon()[0].max()))

    def epsilon(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-learner (ε(δ), best α) under the accumulated composition."""
        return rdp_to_epsilon(self._rdp, self.alphas, self.delta)

    def summary(self) -> dict:
        eps, _ = self.epsilon()
        active = self.messages > 0
        return {
            "sigma": float(self.sigma),
            "delta": float(self.delta),
            "epochs": int(self.epochs),
            "eps_max": float(eps.max()) if eps.size else 0.0,
            "eps_median_active": float(np.median(eps[active])) if active.any() else 0.0,
            "messages_total": int(self.messages.sum()),
            "messages_max_per_learner": int(self.messages.max()) if eps.size else 0,
            "eps_trajectory": [round(e, 6) for e in self.eps_trajectory],
        }
