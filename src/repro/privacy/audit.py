"""Empirical leakage audit of the gradient-exchange channel.

PR 3 made the paper's privacy claim *structural* (only gradient messages
cross learner/shard boundaries); this harness makes it *quantitative*.
Threat model: an honest-but-curious neighbor (or shard) observing the
outbox stream — tuples ``(sender i, item j, message g̃ = DP(∂L/∂p^i_j))``
— exactly what `_sparse_batch_update_messages` ships. Two attacks:

* **Gradient-inversion rating reconstruction** — early in training
  p ≈ q ≈ 0, so the raw message is −conf·(r − u·v)·u + β·p ≈ −conf·r·u:
  its magnitude is ∝ the rating. The attacker scores each message by
  (a) its L2 norm and (b) its projection on the sender's estimated u
  direction (top right-singular vector of the sender's message matrix —
  the attacker never sees u itself), and tries to separate r=1 check-ins
  from r=0 negative samples. Reported as advantage = 2·AUC − 1.

* **Membership inference** — "was (i, j) actually rated?": candidate
  pairs are scored by the largest observed message norm for that pair
  (unobserved pairs score 0); members are held-out train pairs,
  non-members uniformly sampled unrated pairs.

With DP off both attacks succeed almost surely (advantage → 1, the
numeric form of "gradients leak ratings"); with the mechanism on, noise
swamps the signal and advantage falls toward 0 as ε shrinks — the curve
`benchmarks/privacy_bench.py` records.

Message capture replays the EXACT training path: same sampling stream,
same `_step_deltas` math, same counter-keyed noise (deterministic given
the rng seed), via the messages-returning variant of the sparse batch
update — the audited stream is the shipped stream, not a re-derivation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dmf


@dataclasses.dataclass
class MessageLog:
    """The observed outbox stream: one row per sent gradient message."""

    sender: np.ndarray    # (N,) int sender learner ids
    item: np.ndarray      # (N,) int item ids
    rating: np.ndarray    # (N,) float ground-truth r (attacker target, NOT observed)
    conf: np.ndarray      # (N,) float confidence (ground truth, NOT observed)
    gp: np.ndarray        # (N, K) the messages as shipped (post-DP)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _audit_step(U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf, rid, dp_seed, cfg):
    return dmf._sparse_batch_update_messages(
        U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf, cfg,
        valid=None, rid=rid, dp_seed=dp_seed)


def observe_messages(cfg: dmf.DMFConfig, train: np.ndarray, nbr,
                     epochs: int = 1, seed: int | None = None) -> MessageLog:
    """Run ``epochs`` of the sparse training path from a fresh init,
    recording every gradient message exactly as it leaves its sender
    (post-mechanism when ``cfg.dp``). Same rng protocol as `dmf.fit`, so
    the captured stream is bit-identical to what training would ship."""
    assert cfg.mode != "ldmf", "ldmf exchanges nothing — nothing to audit"
    assert cfg.n_shards == 1, "audit observes the single-device stream"
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    state = dmf.init_state(cfg, rng)
    B = cfg.batch_size
    snd, itm, rat, cnf, msgs = [], [], [], [], []
    U, P, Q = state.U, state.P, state.Q
    for _ in range(epochs):
        ui, vj, r, conf = dmf.sample_epoch(train, cfg, rng)
        nb = len(ui) // B
        n = nb * B
        rid, dp_seed = dmf.epoch_dp_inputs(cfg, rng, n)
        dp_seed_j = jnp.asarray(dp_seed, jnp.int32)
        for b in range(nb):
            sl = slice(b * B, (b + 1) * B)
            U, P, Q, _, gp = _audit_step(
                U, P, Q, nbr.idx, nbr.wgt,
                jnp.asarray(ui[sl].astype(np.int32)),
                jnp.asarray(vj[sl].astype(np.int32)),
                jnp.asarray(r[sl]), jnp.asarray(conf[sl]),
                jnp.asarray(rid[sl]), dp_seed_j, cfg)
            snd.append(ui[sl])
            itm.append(vj[sl])
            rat.append(r[sl])
            cnf.append(conf[sl])
            msgs.append(np.asarray(gp))
    return MessageLog(
        sender=np.concatenate(snd), item=np.concatenate(itm),
        rating=np.concatenate(rat), conf=np.concatenate(cnf),
        gp=np.concatenate(msgs))


def _auc(pos: np.ndarray, neg: np.ndarray) -> float:
    """Rank-based AUC = P(score⁺ > score⁻) + ½·P(=), tie-averaged."""
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    s = np.concatenate([pos, neg]).astype(np.float64)
    _, inv, counts = np.unique(s, return_inverse=True, return_counts=True)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    ranks = (starts + (counts + 1) / 2.0)[inv]          # 1-based avg ranks
    u = ranks[: len(pos)].sum() - len(pos) * (len(pos) + 1) / 2.0
    return float(u / (len(pos) * len(neg)))


def _advantage(auc: float) -> float:
    return max(0.0, 2.0 * auc - 1.0)


def rating_reconstruction_attack(log: MessageLog) -> dict:
    """Distinguish real check-ins (r=1) from negative samples (r=0) in the
    observed stream. Two scorers: the message norm, and the
    gradient-inversion projection |g̃·ŵᵢ| with ŵᵢ the top right-singular
    vector of sender i's observed message matrix."""
    norms = np.linalg.norm(log.gp, axis=1)
    pos = log.rating > 0.5
    norm_auc = _auc(norms[pos], norms[~pos])

    proj = norms.copy()        # senders with a single message keep the norm
    for s in np.unique(log.sender):
        rows = np.nonzero(log.sender == s)[0]
        if len(rows) >= 2:
            G = log.gp[rows]
            # top right-singular vector = attacker's estimate of u_s
            _, _, vt = np.linalg.svd(G, full_matrices=False)
            proj[rows] = np.abs(G @ vt[0])
    inv_auc = _auc(proj[pos], proj[~pos])
    return {
        "rating_norm_auc": norm_auc,
        "rating_norm_advantage": _advantage(norm_auc),
        "rating_inversion_auc": inv_auc,
        "rating_inversion_advantage": _advantage(inv_auc),
    }


def membership_inference_attack(log: MessageLog, train: np.ndarray,
                                n_users: int, n_items: int,
                                rng: np.random.Generator | None = None,
                                n_pairs: int = 2000) -> dict:
    """Score candidate (user, item) pairs by the largest observed message
    norm for the pair; members = train pairs, non-members = uniformly
    sampled unrated pairs. Unobserved pairs score 0 — the attacker's
    baseline for "never exchanged"."""
    rng = rng or np.random.default_rng(0)
    train = np.asarray(train)
    rated = set(map(tuple, train[:, :2].tolist()))
    key = log.sender.astype(np.int64) * n_items + log.item.astype(np.int64)
    norms = np.linalg.norm(log.gp, axis=1)
    best: dict[int, float] = {}
    for k, v in zip(key, norms):
        k = int(k)
        if v > best.get(k, 0.0):
            best[k] = float(v)

    m = min(n_pairs, len(train))
    members = train[rng.choice(len(train), m, replace=False), :2]
    non = []
    while len(non) < m:
        i = int(rng.integers(0, n_users))
        j = int(rng.integers(0, n_items))
        if (i, j) not in rated:
            non.append((i, j))
    non = np.asarray(non)

    def score(pairs):
        return np.asarray([
            best.get(int(i) * n_items + int(j), 0.0) for i, j in pairs])

    auc = _auc(score(members), score(non))
    return {"membership_auc": auc, "membership_advantage": _advantage(auc)}


def run_audit(cfg: dmf.DMFConfig, train: np.ndarray, nbr, n_users: int,
              n_items: int, epochs: int = 1, seed: int = 0,
              n_pairs: int = 2000) -> dict:
    """Capture the outbox stream for ``epochs`` and run both attacks.
    Returns the attack-advantage report for this config's (C, σ)."""
    import math
    log = observe_messages(cfg, train, nbr, epochs=epochs, seed=seed)
    out = {
        # None (not inf) for the no-clip case: the report is JSON-bound
        "dp_clip": float(cfg.dp_clip) if math.isfinite(cfg.dp_clip) else None,
        "dp_sigma": float(cfg.dp_sigma),
        "n_messages": int(len(log.sender)),
    }
    out.update(rating_reconstruction_attack(log))
    out.update(membership_inference_attack(
        log, train, n_users, n_items,
        rng=np.random.default_rng(seed + 1), n_pairs=n_pairs))
    return out


def screening_report(log: MessageLog, norm_cap: float,
                     reject_prob: float | None = None) -> dict:
    """Privacy-side view of byzantine receiver screening (robustness/
    byzantine.py): replay the accept gate over an observed HONEST message
    stream and report what it costs and what it leaks.

    The accept bit is post-processing of the released message g̃ — a
    deterministic function of (g̃, τ) computable by any observer of the
    channel, so it consumes no additional ε (the DP guarantee of the
    release covers every function of it). What screening *does* add is an
    explicit utility price — honest messages falsely rejected — and a
    1-bit side channel correlated with the pre-noise norm: the report
    quantifies both (``pass_rate`` against the calibrated bound, and the
    accept-bit/rating agreement, which stays ≈ chance when τ is set by
    `mechanism.screening_threshold` because nearly everything passes).
    """
    norms = np.linalg.norm(log.gp, axis=1)
    finite = np.isfinite(log.gp).all(axis=1)
    ok = finite & (norms <= norm_cap)
    pos = log.rating > 0.5
    # the accept bit as a rating classifier: its AUC is the leak magnitude
    auc = _auc(ok[pos].astype(np.float64), ok[~pos].astype(np.float64))
    out = {
        "norm_cap": float(norm_cap) if np.isfinite(norm_cap) else None,
        "n_messages": int(len(norms)),
        "pass_rate": float(ok.mean()) if len(norms) else 1.0,
        "reject_rate": float(1.0 - ok.mean()) if len(norms) else 0.0,
        "norm_p50": float(np.quantile(norms, 0.5)) if len(norms) else 0.0,
        "norm_p99": float(np.quantile(norms, 0.99)) if len(norms) else 0.0,
        "norm_max": float(norms.max()) if len(norms) else 0.0,
        "accept_bit_rating_auc": auc,
        "accept_bit_rating_advantage": _advantage(auc),
    }
    if reject_prob is not None:
        out["calibrated_reject_prob"] = float(reject_prob)
    return out
