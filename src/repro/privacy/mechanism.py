"""The DP mechanism on the gradient-exchange channel: config surface and
seed/std conventions. The clip+noise math itself lives where it executes —
`core/dmf._dp_message` (jnp epoch paths, pre-scatter/pre-`all_to_all`),
the fused step kernel `ops.dmf_fused_step_dp` (Pallas path), and the
standalone fused op `ops.dp_clip_noise` / `ref.dp_clip_noise_ref` (the
self-contained mechanism kernel + its oracle) — all drawing the ONE
counter-keyed stream defined by `kernels/dp_noise.gauss_counter`.

What leaves a learner in Alg. 1 is the global-factor gradient message
∂L/∂p^i_j. Following "Practical Privacy Preserving POI Recommendation"
(Chen et al.), the mechanism makes that message differentially private at
the *sender*, before any routing:

    g̃ = g · min(1, C / ‖g‖₂)  +  N(0, (σC)² I)                 (local DP)

Receivers — the sender's own line-11 update included — only ever apply the
noised message, so an honest-but-curious neighbor (or shard) observes a
(C, σ)-Gaussian-mechanism release per message and nothing else. The noise
is keyed by ``(seed, global stream row id, column)`` through a counter
PRNG (kernels/dp_noise.py): deterministic given the per-epoch seed, hence
shard-count-invariant — the sharded path perturbs with bit-identical noise
to the single-device scan for the same epoch stream.

Config surface (core/dmf.DMFConfig):
  * ``dp_clip``  — C, the per-message L2 bound (inf = no clipping);
  * ``dp_sigma`` — σ, the noise multiplier *relative to C* (0 = no noise);
  * ``dp_seed``  — the mechanism's base seed, folded with a fresh
                   per-epoch draw so noise never repeats across epochs.

Disabled (σ=0 ∧ C=∞) the mechanism is skipped entirely — the compiled
epoch is the identical un-noised program, bit-exact with PRs 1-3.
"""
from __future__ import annotations

import math

import numpy as np

_GOLDEN = 0x9E3779B9
_U32 = 1 << 32


def dp_enabled(cfg) -> bool:
    """True iff the config requests any DP processing of the messages."""
    return cfg.dp_sigma > 0.0 or math.isfinite(cfg.dp_clip)


def noise_std(cfg) -> float:
    """Absolute noise std σ·C (0 when σ=0; σ>0 requires finite C —
    enforced by DMFConfig.__post_init__)."""
    if cfg.dp_sigma <= 0.0:
        return 0.0
    return cfg.dp_sigma * cfg.dp_clip


def screening_threshold(cfg, dim: int, reject_prob: float = 1e-6) -> float:
    """Norm cap τ for receiver-side byzantine screening
    (robustness/byzantine.py), calibrated so HONEST DP releases pass.

    An honest message is g̃ = clip_C(g) + N(0, (σC)² I_K), so
    ‖g̃‖ ≤ C + ‖z‖ with ‖z‖² = (σC)²·χ²_K. The Laurent–Massart tail bound
    gives  Pr[χ²_K ≥ K + 2√(K t) + 2t] ≤ e^{-t};  with t = ln(1/p):

        τ = C + σC · √(K + 2√(K·t) + 2t)

    i.e. an honest learner's message is rejected with probability ≤ p
    (``reject_prob``) per message — the false-reject rate the defense
    costs, and the slack an attacker gets for free: anything it sends
    under τ is indistinguishable-by-norm from honest traffic, which is
    why norm-preserving attacks (sign flip) need robust *aggregation*,
    not screening. Degenerate regimes: σ=0 → τ=C exactly (clipping is
    deterministic); C=∞ (no DP) → τ=∞, screening reduces to the finite
    check. The audit-side view of what the accept bit leaks is
    `privacy.audit.screening_report`.
    """
    assert 0.0 < reject_prob < 1.0, reject_prob
    if not math.isfinite(cfg.dp_clip):
        return float("inf")
    if cfg.dp_sigma <= 0.0:
        return float(cfg.dp_clip)
    t = math.log(1.0 / reject_prob)
    k = float(dim)
    chi2 = k + 2.0 * math.sqrt(k * t) + 2.0 * t
    return float(cfg.dp_clip + noise_std(cfg) * math.sqrt(chi2))


def epoch_noise_seed(rng: np.random.Generator, cfg) -> int:
    """Per-epoch mechanism seed: a fresh rng draw folded with ``dp_seed``.

    Drawn AFTER the epoch's minibatch sampling (both the single-device and
    the sharded epoch do sample-then-draw in that order, so their rng
    streams — and therefore their noise — stay identical). Noise re-used
    across epochs would cancel in update differences and leak; the fresh
    draw guarantees a new stream every epoch. DP-off epochs never call
    this, leaving the rng stream bit-exact with the un-noised paths.
    """
    draw = int(rng.integers(0, 2**31 - 1))
    return int((cfg.dp_seed * _GOLDEN + draw) % _U32) & 0x7FFFFFFF
