"""Small pytree utilities (no optax/flax offline — built from scratch)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_paths(tree):
    """List of ('/'-joined key path, leaf) pairs."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out
