"""Checkpointing: pytree <-> directory of .npy leaves + msgpack manifest.

Sharding-aware on the read path: ``restore`` accepts an optional sharding
tree and device_puts leaves accordingly (single-host; a multi-host variant
would shard-read per process — out of scope for the CPU container, noted in
DESIGN.md).

Integrity: ``save`` records a sha256 per leaf file in the manifest;
``restore`` verifies each leaf's bytes before deserializing and raises
`CorruptCheckpointError` on any mismatch or missing file (a torn write, a
flipped bit on disk, a truncated copy). ``verify`` is the non-raising
check — `robustness.recovery.resolve_step_dir` uses it to fall back from
a corrupted latest snapshot to the newest intact one. Manifests written
before checksums existed (no ``sha256`` key) restore unverified.
"""
from __future__ import annotations

import hashlib
import json
import pathlib

import jax
import numpy as np


class CorruptCheckpointError(RuntimeError):
    """A checkpoint leaf failed its manifest sha256 (or is missing)."""


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
        out["/".join(keys)] = leaf
    return out, treedef


def save(path: str | pathlib.Path, tree, step: int | None = None) -> None:
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(leaf)
        fn = name.replace("/", "__") + ".npy"
        raw = arr.dtype.kind not in "biufc"  # bf16/fp8: numpy stores as void
        # raw leaves save as a FLAT byte buffer: .view(uint8) on the shaped
        # array rejects 0-d scalars, and restore reshapes from the manifest
        np.save(path / fn,
                np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
                if raw else arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "raw": raw, "sha256": _sha256(path / fn),
        }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


def restore(path: str | pathlib.Path, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    flat_like, treedef = _flatten(like)
    flat_sh = None
    if shardings is not None:
        flat_sh, _ = _flatten(shardings)
    leaves = {}
    for name in flat_like:
        info = manifest["leaves"][name]
        f = path / info["file"]
        if not f.exists():
            raise CorruptCheckpointError(f"missing leaf file {f}")
        if "sha256" in info and _sha256(f) != info["sha256"]:
            raise CorruptCheckpointError(
                f"leaf {name!r} at {f} fails its manifest sha256 — the "
                "checkpoint is corrupted on disk")
        arr = np.load(f)
        if info.get("raw"):
            import jax.numpy as jnp
            dt = jnp.dtype(info["dtype"])
            arr = arr.view(dt).reshape(info["shape"])
        if flat_sh is not None:
            arr = jax.device_put(arr, flat_sh[name])
        leaves[name] = arr
    # rebuild in treedef order
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for pathk, _leaf in flat:
        keys = []
        for p in pathk:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
        ordered.append(leaves["/".join(keys)])
    return jax.tree_util.tree_unflatten(treedef, ordered)


def verify(path: str | pathlib.Path) -> bool:
    """Non-raising integrity check of one checkpoint directory: manifest
    readable and every leaf file present with a matching sha256 (leaves
    from pre-checksum manifests pass — nothing to verify against)."""
    path = pathlib.Path(path)
    try:
        manifest = json.loads((path / "manifest.json").read_text())
    except (OSError, ValueError):
        return False
    for info in manifest.get("leaves", {}).values():
        f = path / info["file"]
        if not f.exists():
            return False
        if "sha256" in info and _sha256(f) != info["sha256"]:
            return False
    return True


def latest_step(root: str | pathlib.Path) -> int | None:
    root = pathlib.Path(root)
    steps = [
        int(p.name.split("_")[-1])
        for p in root.glob("step_*")
        if p.is_dir() and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def steps(root: str | pathlib.Path) -> list[int]:
    """All step numbers under a checkpoint root, ascending."""
    root = pathlib.Path(root)
    return sorted(
        int(p.name.split("_")[-1])
        for p in root.glob("step_*")
        if p.is_dir() and (p / "manifest.json").exists())
