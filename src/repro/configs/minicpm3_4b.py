"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense, MLA attention.

62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448; MLA with
kv_lora_rank=256, q_lora_rank=768 per the model card (rope dim 32).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    kv_lora_rank=256,
    q_lora_rank=768,
    rope_head_dim=32,
    period=(LayerSpec(kind="attn"),),
)
