"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD state-space model.

64L d_model=2560 (attn-free), ssm_state=128, expand=2 (d_inner=5120),
head_dim=64 (80 heads), vocab=50280.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_d_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    period=(LayerSpec(kind="mamba"),),
)
