"""DMF on the Alipay-like dataset (Table 1 row 2: 5,996 users / 7,404 POIs /
18,978 ratings / 298 cities)."""
from repro.configs.dmf_foursquare import dmf_config  # noqa: F401 (same hypers)
from repro.core.graph import GraphConfig

GRAPH = GraphConfig(n_neighbors=2, walk_length=3, uniform_weights=True)
DATASET = dict(kind="alipay", reduced_default=True)
