"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "minicpm3-4b",
    "llama-3.2-vision-90b",
    "deepseek-v2-lite-16b",
    "qwen1.5-4b",
    "musicgen-medium",
    "minitron-4b",
    "deepseek-v2-236b",
    "mamba2-2.7b",
    "jamba-1.5-large-398b",
    "yi-34b",
]


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
