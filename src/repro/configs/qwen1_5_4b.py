"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B family] — dense GQA with QKV bias.

40L d_model=2560 20H (kv=20, i.e. MHA) d_ff=6912 vocab=151936.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    period=(LayerSpec(kind="attn"),),
)
