"""DeepSeek-V2 (236B) [arXiv:2405.04434] — MoE with MLA.

60L d_model=5120 128H d_ff=1536(per-expert) vocab=102400; MLA kv_lora=512,
q_lora=1536; MoE: 2 shared + 160 routed experts, top-6.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=0,
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    n_routed_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    period=(LayerSpec(kind="attn", moe=True),),
)
