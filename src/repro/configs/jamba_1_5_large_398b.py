"""Jamba-1.5-Large (398B) [arXiv:2403.19887] — hybrid Mamba+attention MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576; Mamba:attn 7:1 interleave
(period of 8 with one attention layer), MoE 16 experts top-2 on every
other layer; vocab=65536.
"""
from repro.models.config import LayerSpec, ModelConfig

_M = LayerSpec(kind="mamba")
_Mmoe = LayerSpec(kind="mamba", moe=True)
_A = LayerSpec(kind="attn")
_Amoe = LayerSpec(kind="attn", moe=True)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    n_routed_experts=16,
    n_shared_experts=0,
    moe_top_k=2,
    moe_d_ff=24576,
    ssm_d_state=128,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_n_groups=8,
    # period of 8: [M, Mmoe, M, Mmoe, A, Mmoe, M, Mmoe] — 1 attn : 7 mamba,
    # MoE every other layer (Jamba's documented 1:7 / alternate-MoE layout)
    period=(_M, _Mmoe, _M, _Mmoe, _A, _Mmoe, _M, _Mmoe),
)
