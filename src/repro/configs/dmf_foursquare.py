"""DMF on the Foursquare-like dataset — the paper's primary benchmark
(Table 1 row 1: 6,524 users / 3,197 POIs / 26,186 ratings / 117 cities).

Hyperparameters follow the paper's §Experiments: α=0.1, θ=0.1, N=2, m=3,
w_{ii'}=1, K ∈ {5,10,15}, D ∈ {1..4}; β/γ tuned (Fig. 5).
"""
from repro.core.dmf import DMFConfig
from repro.core.graph import GraphConfig

GRAPH = GraphConfig(n_neighbors=2, walk_length=3, uniform_weights=True)


def dmf_config(n_users: int, n_items: int, dim: int = 10) -> DMFConfig:
    return DMFConfig(
        n_users=n_users, n_items=n_items, dim=dim,
        alpha=0.1, beta=0.1, gamma=0.01, lr=0.1, neg_samples=3,
    )


DATASET = dict(kind="foursquare", reduced_default=True)
