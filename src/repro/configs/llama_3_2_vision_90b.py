"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaled] — VLM.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; cross-attention
image layers interleaved 1:4 (20 cross + 80 self = 100). The vision encoder
(ViT) + projector is the stubbed frontend: ``input_specs`` provides
precomputed patch embeddings (B, 1600, d_model) — DESIGN.md carve-out.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    n_image_tokens=1600,
    period=(
        LayerSpec(kind="cross"),
        LayerSpec(kind="attn"),
        LayerSpec(kind="attn"),
        LayerSpec(kind="attn"),
        LayerSpec(kind="attn"),
    ),
)
