"""Minitron-4B [arXiv:2407.14679] — pruned Nemotron dense GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    period=(LayerSpec(kind="attn"),),
)
