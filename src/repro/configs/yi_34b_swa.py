"""yi-34b-swa — sliding-window variant of yi-34b (window 8192), the
dense-architecture carve-in for long_500k: decode attends to the last 8k
positions via a ring-buffer cache (O(window) memory at 524k context).
Not part of the assigned-10 list; selectable as --arch yi-34b-swa.
"""
import dataclasses

from repro.configs.yi_34b import CONFIG as _BASE
from repro.models.config import LayerSpec

CONFIG = dataclasses.replace(
    _BASE,
    name="yi-34b-swa",
    period=(LayerSpec(kind="attn", sliding_window=8192),),
)
