"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434] — MoE with MLA.

27L d_model=2048 16H d_ff=1408(per-expert) vocab=102400; MLA kv_lora=512
(no q-lora in Lite); MoE: 2 shared + 64 routed experts, top-6.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                      # all FFNs are MoE (first-dense simplification
                                 # noted in DESIGN.md §Arch-applicability)
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    rope_head_dim=64,
    n_routed_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    period=(LayerSpec(kind="attn", moe=True),),
)
