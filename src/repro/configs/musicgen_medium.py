"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=1536 24H d_ff=6144 vocab=2048 per codebook, 4 codebooks with
the delay interleaving pattern. The EnCodec tokenizer (conv codec) is the
stubbed frontend: inputs are codebook token ids (B, S, 4) — DESIGN.md
carve-out.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    n_codebooks=4,
    period=(LayerSpec(kind="attn"),),
)
