"""Learner-sharded DMF: Alg. 1 as SPMD over a ``learners`` mesh axis.

The paper frames DMF as "distributed learning with multi-learners (users)";
this module makes that literal at execution level: the learner axis of every
per-user buffer — U (I, K), P/Q (I, J, K), the neighbor table, the serving
engine's V/seen rows — is partitioned row-wise over an ``n_shards``-device
mesh, and one epoch is ONE SPMD dispatch (shard_map over the existing
`lax.scan` epoch). Item factors are *per-learner copies* already, so the
item axis needs no sharding — only learner-to-learner messages cross shard
boundaries, exactly like the paper's protocol.

Cross-shard propagation (Alg. 1 lines 13-15): each rating's global-factor
gradient ∂L/∂p^i_j must reach user i's ≤D-hop receivers, who may live on
other shards. `graph.partition_neighbor_table` pre-splits each sender row
of the (I, S) neighbor table by *destination shard* into an (I, n_shards, S)
schema, so a training step builds a fixed-shape outbox per destination —
   (weights (D, B, S), local receiver rows (D, B, S),
    gradients gp (D, B, K), item ids (D, B))
— and routes it with one `lax.all_to_all` per tensor. The receiving shard
scatter-adds ``-θ · w · gp`` into its local P rows. Weight-0 slots (receiver
on another shard, padded batch rows, padded table slots) scatter exactly
zero, so the sharded step applies precisely the same update mass as the
single-device sparse path (invariance suite: tests/test_dmf_sharded.py).

Privacy invariant (the paper's "only gradients ever leave a learner"): the
outbox is a pure function of (gp, static graph tables, item ids) — built by
`build_outbox`, which never sees ratings, u_i, or q^i. Ratings influence
other shards only through the gp messages; a learner's U/Q rows live only
on its home shard (tests/test_dmf_sharded.py::test_privacy_*).

Batch routing: the epoch's minibatch stream is the SAME stream the
single-device path samples (same rng), with each minibatch's rows routed
host-side to their user's home shard and padded to a fixed per-shard
capacity with valid=0 rows (exact no-ops, the `_sparse_batch_update`
convention). SGD batch semantics are unchanged — a minibatch's updates are
an order-free sum, so distributing its rows over shards is associativity,
not approximation (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dmf as dmf_lib
from repro.core import graph as graph_lib
from repro.core import metrics as metrics_lib
from repro.launch.mesh import shard_map

AXIS = "learners"

# jax.sharding.PartitionSpec under a second alias: inside the epoch body the
# name ``P`` is the item-factor buffer, so specs there use ``P_``.
P_ = P


def rows_per_shard(n_users: int, n_shards: int) -> int:
    return -(-n_users // n_shards)


def shard_row_slices(n_rows: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous [start, end) UNPADDED row ranges per shard under the same
    ceil-div layout as `rows_per_shard` (the trailing shards may be short or
    empty). The serving factor store's host-level row sharding
    (`serving/store.py shard_rows`) slices its HBM-resident slabs along
    these, so its request routing agrees with the SPMD engine's
    ``user // rows_per_shard`` rule."""
    rows = rows_per_shard(n_rows, n_shards)
    return [(min(d * rows, n_rows), min((d + 1) * rows, n_rows))
            for d in range(n_shards)]


@functools.lru_cache(maxsize=None)
def make_learner_mesh(n_shards: int) -> Mesh:
    """1-D ``learners`` mesh over the first n_shards local devices. On a CPU
    host, provision devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
    initializes (tests/conftest.py does this for the test suite)."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise RuntimeError(
            f"need {n_shards} devices for the learner mesh, have {len(devs)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} before jax initializes"
        )
    return Mesh(np.asarray(devs[:n_shards]), (AXIS,))


def pad_rows(x: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Zero-pad axis 0 up to n_rows (identity when already there)."""
    pad = n_rows - x.shape[0]
    if pad == 0:
        return x
    assert pad > 0, (x.shape, n_rows)
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))


def pad_state(state: dmf_lib.DMFState, n_rows: int) -> dmf_lib.DMFState:
    return dmf_lib.DMFState(
        U=pad_rows(state.U, n_rows),
        P=pad_rows(state.P, n_rows),
        Q=pad_rows(state.Q, n_rows),
    )


def unpad_state(state: dmf_lib.DMFState, n_users: int) -> dmf_lib.DMFState:
    """Slice the learner axis back to the real user count (gathers a sharded
    state onto the default device)."""
    if state.U.shape[0] == n_users:
        return state
    return dmf_lib.DMFState(
        U=jnp.asarray(state.U[:n_users]),
        P=jnp.asarray(state.P[:n_users]),
        Q=jnp.asarray(state.Q[:n_users]),
    )


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Static per-run sharding artifacts: the mesh and the
    destination-partitioned neighbor table. Build once via
    `make_shard_plan` and reuse across epochs (dmf.fit does)."""

    mesh: Mesh
    part: graph_lib.PartitionedNeighborTable
    n_shards: int

    @property
    def rows(self) -> int:
        return self.part.rows_per_shard

    @property
    def n_rows_padded(self) -> int:
        return self.part.rows_per_shard * self.n_shards


def make_shard_plan(nbr: graph_lib.NeighborTable, cfg: dmf_lib.DMFConfig) -> ShardPlan:
    part = graph_lib.partition_neighbor_table(nbr, cfg.n_shards, cfg.n_users)
    return ShardPlan(mesh=make_learner_mesh(cfg.n_shards), part=part,
                     n_shards=cfg.n_shards)


# ---------------------------------------------------------------------------
# Host-side batch routing: the single-device minibatch stream, with each
# batch's rows grouped by the sender's home shard.
# ---------------------------------------------------------------------------
def shard_batches(
    ui: np.ndarray, vj: np.ndarray, r: np.ndarray, conf: np.ndarray,
    n_shards: int, rows: int, cap_multiple: int = 32, extras=(),
):
    """Route (nb, B) minibatch rows to their user's home shard.

    Returns (ui_local, vj, r, conf, valid, rid), each (nb, n_shards, Bs)
    with Bs = max realized per-(batch, shard) row count rounded up to
    ``cap_multiple`` (a stable dispatch shape across epochs: the rounded max
    rarely moves, so the jitted epoch recompiles at most once or twice per
    run). Padded slots carry ui=0, conf=0, valid=0 — exact no-ops in the
    step. Row order inside a shard group preserves batch order, so
    n_shards=1 reproduces the single-device batch stream bit-for-bit.

    ``rid`` carries each routed row's GLOBAL stream position (batch·B +
    slot in the unsharded stream) — the DP mechanism keys its counter
    noise by it, which is what makes the noised sharded epoch invariant to
    the shard count (kernels/dp_noise.py).

    ``extras``: additional (nb, B) per-row float arrays (e.g. the churn
    path's fault gates) routed identically with fill 0, appended to the
    returned tuple in order.
    """
    nb, B = ui.shape
    shard = ui // rows                              # (nb, B)
    order = np.argsort(shard, axis=1, kind="stable")
    s_sorted = np.take_along_axis(shard, order, axis=1)
    counts = np.zeros((nb, n_shards), np.int64)
    np.add.at(counts, (np.repeat(np.arange(nb), B), shard.reshape(-1)), 1)
    Bs = int(-(-max(int(counts.max()), 1) // cap_multiple) * cap_multiple)
    start = np.concatenate(
        [np.zeros((nb, 1), np.int64), np.cumsum(counts, axis=1)[:, :-1]], axis=1)
    slot = np.arange(B)[None, :] - np.take_along_axis(start, s_sorted, axis=1)
    batch_ix = np.repeat(np.arange(nb), B)

    def route(x, fill=0):
        out = np.full((nb, n_shards, Bs), fill, x.dtype)
        xs = np.take_along_axis(x, order, axis=1)
        out[batch_ix, s_sorted.reshape(-1), slot.reshape(-1)] = xs.reshape(-1)
        return out

    ui_l = route((ui % rows).astype(np.int32))
    vj_s = route(vj.astype(np.int32))
    r_s = route(r.astype(np.float32))
    conf_s = route(conf.astype(np.float32))
    valid = (np.arange(Bs)[None, None, :] < counts[:, :, None]).astype(np.float32)
    rid = route(np.arange(nb * B, dtype=np.int32).reshape(nb, B))
    routed_extras = tuple(
        route(np.asarray(x, np.float32)) for x in extras)
    return (ui_l, vj_s, r_s, conf_s, valid, rid) + routed_extras


# ---------------------------------------------------------------------------
# The SPMD step: local Eq. 9-11 + all_to_all gradient-message exchange.
# ---------------------------------------------------------------------------
def build_outbox(gp, tbl_idx, tbl_wgt, vj):
    """Fixed-shape per-destination outbox for one minibatch on one shard.

    Pure function of the P-gradient messages ``gp (B, K)``, the *static*
    destination-partitioned graph tables ``tbl_idx/tbl_wgt (B, D, S)``
    (gathered for the batch's senders), and the batch item ids ``vj (B,)``.
    It has no access to ratings, confidences, u, or q — the privacy
    invariant "only global-factor gradients leave a learner" is structural
    here, and tests/test_dmf_sharded.py asserts the content is a function
    of gp alone (given the static tables): equal errors => equal outbox,
    whatever the ratings were.

    Returns (weights (D, B, S), local receiver rows (D, B, S),
    gradients (D, B, K), item ids (D, B)) — destination-major, ready for
    one `all_to_all` per tensor.
    """
    D = tbl_idx.shape[1]
    out_w = jnp.transpose(tbl_wgt, (1, 0, 2))
    out_i = jnp.transpose(tbl_idx, (1, 0, 2))
    out_g = jnp.broadcast_to(gp[None], (D,) + gp.shape)
    out_v = jnp.broadcast_to(vj[None], (D,) + vj.shape)
    return out_w, out_i, out_g, out_v


def _sharded_batch_update(U, P, Q, pidx, pwgt, ui, vj, r, conf, valid, noise,
                          cfg: dmf_lib.DMFConfig, prop_now=None,
                          online_local=None, byz=None, amul=None, ashill=None,
                          dirs=None, vjm=None, bkt=None, byz_cap=0,
                          tele=False):
    """One minibatch of Alg. 1 on one shard: local gathers + Eq. 9-11 via
    the SAME `dmf._step_deltas` as the single-device paths (the equivalence
    suite leans on that), local U/Q scatters, and the cross-shard P-gradient
    exchange.

    Noise-before-routing (DESIGN.md §9): with DP on, the clip+noise
    mechanism runs on ``gp`` HERE — before `build_outbox` and the
    `all_to_all` — so what crosses the shard boundary is already the
    noised message; no shard ever holds a peer's raw gradient. ``noise``
    is the batch rows' pre-scaled σC block, gathered from the epoch's
    counter-stream draw by each row's GLOBAL stream id — bit-identical to
    what the single-device scan adds, whatever shard the row landed on.
    The PR 3 privacy invariant (outbox = pure function of the message +
    static tables) is preserved with ``gp`` simply replaced by its DP
    release.

    Fault gates (robustness/faults.py; both None on the fault-free path):
    ``prop_now`` (B,) restricts a straggler row's scatter to the sender's
    own self slot (dest shard == me AND local row == sender), pre-outbox —
    its neighbor deliveries come from the delay ring later; ``online_local``
    (rows,) zeroes received weights into this shard's offline rows.
    Returns the released message block ``gp`` too (the churn epoch buffers
    it); the fault-free epoch discards it.

    Byzantine path (``byz`` a static `DefenseConfig`; None = untouched
    trace, see `dmf._sparse_batch_update_messages`): the sender's line-11
    self update stays honest and pre-outbox; outgoing messages are
    corrupted per the routed attack arrays BEFORE `build_outbox` (what
    crosses the wire is the corrupted release — the outbox purity
    invariant holds with gp replaced by the adversary's choice), screened
    on the RECEIVING shard after the `all_to_all` (each shard defends
    itself), and robust-combined per (receiver, item) bucket when
    ``byz.aggregation != "sum"`` (``bkt`` the host-compiled per-shard
    `MessageGroups` arrays in received-slot order).

    Telemetry (``tele``, static; obs/telemetry.py): when True a sixth
    return value carries this shard's TELE_W read-only reductions —
    message counts are RECEIVED deliveries (post fault gates), so each
    shard's slot 4 is "messages routed to me" and the shard sum matches
    the single-device delivery count. False (the default) traces none of
    it — the compiled program is unchanged."""
    theta = cfg.lr
    if cfg.dp and cfg.mode != "ldmf":
        du, gp, dq, loss = dmf_lib._step_deltas_dp(
            U, P, Q, ui, vj, r, conf, cfg, valid, noise)
    else:
        du, gp, dq, loss = dmf_lib._step_deltas(
            U, P, Q, ui, vj, r, conf, cfg, valid)
    U = U.at[ui].add(du)
    if cfg.mode != "gdmf":
        Q = Q.at[ui, vj].add(dq)
    if tele:
        z = jnp.zeros((), du.dtype)
        u_sq = jnp.sum(du * du)
        q_sq = jnp.sum(dq * dq) if cfg.mode != "gdmf" else z
    if cfg.mode == "ldmf":
        if tele:   # purely local: nothing released, nothing scattered
            return U, P, Q, loss, gp, jnp.stack(
                [u_sq, q_sq, z, z, z, z, z])
        return U, P, Q, loss, gp
    if byz is None:
        # lines 11 + 13-15 across shards: gather the batch senders' rows of
        # the destination-partitioned table, exchange, scatter locally.
        pi, pw = pidx[ui], pwgt[ui]                  # (B, D, S)
        if prop_now is not None:
            me = jax.lax.axis_index(AXIS)
            D = pi.shape[1]
            selfm = ((jnp.arange(D)[None, :, None] == me)
                     & (pi == ui[:, None, None])).astype(pw.dtype)
            pw = pw * jnp.maximum(prop_now[:, None, None], selfm)
        out_w, out_i, out_g, out_v = build_outbox(gp, pi, pw, vj)
        rw = jax.lax.all_to_all(out_w, AXIS, 0, 0)   # (D, B, S) source-major
        ri = jax.lax.all_to_all(out_i, AXIS, 0, 0)
        rg = jax.lax.all_to_all(out_g, AXIS, 0, 0)   # (D, B, K)
        rv = jax.lax.all_to_all(out_v, AXIS, 0, 0)   # (D, B)
        if online_local is not None:
            rw = rw * online_local[ri]               # offline receivers get 0
        upd = rw[..., None] * rg[:, :, None, :]      # (D, B, S, K)
        P = P.at[ri, rv[:, :, None]].add(-theta * upd)
        if tele:
            me = jax.lax.axis_index(AXIS)
            D = rw.shape[0]
            # received self slots (source shard == me, receiver == sender)
            # don't count as routed messages — matches the single-device
            # neighbor-delivery count when summed over shards
            selfr = ((jnp.arange(D)[:, None, None] == me)
                     & (ri == ui[None, :, None])).astype(rw.dtype)
            n_msgs = jnp.sum((rw * (1.0 - selfr) > 0).astype(rw.dtype))
            gp2r = jnp.sum(rg * rg, axis=-1)         # (D, B)
            scatter_sq = theta * theta * jnp.sum(
                gp2r * jnp.sum(rw * rw, axis=-1))
            return U, P, Q, loss, gp, jnp.stack(
                [u_sq, q_sq, jnp.sum(gp * gp), scatter_sq, n_msgs, z, z])
        return U, P, Q, loss, gp
    from repro.robustness import byzantine as byz_lib
    K = gp.shape[-1]
    pi, pw = pidx[ui], pwgt[ui]                      # (B, D, S)
    me = jax.lax.axis_index(AXIS)
    D = pi.shape[1]
    selfm = ((jnp.arange(D)[None, :, None] == me)
             & (pi == ui[:, None, None])).astype(pw.dtype)
    w_self = jnp.sum(pw * selfm, axis=(1, 2))
    if online_local is not None:
        w_self = w_self * online_local[ui]
    P = P.at[ui, vj].add(-theta * w_self[:, None] * gp)
    pw_msg = pw * (1.0 - selfm)
    if prop_now is not None:
        pw_msg = pw_msg * prop_now[:, None, None]
    gp_sent = gp
    if amul is not None:
        gp_sent = byz_lib.corrupt_messages(gp, amul, ashill, dirs[ui])
    vj_out = vjm if vjm is not None else vj
    out_w, out_i, out_g, out_v = build_outbox(gp_sent, pi, pw_msg, vj_out)
    rw = jax.lax.all_to_all(out_w, AXIS, 0, 0)       # (D, B, S) source-major
    ri = jax.lax.all_to_all(out_i, AXIS, 0, 0)
    rg = jax.lax.all_to_all(out_g, AXIS, 0, 0)       # (D, B, K)
    rv = jax.lax.all_to_all(out_v, AXIS, 0, 0)       # (D, B)
    if online_local is not None:
        rw = rw * online_local[ri]
    rw_pre = rw   # pre-screen delivery weights (telemetry baseline)
    if byz.screen:
        ok = byz_lib.screen_ok(rg, byz.norm_cap)     # (D, B)
        rg = jnp.where(ok[..., None] > 0, rg, 0.0)
        rw = rw * ok[:, :, None]
    # 0·NaN = NaN: zero-weight slots must deliver exactly 0 even when the
    # (undefended) message content is a bomb. With screening on, rg is
    # already zeroed wherever it was non-finite, so the plain multiply is
    # safe — and ±0 contributions leave the scatter-add bitwise unchanged.
    if byz.screen:
        upd = rw[..., None] * rg[:, :, None, :]
    else:
        upd = jnp.where((rw > 0)[..., None],
                        rw[..., None] * rg[:, :, None, :], 0.0)
    if byz.aggregation == "sum":
        P = P.at[ri, rv[:, :, None]].add(-theta * upd)
        scat = upd
    else:
        b_id, b_pos, b_recv, b_item = bkt
        vals = upd.reshape(-1, K)                    # (D·B·S, K) recv order
        validity = (rw > 0).astype(gp.dtype).reshape(-1)
        comb = byz_lib.robust_combine(
            vals, validity, b_id.reshape(-1), b_pos.reshape(-1),
            b_recv.shape[-1], byz_cap, byz)
        P = P.at[b_recv, b_item].add(-theta * comb)
        scat = comb
    if tele:
        n_pre = jnp.sum((rw_pre > 0).astype(pw.dtype))   # attempted
        n_post = jnp.sum((rw > 0).astype(pw.dtype))      # survived screen
        self_sq = jnp.sum((w_self[:, None] * gp) ** 2)
        scatter_sq = theta * theta * (self_sq + jnp.sum(scat * scat))
        return U, P, Q, loss, gp_sent, jnp.stack(
            [u_sq, q_sq, jnp.sum(gp_sent * gp_sent), scatter_sq,
             n_pre, n_post, n_pre - n_post])
    return U, P, Q, loss, gp_sent


@functools.partial(
    jax.jit, static_argnames=("cfg", "mesh", "tele"), donate_argnums=(0, 1, 2))
def _epoch_sharded(U, P, Q, pidx, pwgt, ui, vj, r, conf, valid, rid, dp_seed,
                   cfg, mesh, tele: bool = False):
    """A full epoch as ONE SPMD dispatch: shard_map over the learner axis,
    `lax.scan` over minibatches inside. Inputs: U (I_pad, K), P/Q
    (I_pad, J, K), tables (I_pad, D, S), batches (nb, D, Bs), plus the
    routed global stream ids ``rid`` (nb, D, Bs) and the per-epoch traced
    ``dp_seed`` keying the DP noise (dead inputs when DP is off). With DP
    noise on, every shard draws the SAME full-epoch noise block from the
    counter stream (one vectorized pass, replicated compute — noise is
    (n, K), small next to P) and gathers its routed rows' slices by rid:
    bit-identical noise to the single-device scan for every row, any mesh
    width. Returns the updated factors and per-(batch, shard) losses
    (nb, D)."""
    from repro.privacy import mechanism
    noise_on = cfg.dp and cfg.mode != "ldmf" and mechanism.noise_std(cfg) > 0

    def shard_body(U, P, Q, pidx, pwgt, ui, vj, r, conf, valid, rid, dp_seed):
        ui, vj, r, conf, valid, rid = (
            x[:, 0] for x in (ui, vj, r, conf, valid, rid))
        if noise_on:
            from repro.kernels.dp_noise import gauss_counter
            nb = ui.shape[0]
            K = U.shape[-1]
            all_rid = jnp.arange(
                nb * cfg.batch_size, dtype=jnp.int32).reshape(-1, 1)
            Z = mechanism.noise_std(cfg) * gauss_counter(dp_seed, all_rid, K)

        def body(carry, batch):
            U, P, Q = carry
            b_ui, b_vj, b_r, b_conf, b_val, b_rid = batch
            out = _sharded_batch_update(
                U, P, Q, pidx, pwgt, b_ui, b_vj, b_r, b_conf, b_val,
                Z[b_rid] if noise_on else None, cfg, tele=tele)
            if tele:
                U, P, Q, loss, _, tvec = out
                return (U, P, Q), (loss, tvec)
            U, P, Q, loss, _ = out
            return (U, P, Q), loss

        (U, P, Q), ys = jax.lax.scan(
            body, (U, P, Q), (ui, vj, r, conf, valid, rid))
        if tele:
            losses, tvecs = ys
            # (1, TELE_W) per shard -> (D, TELE_W) at the out spec
            return U, P, Q, losses[:, None], tvecs.sum(axis=0)[None]
        return U, P, Q, ys[:, None]

    out_specs = (P_(AXIS), P_(AXIS), P_(AXIS), P_(None, AXIS))
    if tele:
        out_specs += (P_(AXIS),)
    return shard_map(
        shard_body, mesh=mesh,
        in_specs=(P_(AXIS), P_(AXIS), P_(AXIS), P_(AXIS), P_(AXIS),
                  P_(None, AXIS), P_(None, AXIS), P_(None, AXIS),
                  P_(None, AXIS), P_(None, AXIS), P_(None, AXIS), P_()),
        out_specs=out_specs,
        check_vma=False,
    )(U, P, Q, pidx, pwgt, ui, vj, r, conf, valid, rid, dp_seed)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mesh", "use_ring", "byz", "use_attack",
                     "byz_cap", "tele"),
    donate_argnums=(0, 1, 2))
def _epoch_sharded_churn(U, P, Q, pidx, pwgt, dpidx, dpwgt, ui, vj, r, conf,
                         valid, rid, prop_now, online, ring_gp, ring_ui,
                         ring_vj, ring_deliver, dp_seed, amul, ashill, vjm,
                         dirs, b_id, b_pos, b_recv, b_item, cfg, mesh,
                         use_ring, byz=None, use_attack=False, byz_cap=0,
                         tele: bool = False):
    """`_epoch_sharded` under a fault schedule — STILL one SPMD dispatch.

    Extra inputs: the fault gates (``prop_now`` routed like the batches,
    ``online`` (I_pad,) row-sharded), the SAME partitioned table a second
    time sharded by DESTINATION (``dpidx``/``dpwgt`` with spec
    P(None, learners) → each shard holds every sender's receiver-list
    destined for ITS rows — what stale-message delivery needs, no comms),
    and the replicated delay-ring content. Start-of-epoch delivery scatters
    each due buffered message into the local P rows (neighbor slots only,
    receiver-online gated). The epoch's released messages are re-assembled
    into a replicated (n, K) stream block for the ring: each shard scatters
    its routed rows' gp by global stream id, then one `psum` (padded rows
    carry gp=0/rid=0 — they add zero). Returns (U, P, Q, losses, block).

    Under the trivial schedule (gates all ones, ``use_ring=False``) every
    fault op multiplies by 1.0 — the outputs are bitwise `_epoch_sharded`'s.

    Byzantine args (``byz``/``use_attack``/``byz_cap`` static; attack
    arrays routed like the batches, ``dirs`` row-sharded, bucket arrays in
    per-destination received-slot order with spec P(None, learners)):
    with ``byz=None`` every one is a statically dead input and the trace
    is unchanged. Ring messages are screened AT DELIVERY on the receiving
    shard — stale corrupted messages don't dodge the gate."""
    from repro.privacy import mechanism
    noise_on = cfg.dp and cfg.mode != "ldmf" and mechanism.noise_std(cfg) > 0
    theta = cfg.lr
    robust = byz is not None and byz.aggregation != "sum"

    def shard_body(U, P, Q, pidx, pwgt, dpidx, dpwgt, ui, vj, r, conf, valid,
                   rid, prop_now, online, ring_gp, ring_ui, ring_vj,
                   ring_deliver, dp_seed, amul, ashill, vjm, dirs, b_id,
                   b_pos, b_recv, b_item):
        ui, vj, r, conf, valid, rid, prop_now = (
            x[:, 0] for x in (ui, vj, r, conf, valid, rid, prop_now))
        rows = U.shape[0]
        K = U.shape[-1]
        me = jax.lax.axis_index(AXIS)
        if use_ring:
            # deliver the buffered messages due THIS epoch into local P rows
            gflat = ring_gp.reshape(-1, K)               # (L·n, K)
            di = dpidx[ring_ui, 0]                       # (L·n, S) local rows
            dw = dpwgt[ring_ui, 0]
            selfm = ((me * rows + di) == ring_ui[:, None]).astype(dw.dtype)
            dw = (dw * (1.0 - selfm) * online[di]
                  * ring_deliver[:, None])
            if byz is not None:
                from repro.robustness import byzantine as byz_lib
                if byz.screen:
                    okd = byz_lib.screen_ok(gflat, byz.norm_cap)
                    gflat = jnp.where(okd[:, None] > 0, gflat, 0.0)
                    dw = dw * okd[:, None]
                dupd = jnp.where((dw > 0)[:, :, None],
                                 dw[:, :, None] * gflat[:, None, :], 0.0)
            else:
                dupd = dw[:, :, None] * gflat[:, None, :]
            P = P.at[di, ring_vj[:, None]].add(-theta * dupd)
        if noise_on:
            from repro.kernels.dp_noise import gauss_counter
            nb = ui.shape[0]
            all_rid = jnp.arange(
                nb * cfg.batch_size, dtype=jnp.int32).reshape(-1, 1)
            Z = mechanism.noise_std(cfg) * gauss_counter(dp_seed, all_rid, K)

        xs = [ui, vj, r, conf, valid, rid, prop_now]
        if use_attack:
            xs += [amul[:, 0], ashill[:, 0]]
        if byz is not None:
            xs.append(vjm[:, 0])
        if robust:
            xs += [b_id[:, 0], b_pos[:, 0], b_recv[:, 0], b_item[:, 0]]

        def body(carry, batch):
            U, P, Q = carry
            b_ui, b_vj, b_r, b_conf, b_val, b_rid, b_prop = batch[:7]
            i = 7
            b_amul = b_ashill = b_vjm = bkt = None
            if use_attack:
                b_amul, b_ashill = batch[i], batch[i + 1]
                i += 2
            if byz is not None:
                b_vjm = batch[i]
                i += 1
            if robust:
                bkt = batch[i:i + 4]
            out = _sharded_batch_update(
                U, P, Q, pidx, pwgt, b_ui, b_vj, b_r, b_conf, b_val,
                Z[b_rid] if noise_on else None, cfg,
                prop_now=b_prop, online_local=online, byz=byz,
                amul=b_amul, ashill=b_ashill,
                dirs=dirs if use_attack else None, vjm=b_vjm, bkt=bkt,
                byz_cap=byz_cap, tele=tele)
            if tele:
                U, P, Q, loss, gp, tvec = out
            else:
                U, P, Q, loss, gp = out
            y = [loss]
            if use_ring:
                y.append(gp)
            if tele:
                y.append(tvec)
            return (U, P, Q), (tuple(y) if len(y) > 1 else y[0])

        (U, P, Q), ys = jax.lax.scan(body, (U, P, Q), tuple(xs))
        tvecs = None
        if tele:
            ys, tvecs = (ys[:-1], ys[-1])
            ys = ys if use_ring else ys[0]
        if use_ring:
            losses, gps = ys
            # replicated released-message stream block for the delay ring:
            # scatter-add my rows by global stream id, psum across shards
            n_stream = ui.shape[0] * cfg.batch_size
            blk = jnp.zeros((n_stream, K), gps.dtype)
            blk = blk.at[rid.reshape(-1)].add(gps.reshape(-1, K))
            blk = jax.lax.psum(blk, AXIS)
        else:
            losses = ys
            blk = jnp.zeros((1, K), jnp.float32)
        ret = (U, P, Q, losses[:, None], blk)
        if tele:
            # (1, TELE_W) per shard -> (D, TELE_W) at the out spec
            ret += (tvecs.sum(axis=0)[None],)
        return ret

    out_specs = (P_(AXIS), P_(AXIS), P_(AXIS), P_(None, AXIS), P_())
    if tele:
        out_specs += (P_(AXIS),)
    return shard_map(
        shard_body, mesh=mesh,
        in_specs=(P_(AXIS), P_(AXIS), P_(AXIS), P_(AXIS), P_(AXIS),
                  P_(None, AXIS), P_(None, AXIS),
                  P_(None, AXIS), P_(None, AXIS), P_(None, AXIS),
                  P_(None, AXIS), P_(None, AXIS), P_(None, AXIS),
                  P_(None, AXIS), P_(AXIS),
                  P_(), P_(), P_(), P_(), P_(),
                  P_(None, AXIS), P_(None, AXIS), P_(None, AXIS), P_(AXIS),
                  P_(None, AXIS), P_(None, AXIS), P_(None, AXIS),
                  P_(None, AXIS)),
        out_specs=out_specs,
        check_vma=False,
    )(U, P, Q, pidx, pwgt, dpidx, dpwgt, ui, vj, r, conf, valid, rid,
      prop_now, online, ring_gp, ring_ui, ring_vj, ring_deliver, dp_seed,
      amul, ashill, vjm, dirs, b_id, b_pos, b_recv, b_item)


def train_epoch_churn_sharded(
    state: dmf_lib.DMFState,
    prop,
    train: np.ndarray,
    cfg: dmf_lib.DMFConfig,
    rng: np.random.Generator,
    t: int,
    schedule,                   # robustness.faults.ChurnPlan
    ring,                       # robustness.faults.DelayRing | None
    accountant=None,
    attack=None,                # robustness.byzantine.AttackPlan | None
    byz=None,                   # robustness.byzantine.DefenseConfig | None
    tele: bool = False,         # append the (n_shards, TELE_W) device stats
) -> tuple[dmf_lib.DMFState, float]:
    """Sharded counterpart of `dmf.train_epoch_churn`: the same sampled
    stream and fault gates (host-side, shard-count-independent), rows and
    gates routed to home shards, one SPMD dispatch per epoch. The delay
    ring is replicated — its written content is the psum-assembled global
    released-message stream, so a run's ring state is invariant to the
    mesh width (and a resume can switch shard counts).

    ``attack``/``byz`` mirror the single-device path: the attack arrays
    are realized on the ROUTED stream (same per-(user, epoch) corruption,
    whatever shard a row landed on), message-bucket membership is compiled
    per destination shard in received-slot order, and screening decisions
    depend only on message content + τ — all shard-count invariant
    (tests/test_byzantine.py pins defended runs across mesh widths)."""
    plan = _as_plan(prop, cfg)
    ui, vj, r, conf = dmf_lib.sample_epoch(train, cfg, rng)
    B = cfg.batch_size
    nb = len(ui) // B
    n = nb * B
    shape = (nb, B)
    ui2 = ui[:n].reshape(shape)
    vj2 = vj[:n].reshape(shape)
    _, dp_seed = dmf_lib.epoch_dp_inputs(cfg, rng, n)
    on, sender_on, prop_now, due = schedule.epoch_row_masks(t, ui2)
    conf2 = conf[:n].reshape(shape) * sender_on
    if accountant is not None:
        accountant.observe_epoch(ui2, valid=sender_on)
    ui_l, vj_s, r_s, conf_s, valid, rid, son_s, pnow_s = shard_batches(
        ui2, vj2, r[:n].reshape(shape), conf2, cfg.n_shards, plan.rows,
        extras=(sender_on, prop_now))
    valid = valid * son_s       # offline senders' routed rows are inert
    online_pad = np.zeros(plan.n_rows_padded, np.float32)
    online_pad[: schedule.n_users] = on
    use_ring = ring is not None
    if use_ring:
        r_ui = ring.ui.reshape(-1)
        r_vj = ring.vj.reshape(-1)
        r_del = (ring.due.reshape(-1) == t).astype(np.float32)
        ring_gp = ring.gp
    else:  # statically-skipped dummies (dead jit inputs)
        r_ui = np.zeros(1, np.int32)
        r_vj = np.zeros(1, np.int32)
        r_del = np.zeros(1, np.float32)
        ring_gp = jnp.zeros((1, 1, cfg.dim), jnp.float32)
    use_attack = attack is not None
    K = cfg.dim
    if use_attack:
        assert byz is not None
        # realize the attack on the routed stream by GLOBAL user id —
        # identical per-(user, epoch) corruption at every mesh width;
        # padded slots are forced honest via the routed validity
        gl_ui = (np.arange(cfg.n_shards)[None, :, None] * plan.rows
                 + ui_l).astype(np.int64)
        amul, ashill, vjm = attack.epoch_row_attack(
            t, gl_ui, vj_s, sender_on=(valid > 0))
        # the ring buffers the UNSHARDED stream: same realization there
        amul_g, ashill_g, vjm_g = attack.epoch_row_attack(
            t, ui2, vj2, sender_on=sender_on)
        dirs_pad = np.zeros((plan.n_rows_padded, K), np.float32)
        dirs_pad[: schedule.n_users] = attack.dirs
        dirs = jnp.asarray(dirs_pad)
    else:
        amul = ashill = np.zeros((1, cfg.n_shards, 1), np.float32)
        vjm = vj_s
        vjm_g = vj2
        dirs = jnp.zeros((cfg.n_shards, K), jnp.float32)
    robust = byz is not None and byz.aggregation != "sum"
    if robust:
        from repro.robustness import byzantine as byz_lib
        groups = byz_lib.group_messages_sharded(
            ui_l, vjm, valid, plan.part.idx, plan.part.wgt, plan.rows,
            cfg.n_shards, cfg.n_items, prop_now=pnow_s, online=online_pad)
        gb = (jnp.asarray(groups.bucket_id), jnp.asarray(groups.pos),
              jnp.asarray(groups.recv), jnp.asarray(groups.item))
        byz_cap = groups.cap
    else:
        z3 = np.zeros((1, cfg.n_shards, 1), np.int32)
        gb = (z3, z3, z3, z3)
        byz_cap = 0
    st = shard_state(state, plan)
    out = _epoch_sharded_churn(
        st.U, st.P, st.Q, plan.part.idx, plan.part.wgt,
        plan.part.idx, plan.part.wgt,
        jnp.asarray(ui_l), jnp.asarray(vj_s), jnp.asarray(r_s),
        jnp.asarray(conf_s), jnp.asarray(valid), jnp.asarray(rid),
        jnp.asarray(pnow_s), jnp.asarray(online_pad),
        ring_gp, jnp.asarray(r_ui), jnp.asarray(r_vj), jnp.asarray(r_del),
        jnp.asarray(dp_seed, jnp.int32),
        jnp.asarray(amul), jnp.asarray(ashill), jnp.asarray(vjm), dirs,
        gb[0], gb[1], gb[2], gb[3],
        cfg, plan.mesh, use_ring, byz, use_attack, byz_cap, tele=tele)
    U, Pm, Q, losses, blk = out[:5]
    if use_ring:
        ring.write(t, blk, ui2, vjm_g if byz is not None else vj2, due)
    total = float(np.asarray(losses, dtype=np.float64).sum())
    realized = int(sender_on.sum())
    l = total / max(realized, 1)
    if tele:
        return dmf_lib.DMFState(U, Pm, Q), l, np.asarray(out[5])
    return dmf_lib.DMFState(U, Pm, Q), l


def _as_plan(prop, cfg: dmf_lib.DMFConfig) -> ShardPlan:
    if isinstance(prop, ShardPlan):
        assert prop.n_shards == cfg.n_shards, (prop.n_shards, cfg.n_shards)
        return prop
    if not isinstance(prop, graph_lib.NeighborTable):
        prop = graph_lib.neighbor_table_from_dense(np.asarray(prop))
    return make_shard_plan(prop, cfg)


def shard_state(state: dmf_lib.DMFState, plan: ShardPlan) -> dmf_lib.DMFState:
    """Pad the learner axis to the mesh and place each factor with its
    row sharding (no-op if already padded; re-placement is cheap then)."""
    sh = NamedSharding(plan.mesh, P(AXIS))
    st = pad_state(state, plan.n_rows_padded)
    return dmf_lib.DMFState(
        U=jax.device_put(st.U, sh),
        P=jax.device_put(st.P, sh),
        Q=jax.device_put(st.Q, sh),
    )


def train_epoch_sharded(
    state: dmf_lib.DMFState,
    prop,                       # ShardPlan | graph.NeighborTable | dense M
    train: np.ndarray,
    cfg: dmf_lib.DMFConfig,
    rng: np.random.Generator,
    accountant=None,
    tele: bool = False,         # append the (n_shards, TELE_W) device stats
) -> tuple[dmf_lib.DMFState, float]:
    """Sharded counterpart of `dmf.train_epoch`: identical minibatch stream
    (same rng consumption — the per-epoch DP seed draw included, so DP-on
    noise matches the single-device epoch bit-for-bit), rows routed to home
    shards, one SPMD dispatch. Returns a state whose learner axis stays
    padded+sharded across epochs (donated buffers, no per-epoch host
    round-trip); slice with `unpad_state` when done — `dmf.fit` does both
    automatically. ``accountant`` observes the realized stream like the
    single-device path (ε accounting is shard-count-independent)."""
    plan = _as_plan(prop, cfg)
    ui, vj, r, conf = dmf_lib.sample_epoch(train, cfg, rng)
    B = cfg.batch_size
    nb = len(ui) // B
    n = nb * B
    shape = (nb, B)
    _, dp_seed = dmf_lib.epoch_dp_inputs(cfg, rng, n)
    if accountant is not None:
        accountant.observe_epoch(ui[:n].reshape(shape))
    ui_l, vj_s, r_s, conf_s, valid, rid = shard_batches(
        ui[:n].reshape(shape), vj[:n].reshape(shape),
        r[:n].reshape(shape), conf[:n].reshape(shape),
        cfg.n_shards, plan.rows)
    st = shard_state(state, plan)
    out = _epoch_sharded(
        st.U, st.P, st.Q, plan.part.idx, plan.part.wgt,
        jnp.asarray(ui_l), jnp.asarray(vj_s), jnp.asarray(r_s),
        jnp.asarray(conf_s), jnp.asarray(valid), jnp.asarray(rid),
        jnp.asarray(dp_seed, jnp.int32), cfg, plan.mesh, tele=tele)
    U, Pm, Q, losses = out[:4]
    total = float(np.asarray(losses, dtype=np.float64).sum())
    l = total / max(n, 1)
    if tele:
        return dmf_lib.DMFState(U, Pm, Q), l, np.asarray(out[4])
    return dmf_lib.DMFState(U, Pm, Q), l


# ---------------------------------------------------------------------------
# Sharded evaluation: per-user top-k is row-parallel — no communication.
# ---------------------------------------------------------------------------
def evaluate_sharded(
    state: dmf_lib.DMFState, train: np.ndarray, test: np.ndarray,
    n_users: int, n_items: int, n_shards: int, ks=(5, 10),
    interpret: bool = True, chunk_users: int | None = None,
) -> dict[str, float]:
    """`dmf.evaluate` over the learner mesh: each shard streams its own
    users' (rows, J, K) factors through the per-user top-k kernel; results
    concatenate along the learner axis. Bit-identical to the single-device
    kernel per user (row-parallel, no cross-shard reads).

    ``chunk_users`` bounds the per-shard rows staged per dispatch: the
    evaluation walks local row windows of that width across all shards at
    once, building each window's V = P + Q view and train/test mask rows on
    the fly — the full (I, J, K) V and (I, J) masks never co-materialize
    with the factors. Results are identical to the unchunked path (per-user
    hit counts are integers, reduced once at the end)."""
    from repro.kernels import ops

    mesh = make_learner_mesh(n_shards)
    rows = rows_per_shard(n_users, n_shards)
    I_pad = rows * n_shards
    kmax = max(ks)
    st = unpad_state(state, n_users)

    def body(U_loc, V_loc, m_loc):
        return ops.recommend_topk_peruser(
            U_loc, V_loc, m_loc, kmax, interpret=interpret)

    dispatch = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
        check_vma=False,
    ))

    if chunk_users is None:
        train_mask = metrics_lib.masks_from_interactions(
            n_users, n_items, train)
        test_mask = metrics_lib.masks_from_interactions(n_users, n_items, test)
        U = pad_rows(st.U, I_pad)
        V = pad_rows(st.P + st.Q, I_pad)
        mask = pad_rows(jnp.asarray(train_mask.astype(np.int8)), I_pad)
        _, idx = dispatch(U, V, mask)
        return metrics_lib.evaluate_ranking_from_topk(
            np.asarray(idx)[:n_users], test_mask, ks)

    rc = min(max(int(chunk_users), 1), rows)
    hits: dict[int, list[np.ndarray]] = {k: [] for k in ks}
    n_test_parts: list[np.ndarray] = []
    order_parts: list[np.ndarray] = []
    for t in range(0, rows, rc):
        width = min(rc, rows - t)
        U_parts, V_parts, m_parts, ts_parts, gids = [], [], [], [], []
        for d in range(n_shards):
            g0 = d * rows + t
            ids = np.arange(g0, g0 + width)
            safe = jnp.asarray(np.minimum(ids, max(n_users - 1, 0)))
            U_parts.append(st.U[safe])
            V_parts.append(st.P[safe] + st.Q[safe])
            m_parts.append(metrics_lib.masks_from_interactions_rows(
                g0, width, n_items, train))
            ts_parts.append(metrics_lib.masks_from_interactions_rows(
                g0, width, n_items, test))
            gids.append(ids)
        _, idx = dispatch(
            jnp.concatenate(U_parts), jnp.concatenate(V_parts),
            jnp.asarray(np.concatenate(m_parts).astype(np.int8)))
        rec = np.asarray(idx)
        ts = np.concatenate(ts_parts)
        ids = np.concatenate(gids)
        real = ids < n_users
        for k in ks:
            hits[k].append(metrics_lib.topk_hits(rec, ts, k)[real])
        n_test_parts.append(ts.sum(axis=1)[real])
        order_parts.append(ids[real])
    # windows interleave shards — restore global user order so the float
    # reduction matches the unchunked mean exactly
    order = np.argsort(np.concatenate(order_parts), kind="stable")
    n_test = np.concatenate(n_test_parts)[order]
    out = {}
    for k in ks:
        p, r = metrics_lib.precision_recall_from_hits(
            np.concatenate(hits[k])[order], n_test, k)
        out[f"P@{k}"] = p
        out[f"R@{k}"] = r
    return out
