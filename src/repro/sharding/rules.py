"""Logical-axis -> mesh-axis resolution.

``init_params`` returns a spec tree whose leaves are tuples of logical axis
names (one per tensor dim, or None). This module resolves them into
``PartitionSpec``s for a concrete mesh, with a divisibility fallback: a dim
whose size does not divide the target mesh-axis size is replicated (e.g.
yi-34b's 56 heads or minicpm3's 73448 vocab on a 16-wide model axis — the
fallback is recorded by the dry-run and padding them is a §Perf item).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical name -> mesh axis (train rules; "embed" is the FSDP dim)
LOGICAL_RULES: dict[str, str | None] = {
    "embed": "data",          # FSDP: weights gathered per layer
    "embed_nodiv": None,      # embed-sized dims kept replicated (norms, router)
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "expert_ff": None,        # serve weight-stationary mode pins this to data
    "vocab": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
}

# §Perf layout variants (see EXPERIMENTS.md):
#   pure data-parallel over the whole mesh for small dense models — removes
#   tensor-parallel activation all-reduces; batch spans (data, model)
DP_OVERRIDES = {
    "embed": ("data", "model"),
    "ff": None, "heads": None, "kv_heads": None, "vocab": None,
    "ssm_inner": None, "ssm_heads": None, "experts": None,
}
#   weight-stationary serving — weights resident (no FSDP gather); MoE
#   expert hidden dim sharded over data (moe_ffn_sharded's ws path)
SERVE_WS_OVERRIDES = {"embed": None, "expert_ff": "data"}


def resolve_spec(
    logical: tuple, shape: tuple[int, ...], mesh, *, fsdp: bool = True,
    overrides: dict | None = None,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible dims."""
    out = []
    for name, size in zip(logical, shape):
        if name and name.startswith("__mesh__"):   # direct mesh-axis pin
            ax = name[len("__mesh__"):]
        elif overrides and name in overrides:
            ax = overrides[name]
        else:
            ax = LOGICAL_RULES.get(name) if name else None
        if ax == "data" and not fsdp and not (overrides and name in overrides):
            ax = None
        axs = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if not axs or any(a not in mesh.axis_names for a in axs):
            out.append(None)
            continue
        n = 1
        for a in axs:
            n *= mesh.shape[a]
        if size % n != 0:
            out.append(None)     # divisibility fallback -> replicate
            continue
        out.append(ax)
    return P(*out)


def params_pspecs(spec_tree, params_tree, mesh, *, fsdp: bool = True,
                  overrides: dict | None = None):
    """Pytree of PartitionSpec aligned with params."""
    is_leaf = lambda s: isinstance(s, tuple) and all(
        isinstance(x, str) or x is None for x in s
    )
    flat_specs, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_leaf)
    flat_params = jax.tree_util.tree_leaves(params_tree)
    assert len(flat_specs) == len(flat_params), (
        len(flat_specs), len(flat_params),
    )
    resolved = [
        resolve_spec(s, p.shape, mesh, fsdp=fsdp, overrides=overrides)
        for s, p in zip(flat_specs, flat_params)
    ]
    return jax.tree_util.tree_unflatten(treedef, resolved)


def params_shardings(spec_tree, params_tree, mesh, *, fsdp: bool = True,
                     overrides: dict | None = None):
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps),
        params_pspecs(spec_tree, params_tree, mesh, fsdp=fsdp, overrides=overrides),
        is_leaf=lambda x: isinstance(x, P),
    )
