"""Optimizers from scratch (optax is not available offline).

Pure-functional, pytree-based, optax-like API:

    opt = adamw(lr=1e-3, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All states are pytrees so they pjit/shard_map transparently (each state
leaf inherits the sharding of its parameter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), ())

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        upd = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
        return upd, OptState(step, ())

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return OptState(
            jnp.zeros((), jnp.int32),
            jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree_util.tree_map(
            lambda mo, g: beta * mo + g.astype(jnp.float32), state.inner, grads
        )
        if nesterov:
            upd = jax.tree_util.tree_map(lambda mo, g: -lr_t * (beta * mo + g), m, grads)
        else:
            upd = jax.tree_util.tree_map(lambda mo: -lr_t * mo, m)
        return upd, OptState(step, m)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip_norm: float | None = 1.0,
    mask: Callable | None = None,   # path-predicate: apply weight decay?
) -> Optimizer:
    """AdamW with global-norm clipping and decoupled weight decay.

    Optimizer moments are f32 regardless of param dtype (mixed-precision
    convention: bf16 params / f32 master-state handled by the caller).
    """

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return OptState(
            jnp.zeros((), jnp.int32),
            AdamState(
                mu=jax.tree_util.tree_map(zeros, params),
                nu=jax.tree_util.tree_map(zeros, params),
            ),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip_norm is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
            )
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.inner.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.inner.nu, grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if mask is None:
            upd = jax.tree_util.tree_map(_upd, mu, nu, params)
        else:
            # decay only where mask(path) is True
            flat, treedef = jax.tree_util.tree_flatten_with_path(params)
            gm = jax.tree_util.tree_leaves(mu)
            gv = jax.tree_util.tree_leaves(nu)
            upds = []
            for (path, p), m, v in zip(flat, gm, gv):
                wd = weight_decay if mask(path) else 0.0
                u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
                if wd:
                    u = u - lr_t * wd * p.astype(jnp.float32)
                upds.append(u)
            upd = jax.tree_util.tree_unflatten(treedef, upds)
        return upd, OptState(step, AdamState(mu, nu))

    return Optimizer(init, update)
