# Scheduling subsystem: continuous-batching request serving over ServingEngine.
#   workload.py  — arrival-process load generators (Poisson, bursty on/off,
#                  trace replay; uniform / power-law user popularity) + CLI
#   scheduler.py — per-shard waiting queues, SLO/priority admission control,
#                  independent microbatch dispatch, ingest interleaving,
#                  and the lockstep global-batch baseline
#   metrics.py   — per-request (arrival→completion) records, queue gauges,
#                  goodput under a p99 SLO
from repro.scheduling.metrics import (QueueGauge, RequestRecord,
                                      latency_percentiles, summarize)
from repro.scheduling.scheduler import (Scheduler, SchedulerConfig,
                                        SchedulerReport, simulate_lockstep)
from repro.scheduling.workload import (Request, WorkloadConfig, generate,
                                       replay)

__all__ = [
    "QueueGauge",
    "Request",
    "RequestRecord",
    "Scheduler",
    "SchedulerConfig",
    "SchedulerReport",
    "WorkloadConfig",
    "generate",
    "latency_percentiles",
    "replay",
    "simulate_lockstep",
    "summarize",
]
