"""Arrival-process load generation: timestamped request streams for serving.

The serving benches so far measured *drain* throughput: hand the engine a
list of ids, clock the wall time. Real POI traffic is a point process —
requests arrive over time, bunch up, and carry deadlines — and a scheduler
can only be evaluated against one. This module generates those streams:

  * ``poisson``  — memoryless arrivals at a target mean rate; the standard
    open-loop load model.
  * ``onoff``    — bursty Markov-modulated Poisson: the stream alternates
    ON windows (rate × burst_factor) and OFF windows (residual rate so the
    long-run mean still equals ``rate_rps``); duty_cycle sets the ON share
    of each period. This is the commute-peak shape POI check-in traffic
    actually has.
  * ``trace``    — replay explicit timestamps (`replay`), e.g. from a real
    check-in log.

User ids ride a popularity model: ``uniform`` or ``powerlaw`` (Zipf-like,
p(rank) ∝ rank^-zipf_s over a seed-keyed permutation of the user universe —
a few heavy hitters, a long tail, matching check-in frequency statistics).

Every request gets ``deadline = arrival + slo_ms`` and a priority drawn
uniformly from [0, priority_levels) (higher = more urgent). Generation is
fully seed-keyed and device-free: the same config always yields the same
stream, so scheduler tests can pin exact admission decisions.

CLI (the load-generator quickstart in README.md):

    PYTHONPATH=src python -m repro.scheduling.workload \
        --process onoff --rate 2000 --n 4096 --users powerlaw \
        --n-users 1024 --slo-ms 50 -o trace.json
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One timestamped serving request (times in seconds)."""
    rid: int                    # arrival index — ties broken by rid
    user: int
    arrival: float
    deadline: float             # arrival + SLO; inf = best-effort
    priority: int = 0           # higher = dispatched first within a queue

    @property
    def slo_s(self) -> float:
        return self.deadline - self.arrival


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_requests: int = 1024
    rate_rps: float = 2000.0        # long-run mean offered load
    process: str = "poisson"        # poisson | onoff
    burst_factor: float = 4.0       # ON-window rate multiplier (onoff);
                                    # burst_factor · duty_cycle ≤ 1 keeps
                                    # the OFF rate non-negative
    duty_cycle: float = 0.2         # ON fraction of each period (onoff)
    period_s: float = 0.05          # ON+OFF cycle length (onoff)
    users: str = "uniform"          # uniform | powerlaw
    zipf_s: float = 1.1             # power-law exponent (powerlaw)
    slo_ms: float = 50.0            # per-request deadline; <=0 or inf = none
    priority_levels: int = 1
    seed: int = 0

    def __post_init__(self):
        assert self.process in ("poisson", "onoff"), self.process
        assert self.users in ("uniform", "powerlaw"), self.users
        if self.process == "onoff":
            assert 0.0 < self.duty_cycle < 1.0, self.duty_cycle
            # OFF-rate = rate·(1-φ·b)/(1-φ) must stay non-negative
            assert self.burst_factor * self.duty_cycle <= 1.0 + 1e-9, (
                "onoff: burst_factor * duty_cycle must be <= 1 so the OFF "
                "rate is non-negative while the mean stays rate_rps")


def arrival_times(cfg: WorkloadConfig, rng: np.random.Generator) -> np.ndarray:
    """(n_requests,) sorted arrival seconds starting at 0."""
    n, rate = cfg.n_requests, cfg.rate_rps
    if n == 0:
        return np.zeros(0, np.float64)
    assert rate > 0, rate
    if cfg.process == "poisson":
        gaps = rng.exponential(1.0 / rate, n)
    else:  # onoff: piecewise-constant-intensity Poisson, cycle by cycle
        phi = cfg.duty_cycle
        p = cfg.period_s
        rate_on = rate * cfg.burst_factor
        rate_off = rate * (1.0 - cfg.burst_factor * phi) / (1.0 - phi)
        t, out = 0.0, []
        cycle = 0   # integer cycle index: deriving it from t via floor
                    # division is float-unstable at the window boundaries
        while len(out) < n:
            on_end = (cycle + phi) * p
            cycle_end = (cycle + 1.0) * p
            if t >= cycle_end:
                cycle += 1
                continue
            in_on = t < on_end
            r = rate_on if in_on else rate_off
            boundary = on_end if in_on else cycle_end
            if r <= 0:  # dead OFF window: jump to the next ON edge
                t = boundary
                continue
            gap = rng.exponential(1.0 / r)
            if t + gap < boundary:
                t += gap
                out.append(t)
            else:
                t = boundary    # rate changes at the boundary: restart draw
                                # (memorylessness makes the restart exact)
        times = np.asarray(out, np.float64)
        return times - times[0]
    times = np.cumsum(gaps)
    return times - times[0]


def sample_users(cfg: WorkloadConfig, n_users: int,
                 rng: np.random.Generator) -> np.ndarray:
    """(n_requests,) requesting user ids under the popularity model."""
    if cfg.users == "uniform":
        return rng.integers(0, n_users, cfg.n_requests).astype(np.int64)
    ranks = rng.permutation(n_users)            # which user is rank r
    p = (np.arange(1, n_users + 1, dtype=np.float64)) ** (-cfg.zipf_s)
    p /= p.sum()
    return ranks[rng.choice(n_users, cfg.n_requests, p=p)].astype(np.int64)


def make_requests(times: np.ndarray, users: np.ndarray, slo_ms: float,
                  priorities: np.ndarray | None = None) -> list[Request]:
    """Zip arrival times + users (+ priorities) into Request records."""
    assert len(times) == len(users)
    slo = np.inf if (slo_ms is None or slo_ms <= 0 or np.isinf(slo_ms)) \
        else slo_ms / 1e3
    pr = np.zeros(len(times), np.int64) if priorities is None else priorities
    return [Request(rid=i, user=int(u), arrival=float(t),
                    deadline=float(t) + slo, priority=int(p))
            for i, (t, u, p) in enumerate(zip(times, users, pr))]


def generate(cfg: WorkloadConfig, n_users: int) -> list[Request]:
    """Seed-keyed end-to-end generation: arrivals × users × priorities."""
    rng = np.random.default_rng(cfg.seed)
    times = arrival_times(cfg, rng)
    users = sample_users(cfg, n_users, rng)
    pr = (rng.integers(0, cfg.priority_levels, cfg.n_requests)
          if cfg.priority_levels > 1 else None)
    return make_requests(times, users, cfg.slo_ms, pr)


def replay(timestamps, users, slo_ms: float = 50.0,
           priorities=None) -> list[Request]:
    """Trace replay: explicit (sorted) arrival seconds + user ids."""
    times = np.asarray(timestamps, np.float64)
    assert (np.diff(times) >= 0).all(), "trace timestamps must be sorted"
    return make_requests(times - (times[0] if len(times) else 0.0),
                         np.asarray(users, np.int64), slo_ms,
                         None if priorities is None
                         else np.asarray(priorities, np.int64))


def to_json(requests: list[Request]) -> dict:
    """Serializable trace (the CLI output / `from_json` input)."""
    return {
        "arrival_s": [r.arrival for r in requests],
        "user": [r.user for r in requests],
        "deadline_s": [None if np.isinf(r.deadline) else r.deadline
                       for r in requests],
        "priority": [r.priority for r in requests],
    }


def from_json(obj: dict) -> list[Request]:
    return [Request(rid=i, user=int(u), arrival=float(t),
                    deadline=np.inf if d is None else float(d),
                    priority=int(p))
            for i, (t, u, d, p) in enumerate(zip(
                obj["arrival_s"], obj["user"], obj["deadline_s"],
                obj["priority"]))]


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="Generate a timestamped serving-request trace.")
    ap.add_argument("--process", default="poisson",
                    choices=("poisson", "onoff"))
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="mean offered load, requests/sec")
    ap.add_argument("--n", type=int, default=1024, help="number of requests")
    ap.add_argument("--n-users", type=int, default=1024,
                    help="user-id universe size")
    ap.add_argument("--users", default="uniform",
                    choices=("uniform", "powerlaw"))
    ap.add_argument("--zipf-s", type=float, default=1.1)
    ap.add_argument("--burst-factor", type=float, default=4.0)
    ap.add_argument("--duty-cycle", type=float, default=0.2)
    ap.add_argument("--period-s", type=float, default=0.05)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--priority-levels", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("-o", "--out", default="",
                    help="output JSON path (default: stdout)")
    args = ap.parse_args(argv)
    cfg = WorkloadConfig(
        n_requests=args.n, rate_rps=args.rate, process=args.process,
        burst_factor=args.burst_factor, duty_cycle=args.duty_cycle,
        period_s=args.period_s, users=args.users, zipf_s=args.zipf_s,
        slo_ms=args.slo_ms, priority_levels=args.priority_levels,
        seed=args.seed)
    trace = to_json(generate(cfg, args.n_users))
    payload = json.dumps(trace, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
        print(f"wrote {args.n} requests to {args.out}")
    else:
        print(payload)


if __name__ == "__main__":
    main()
