"""Continuous-batching request scheduler with SLO-aware admission control.

Sits in FRONT of `serving.ServingEngine` and replaces its drain-everything
dispatch discipline with a real serving loop:

  * **Per-shard waiting queues, independently dispatched.** Each shard's
    queue fires its own `engine.serve_microbatch` the moment it has a full
    microbatch and the shard is free — one slow or empty shard queue never
    holds a global batch hostage. (The old behavior — one SPMD wave of
    microbatch × n_shards requests in lockstep, everyone waiting for the
    widest batch — is preserved as `simulate_lockstep`, the measured
    baseline.)
  * **Deadline- and priority-aware admission.** A request whose SLO cannot
    be met given the queue backlog is rejected at arrival; a request whose
    deadline passes while it waits is expired at batch formation. Nothing
    queues forever. Within a queue, higher priority dispatches first.
  * **Tail-batch coalescing with a max-wait timer.** A partial batch waits
    at most ``max_wait_ms`` for company before it fires.
  * **Ingest interleaving.** Online factor refresh (`serving/online.py`)
    runs only in idle serve slots — when every queue is empty and the
    refresh fits before the next arrival (its cost: a measured EMA, seeded
    by the conservative ``ingest_cost_init_s`` until the first window has
    run; the refresh jit is pre-compiled off the clock so the first
    measurement is execution, not compilation) — so factor refresh never
    blocks the serve path.

Time model: a **virtual clock over real measured compute**. Arrivals are
timestamped by the workload; every dispatch actually executes (its wall
time is measured and advances the clock); shards are modeled as concurrent
servers via per-shard ``busy_until`` times, which is the fleet the paper
describes (each learner serves itself) rather than the one-process
simulation host. Per-request latency is arrival → completion on this
clock — the same definition `EngineStats.request_seconds` uses. Served
slates are REAL engine outputs, bit-identical per request to a direct
`ServingEngine.recommend` of the same user ids at the same factor
snapshot (asserted in tests and BENCH_scheduler).
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as trace_lib
from repro.scheduling import metrics as metrics_lib
from repro.scheduling.metrics import (EXPIRED, REJECTED_DEADLINE,
                                      REJECTED_QUEUE_FULL, SERVED,
                                      QueueGauge, RequestRecord)
from repro.scheduling.workload import Request

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_wait_ms: float = 2.0     # tail-batch coalescing timer
    queue_cap: int = 256         # per-shard waiting-queue capacity
    admission: str = "deadline"  # "deadline": reject SLO-infeasible arrivals
                                 #   (plus queue_cap); "queue_only": only
                                 #   queue_cap; "none": admit everything
    service_ema: float = 0.3     # EMA weight for the service-time estimate
    expire_undispatchable: bool = True   # at batch formation, drop waiting
                                 # requests that can no longer meet their
                                 # deadline even if served immediately
    ingest_cost_init_s: float = 0.25     # assumed cost of an ingest window
                                 # before one has been measured — keeps the
                                 # first refresh out of sub-estimate idle
                                 # slivers between arrivals

    def __post_init__(self):
        assert self.admission in ("deadline", "queue_only", "none")


@dataclasses.dataclass
class SchedulerReport:
    records: list[RequestRecord]
    gauges: list[QueueGauge]
    n_dispatches_per_shard: list[int]
    ingest_intervals: list[tuple[float, float]]   # (start, end) virtual secs
    ingest_reports: list                          # online.RefreshReport per window

    @property
    def n_ingest_windows(self) -> int:
        return len(self.ingest_intervals)

    def served(self) -> list[RequestRecord]:
        """Served records in arrival (rid) order."""
        return sorted((r for r in self.records if r.status == SERVED),
                      key=lambda r: r.rid)

    def summary(self, slo_ms: float | None = None) -> dict:
        return metrics_lib.summarize(self.records, self.gauges, slo_ms)

    def publish(self, registry=None, prefix: str = "scheduler",
                slo_ms: float | None = None) -> dict:
        """Mirror this report's summary into a metrics registry (the
        global one by default); returns the summary dict it published.
        Scalar rates/fractions land as gauges, terminal-state totals as
        gauges too (a report is a finished run, not a live stream), and
        the served-latency distribution replaces the
        ``{prefix}_request_seconds`` histogram series."""
        reg = registry if registry is not None else obs_metrics.get_registry()
        s = self.summary(slo_ms)
        for f in ("n_requests", "n_served", "n_rejected_queue_full",
                  "n_rejected_deadline", "n_expired", "n_fallback",
                  "rejected_frac", "expired_frac", "offered_load_rps",
                  "goodput_rps", "slo_attainment"):
            reg.gauge(f"{prefix}_{f}").set(s[f])
        reg.gauge(f"{prefix}_n_ingest_windows").set(self.n_ingest_windows)
        for k, v in s.get("queue", {}).items():   # QueueGauge aggregates
            reg.gauge(f"{prefix}_queue_{k}").set(v)
        h = reg.histogram(f"{prefix}_request_seconds")
        h.reset()
        h.observe_many(r.latency for r in self.served())
        return s


def _warm_refresh_jit(engine, ocfg) -> None:
    """Compile the online-refresh step for this run's shapes before the
    clock starts: the step donates its factor buffers, so the warm-up runs
    on throwaway copies with an all-padding batch (valid = 0 everywhere —
    an exact no-op update). Without this, the first ingest window's
    measured cost is dominated by jit compilation and both the ingest-cost
    EMA and the window's virtual-clock footprint are garbage."""
    import jax
    import jax.numpy as jnp

    from repro.serving import online as online_lib

    cap = ocfg.batch_cap
    U, P, Q = (jnp.array(x) for x in
               (engine.state.U, engine.state.P, engine.state.Q))
    out = online_lib._refresh_step(
        U, P, Q, engine.nbr.idx, engine.nbr.wgt,
        jnp.zeros(cap, jnp.int32), jnp.zeros(cap, jnp.int32),
        jnp.zeros(cap, jnp.float32), jnp.zeros(cap, jnp.float32),
        jnp.zeros(cap, jnp.float32), jnp.arange(cap, dtype=jnp.int32),
        jnp.asarray(0, jnp.int32), engine.dmf_cfg)
    jax.block_until_ready(out[0])


class Scheduler:
    """Wraps a `ServingEngine`; `run()` plays a timestamped request stream
    through admission → per-shard queues → independent microbatch dispatch.

    The engine's shard layout is reused for routing: user u lives on shard
    ``u // rows_per_shard`` (ids outside [0, n_users) are clamped for
    routing — they flow through admission like any request and get the
    engine's fallback slate at dispatch, flagged in their record)."""

    def __init__(self, engine, cfg: SchedulerConfig = SchedulerConfig()):
        self.engine = engine
        self.cfg = cfg
        self.n_shards = engine.cfg.n_shards
        self._rows = engine._rows if self.n_shards > 1 else engine._n_users
        self._svc_est: float | None = None   # EMA of measured dispatch secs
        self._ingest_est: float | None = None

    # ------------------------------------------------------------ routing
    def shard_of(self, user: int) -> int:
        safe = min(max(int(user), 0), self.engine._n_users - 1)
        return min(safe // self._rows, self.n_shards - 1)

    # ---------------------------------------------------------- admission
    def _admit(self, req: Request, queues, busy, now, records) -> None:
        d = self.shard_of(req.user)
        rec = RequestRecord(rid=req.rid, user=req.user, shard=d,
                            arrival=req.arrival, deadline=req.deadline,
                            priority=req.priority)
        records.append(rec)
        if self.cfg.admission != "none" and len(queues[d]) >= self.cfg.queue_cap:
            rec.status = REJECTED_QUEUE_FULL
            return
        if (self.cfg.admission == "deadline" and self._svc_est is not None
                and not math.isinf(req.deadline)):
            R = self.engine.cfg.microbatch
            waves_ahead = len(queues[d]) // R
            est_done = (max(busy[d], now) + waves_ahead * self._svc_est
                        + self._svc_est)
            if est_done > req.deadline:
                rec.status = REJECTED_DEADLINE
                return
        queues[d].append(rec)

    # ------------------------------------------------------------ dispatch
    def _form_batch(self, queue: list[RequestRecord], now: float
                    ) -> list[RequestRecord]:
        """Expire the un-serveable, then take up to `microbatch` requests in
        (priority desc, arrival, rid) order. Mutates `queue` in place."""
        horizon = now + (self._svc_est or 0.0) \
            if self.cfg.expire_undispatchable else now
        keep = []
        for rec in queue:
            if rec.deadline < horizon:
                rec.status = EXPIRED
            else:
                keep.append(rec)
        keep.sort(key=lambda r: (-r.priority, r.arrival, r.rid))
        R = self.engine.cfg.microbatch
        take, rest = keep[:R], keep[R:]
        queue[:] = rest
        return take

    def _dispatch(self, d: int, take: list[RequestRecord], now: float,
                  n_ingested: int) -> float:
        with trace_lib.span("scheduler.dispatch", shard=d, n=len(take)):
            vals, idx, flags, dt = self.engine.serve_microbatch(
                [r.user for r in take], return_flags=True)
        if self._svc_est is None:
            self._svc_est = dt
        else:
            a = self.cfg.service_ema
            self._svc_est = a * dt + (1 - a) * self._svc_est
        done = now + dt
        for i, rec in enumerate(take):
            rec.status = SERVED
            rec.dispatch_start = now
            rec.completion = done
            rec.fallback = bool(flags[i])
            rec.ingest_epoch = n_ingested
            rec.vals = vals[i]
            rec.idx = idx[i]
        return dt

    # ---------------------------------------------------------------- run
    def run(self, requests: list[Request], ingest_events=(),
            ocfg=None) -> SchedulerReport:
        """Play the stream to completion. ``ingest_events`` is a sequence of
        (m, 2) check-in event arrays; each is one `engine.ingest` window,
        run only in idle slots (any window still pending when the stream
        ends runs after it). Returns the full per-request report."""
        from repro.serving import online as online_lib

        eng, D = self.engine, self.n_shards
        R = eng.cfg.microbatch
        max_wait = self.cfg.max_wait_ms / 1e3
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        queues: list[list[RequestRecord]] = [[] for _ in range(D)]
        busy = [0.0] * D
        records: list[RequestRecord] = []
        gauges: list[QueueGauge] = []
        n_disp = [0] * D
        ingest_pending = list(ingest_events)
        ingest_intervals: list[tuple[float, float]] = []
        ingest_reports = []
        ocfg = ocfg or online_lib.OnlineConfig()
        if ingest_pending:
            _warm_refresh_jit(eng, ocfg)
        clock = reqs[0].arrival if reqs else 0.0
        i = 0
        n = len(reqs)

        def run_ingest_window(at: float) -> float:
            ev = ingest_pending.pop(0)
            t0 = time.perf_counter()
            with trace_lib.span("scheduler.ingest_window", n_events=len(ev)):
                ingest_reports.append(eng.ingest(np.asarray(ev), ocfg))
            din = time.perf_counter() - t0
            self._ingest_est = din if self._ingest_est is None else (
                0.5 * din + 0.5 * self._ingest_est)
            ingest_intervals.append((at, at + din))
            for d in range(D):     # factors mutate: serving waits it out
                busy[d] = max(busy[d], at + din)
            return din

        while i < n or any(queues):
            while i < n and reqs[i].arrival <= clock:
                self._admit(reqs[i], queues, busy, clock, records)
                i += 1
            next_arrival = reqs[i].arrival if i < n else _INF
            # earliest shard that can and should fire
            t_fire, shard = _INF, -1
            for d in range(D):
                if not queues[d]:
                    continue
                t = max(busy[d], clock)
                if len(queues[d]) < R:
                    t = max(t, min(r.arrival for r in queues[d]) + max_wait)
                if t < t_fire:
                    t_fire, shard = t, d
            if shard < 0:
                # everything idle: ingest if it fits, else jump to arrivals
                est_in = (self._ingest_est if self._ingest_est is not None
                          else self.cfg.ingest_cost_init_s)
                if ingest_pending and (
                        next_arrival == _INF
                        or clock + est_in <= next_arrival):
                    run_ingest_window(clock)
                    continue
                if next_arrival == _INF:
                    break
                clock = next_arrival
                continue
            if next_arrival < t_fire:
                clock = next_arrival   # an arrival may fill a batch earlier
                continue
            clock = max(clock, t_fire)
            take = self._form_batch(queues[shard], clock)
            if not take:               # queue was all-expired
                continue
            dt = self._dispatch(shard, take, clock, len(ingest_intervals))
            busy[shard] = clock + dt
            n_disp[shard] += 1
            waiting = queues[shard]
            gauges.append(QueueGauge(
                t=clock, shard=shard, depth=len(waiting),
                oldest_age=(clock - min(r.arrival for r in waiting)
                            if waiting else 0.0),
                batch_occupancy=len(take) / R))
        while ingest_pending:          # stream over: finish refresh backlog
            clock += run_ingest_window(clock)
        return SchedulerReport(records, gauges, n_disp, ingest_intervals,
                               ingest_reports)


def simulate_lockstep(engine, requests: list[Request]) -> SchedulerReport:
    """The pre-scheduler dispatch discipline, made measurable on the same
    virtual clock: one global wave at a time, each wave taking up to
    `microbatch` FIFO requests from EVERY shard queue and completing
    together (`engine.serve_wave` — the one-SPMD-dispatch lockstep), no
    admission control, no expiry. Requests pay for the widest batch: this
    is the baseline whose p50 balloons with shard count in BENCH_serving.
    At ``n_shards == 1`` the wave degenerates to `serve_microbatch`."""
    D = engine.cfg.n_shards
    R = engine.cfg.microbatch
    rows = engine._rows if D > 1 else engine._n_users
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    queues: list[list[RequestRecord]] = [[] for _ in range(D)]
    records: list[RequestRecord] = []
    gauges: list[QueueGauge] = []
    n_disp = [0] * D
    free = 0.0
    i, n = 0, len(reqs)
    clock = reqs[0].arrival if reqs else 0.0

    def admit_up_to(t: float):
        nonlocal i
        while i < n and reqs[i].arrival <= t:
            r = reqs[i]
            safe = min(max(int(r.user), 0), engine._n_users - 1)
            d = min(safe // rows, D - 1)
            rec = RequestRecord(rid=r.rid, user=r.user, shard=d,
                                arrival=r.arrival, deadline=r.deadline,
                                priority=r.priority)
            records.append(rec)
            queues[d].append(rec)
            i += 1

    while i < n or any(queues):
        admit_up_to(clock)
        if not any(queues):
            clock = reqs[i].arrival
            continue
        t_fire = max(clock, free)
        admit_up_to(t_fire)            # late arrivals still catch this wave
        takes = [q[:R] for q in queues]
        for d in range(D):
            queues[d] = queues[d][len(takes[d]):]
        flat = [rec for t in takes for rec in t]
        if D > 1:
            users = np.asarray([r.user for r in flat])
            flags = (engine._fallback_mask(users) if engine.cfg.fallback
                     else np.zeros(len(flat), bool))
            safe = np.where(flags, 0, users).astype(np.int64)
            uids_l = np.zeros((D, R), np.int32)
            off = 0
            for d in range(D):
                m = len(takes[d])
                uids_l[d, :m] = safe[off:off + m] % rows
                off += m
            vals, idx, dt = engine.serve_wave(uids_l)
            engine.stats.n_requests += len(flat)
            out_v = np.concatenate(
                [vals[d, : len(takes[d])] for d in range(D)])
            out_i = np.concatenate(
                [idx[d, : len(takes[d])] for d in range(D)])
            if flags.any():
                out_v = np.array(out_v)
                out_i = np.array(out_i)
                out_v[flags] = engine._pop_vals
                out_i[flags] = engine._pop_items
                engine.stats.n_fallbacks += int(flags.sum())
        else:
            out_v, out_i, flags, dt = engine.serve_microbatch(
                [r.user for r in flat], return_flags=True)
        done = t_fire + dt
        for j, rec in enumerate(flat):
            rec.status = SERVED
            rec.dispatch_start = t_fire
            rec.completion = done
            rec.fallback = bool(flags[j])
            rec.vals = out_v[j]
            rec.idx = out_i[j]
        for d in range(D):
            if takes[d]:
                n_disp[d] += 1
            gauges.append(QueueGauge(
                t=t_fire, shard=d, depth=len(queues[d]),
                oldest_age=(t_fire - min(r.arrival for r in queues[d])
                            if queues[d] else 0.0),
                batch_occupancy=len(takes[d]) / R))
        clock = free = done
    return SchedulerReport(records, gauges, n_disp, [], [])
