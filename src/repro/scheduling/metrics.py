"""Request-level serving metrics: records, queue gauges, goodput under SLO.

One latency definition everywhere: **arrival → completion**, per request —
the same definition `ServingEngine.EngineStats.request_seconds` uses (NOT
per-dispatch wall time, which hides the queueing a request pays while
earlier dispatches drain). The headline serving metric is **goodput under
a p99 SLO**: the rate of requests that completed within their deadline,
over the serving horizon. Peak rps alone rewards schedulers that let tail
requests rot in a queue; goodput does not — a request served after its
deadline (or never) counts for nothing.

Definitions written to every scheduler report / BENCH_scheduler.json:

  offered_load_rps  (n_arrivals - 1) / (last_arrival - first_arrival) —
                    the MLE of a Poisson rate observed over the arrival
                    window (n arrivals delimit n-1 inter-arrival gaps; the
                    naive n/span overestimates by n/(n-1)). Degenerate
                    runs (a single arrival, or all arrivals simultaneous)
                    fall back to n / horizon so a 1-request run reports
                    its actual (non-zero) load instead of 0.0.
  goodput_rps       n_served_within_deadline / horizon,
                    horizon = last_completion - first_arrival
  slo_attainment    n_served_within_deadline / n_arrivals  (rejected and
                    expired requests count against attainment — admission
                    control is honest only if refusals aren't free)
  p99_slo_met       p99(latency of served) <= SLO
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import metrics as obs_metrics

# terminal request states
SERVED = "served"
REJECTED_QUEUE_FULL = "rejected_queue_full"   # waiting queue at capacity
REJECTED_DEADLINE = "rejected_deadline"       # admission: SLO infeasible
EXPIRED = "expired"                           # deadline passed while queued


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle of one request through the scheduler."""
    rid: int
    user: int
    shard: int
    arrival: float               # seconds, virtual clock
    deadline: float              # arrival + SLO (inf = no SLO)
    priority: int = 0
    status: str = SERVED
    dispatch_start: float = float("nan")
    completion: float = float("nan")
    fallback: bool = False       # served from the popularity slate
    ingest_epoch: int = 0        # ingest windows applied before dispatch
    vals: np.ndarray | None = None   # served slate (for exactness checks)
    idx: np.ndarray | None = None

    @property
    def latency(self) -> float:
        return self.completion - self.arrival

    @property
    def met_slo(self) -> bool:
        return self.status == SERVED and self.completion <= self.deadline


@dataclasses.dataclass
class QueueGauge:
    """Queue state sampled at each dispatch decision."""
    t: float
    shard: int
    depth: int                   # waiting-queue depth after batch formation
    oldest_age: float            # age of the oldest still-waiting request
    batch_occupancy: float       # n_real / microbatch of the fired batch


def latency_percentiles(latencies_s, qs=(50, 95, 99)) -> dict[str, float]:
    """{p50_ms, ...} over per-request latencies (seconds in, ms out).
    Delegates to `repro.obs.metrics.latency_percentiles` — the single
    repo-wide percentile definition (kept as a re-export here so existing
    imports keep working)."""
    return obs_metrics.latency_percentiles(latencies_s, qs)


def summarize(records: list[RequestRecord],
              gauges: list[QueueGauge] | None = None,
              slo_ms: float | None = None) -> dict:
    """Aggregate a scheduler (or baseline) run into the report dict the
    benches serialize. Empty runs summarize to zeros, not NaN crashes."""
    n = len(records)
    served = [r for r in records if r.status == SERVED]
    within = [r for r in served if r.completion <= r.deadline]
    arrivals = np.asarray([r.arrival for r in records], np.float64)
    out = {
        "n_requests": n,
        "n_served": len(served),
        "n_rejected_queue_full": sum(
            r.status == REJECTED_QUEUE_FULL for r in records),
        "n_rejected_deadline": sum(
            r.status == REJECTED_DEADLINE for r in records),
        "n_expired": sum(r.status == EXPIRED for r in records),
        "n_fallback": sum(r.fallback for r in served),
    }
    out["rejected_frac"] = (
        (out["n_rejected_queue_full"] + out["n_rejected_deadline"]) / n
        if n else 0.0)
    out["expired_frac"] = out["n_expired"] / n if n else 0.0
    if n >= 2 and arrivals.max() > arrivals.min():
        # MLE Poisson rate over the observed arrival window (see module
        # docstring): n arrivals delimit n-1 gaps.
        out["offered_load_rps"] = float((n - 1) / (arrivals.max() - arrivals.min()))
    elif n >= 1:
        # Degenerate window (single request, or all arrivals at the same
        # instant): the arrival span carries no rate information, so fall
        # back to n / serving horizon — a 1-request run that completed in
        # 50 ms offered 20 rps, not 0.0.
        horizon = (max((r.completion for r in served), default=float("nan"))
                   - float(arrivals.min()))
        out["offered_load_rps"] = (
            float(n / horizon) if served and horizon > 0 else 0.0)
    else:
        out["offered_load_rps"] = 0.0
    if served:
        horizon = max(r.completion for r in served) - float(arrivals.min())
        out["goodput_rps"] = len(within) / horizon if horizon > 0 else 0.0
        out["latency_ms"] = latency_percentiles(r.latency for r in served)
    else:
        out["goodput_rps"] = 0.0
        out["latency_ms"] = latency_percentiles(())
    out["slo_attainment"] = len(within) / n if n else 0.0
    if slo_ms is not None:
        p99 = out["latency_ms"]["p99_ms"]
        out["p99_slo_met"] = bool(served) and bool(p99 <= slo_ms)
    if gauges:
        depth = np.asarray([g.depth for g in gauges], np.float64)
        age = np.asarray([g.oldest_age for g in gauges], np.float64)
        occ = np.asarray([g.batch_occupancy for g in gauges], np.float64)
        out["queue"] = {
            "depth_mean": float(depth.mean()),
            "depth_max": int(depth.max()),
            "oldest_age_ms_mean": float(age.mean() * 1e3),
            "oldest_age_ms_max": float(age.max() * 1e3),
            "batch_occupancy_mean": float(occ.mean()),
        }
    return out
