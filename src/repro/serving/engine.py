"""ServingEngine — microbatched, geo-pruned, online-updatable POI serving.

The deployment story of the paper: trained factors live per learner
(u_i, p^i + q^i) and recommendations are computed at the edge. This engine
simulates that fleet in one process the way the paper's own evaluation
mocks decentralized learning — it gathers each learner's *own* factors per
request (never a shared dense score matrix) and returns top-k unseen POIs.

Request path:

1. **Microbatcher** — a stream of user-id requests is grouped into
   fixed-shape batches of ``ServingConfig.microbatch`` (the tail batch is
   padded with a repeated real id, results sliced off). Fixed shapes mean
   exactly one compiled dispatch per microbatch, ever.
2. **Dispatch** — one jitted call: route each request to its home-city
   candidate bucket (`candidates.CandidateIndex`), gather ONLY the
   (R, cap, K) candidate windows out of the HBM-resident factor buffers
   (never a per-request (R, J, K) item slab), and run the tiled Pallas
   serve kernel (`ops.serve_topk_window`: window scores → running top-k,
   streamed in (8, K, 128) VMEM tiles). Per-request cost AND staging are
   O(cap·K), not O(J·K) — the property that lets `serving/store.py` push
   the same dispatch to 1M users × 100k POIs.
3. **Online refresh** — ``ingest()`` streams new check-ins through
   `serving/online.py` (Eq. 9-11 local steps + neighbor-table scatter),
   then patches only the touched rows of the served V = P + Q view and the
   affected rows of the seen-filter. Served factors track live data with
   no retraining and no raw-rating movement.

``prune=False`` switches the dispatch to the dense full-J streaming kernel
(`ops.recommend_topk_peruser`) — same microbatching, no geo pruning — kept
as the measured baseline and the exactness fallback for users whose city
overflows the bucket cap.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dmf
from repro.core import graph as graph_lib
from repro.core import metrics as metrics_lib
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as trace_lib
from repro.serving import online as online_lib
from repro.serving.candidates import CandidateIndex


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    microbatch: int = 64     # R — fixed dispatch shape (requests padded to it)
    k: int = 10              # recommendations per request
    prune: bool = True       # geo-pruned candidate path vs dense full-J
    interpret: bool = True   # Pallas interpret mode (CPU container default)
    n_shards: int = 1        # learner-mesh width: >1 serves row-sharded
                             # U/V/seen, one SPMD dispatch per microbatch
                             # of `microbatch` requests PER SHARD
    fallback: bool = True    # graceful degradation: unknown/cold users and
                             # empty candidate buckets get a popularity
                             # slate (flagged) instead of garbage scores


@dataclasses.dataclass
class EngineStats:
    n_requests: int = 0
    n_dispatches: int = 0
    n_refreshes: int = 0
    n_events: int = 0
    n_fallbacks: int = 0
    dispatch_seconds: list[float] = dataclasses.field(default_factory=list)
    # per-REQUEST arrival→completion, one entry per served request. A request
    # that rides the w-th dispatch of a drain pays for every dispatch before
    # it — the lockstep cost per-dispatch numbers hide. This is the one
    # latency definition shared with scheduling/metrics.py.
    request_seconds: list[float] = dataclasses.field(default_factory=list)

    def reset(self) -> None:
        """Zero all counters/latencies (e.g. after warm-up dispatches)."""
        self.__dict__.update(dataclasses.asdict(EngineStats()))

    def latency_percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        """Request-level (arrival→completion) latency percentiles —
        delegates to the one definition in `obs.metrics`."""
        return obs_metrics.latency_percentiles(self.request_seconds, qs)

    def dispatch_latency_percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        """Per-dispatch wall-time percentiles (diagnostic, NOT per-request)."""
        return obs_metrics.latency_percentiles(self.dispatch_seconds, qs)

    def publish(self, registry=None, prefix: str = "serving") -> None:
        """Mirror the local counters/latency streams into a metrics
        registry (the global one by default). Counters export as gauges —
        this object is the source of truth and may be `reset()`, so the
        registry reflects its current totals rather than re-accumulating.
        Latency streams replace the histogram's series wholesale for the
        same reason."""
        reg = registry if registry is not None else obs_metrics.get_registry()
        for f in ("n_requests", "n_dispatches", "n_refreshes", "n_events",
                  "n_fallbacks"):
            reg.gauge(f"{prefix}_{f}").set(getattr(self, f))
        for nm in ("dispatch_seconds", "request_seconds"):
            h = reg.histogram(f"{prefix}_{nm}")
            h.reset()
            h.observe_many(getattr(self, nm))


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def _dispatch_pruned(U, V, seen, bucket_items, user_bucket, uids, *,
                     k: int, interpret: bool):
    """One geo-pruned microbatch: candidate-window gather + tiled serve
    kernel, a single compiled dispatch. Only the (R, cap, K) candidate
    windows are staged out of the HBM-resident factor buffer — never the
    (R, J, K) per-request item slab the pre-tiled path copied. The dispatch
    is read-only over the persistent factor buffers, so nothing is donatable
    here; the state-mutating path (online refresh) donates U/P/Q instead."""
    u = U[uids]                                   # (R, K)   own user factor
    cand = bucket_items[user_bucket[uids]]        # (R, cap) home bucket
    safe = jnp.maximum(cand, 0)                   # pad-safe gather
    vw = V[uids[:, None], safe]                   # (R, cap, K) windows only
    sw = seen[uids[:, None], safe]                # (R, cap) window seen bits
    return ops.serve_topk_window(u, vw, cand, sw, k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def _dispatch_dense(U, V, seen, uids, *, k: int, interpret: bool):
    """Dense baseline microbatch: same gather, full-J streaming top-k."""
    return ops.recommend_topk_peruser(
        U[uids], V[uids], seen[uids], k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "prune"))
def _dispatch_rows(U, P, Q, seen, bucket_items, user_bucket, uids, *,
                   k: int, interpret: bool, prune: bool):
    """Shard-independent microbatch over the raw factor state: gathers the
    requested rows and forms their V = P + Q view on the fly (gather-then-add
    of the same rows is bitwise identical to gathering a precomputed V).
    This is the `serve_microbatch` dispatch — it never touches the sharded
    device views, so one shard's queue can be served without the SPMD
    lockstep over the whole mesh. The pruned path gathers only the
    (R, cap, K) candidate windows straight out of P/Q (gather-then-add of
    the same elements is bitwise identical to windowing a precomputed V)."""
    u = U[uids]
    if prune:
        cand = bucket_items[user_bucket[uids]]
        safe = jnp.maximum(cand, 0)
        vw = P[uids[:, None], safe] + Q[uids[:, None], safe]   # (R, cap, K)
        sw = seen[uids[:, None], safe]
        return ops.serve_topk_window(u, vw, cand, sw, k, interpret=interpret)
    v = P[uids] + Q[uids]
    s = seen[uids]
    return ops.recommend_topk_peruser(u, v, s, k, interpret=interpret)


def _make_sharded_dispatch(mesh, *, k: int, interpret: bool, prune: bool):
    """SPMD serve dispatch over the ``learners`` mesh: every shard gathers
    its OWN users' (u_i, v^i, seen_i) rows and runs the same fused serve
    kernel (or the dense streaming kernel) on its local microbatch — one
    compiled dispatch serves mesh-width × microbatch requests. ``uids`` are
    shard-LOCAL row ids shaped (n_shards, R); the candidate buckets are
    replicated (items are global ids everywhere)."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import shard_map
    from repro.sharding.dmf import AXIS

    def body(U, V, seen, user_bucket, bucket_items, uids):
        u_l = uids[0]                        # (R,) local row ids
        u = U[u_l]
        if prune:
            cand = bucket_items[user_bucket[u_l]]
            safe = jnp.maximum(cand, 0)
            vw = V[u_l[:, None], safe]       # (R, cap, K) windows only
            sw = seen[u_l[:, None], safe]
            return ops.serve_topk_window(u, vw, cand, sw, k,
                                         interpret=interpret)
        return ops.recommend_topk_peruser(
            u, V[u_l], seen[u_l], k, interpret=interpret)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(None, None), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS)),
        check_vma=False,
    ))


class ServingEngine:
    """Batched POI recommendation over a trained `DMFState`.

    ``nbr`` + ``dmf_cfg`` are only required for `ingest()` (online refresh).

    The engine owns a private copy of the factor state: `ingest()` donates
    its U/P/Q buffers to the refresh step (in-place at the XLA level), and
    copying once at construction keeps that from invalidating the
    caller's trained state (e.g. a `FitResult` still used for evaluation).
    """

    def __init__(
        self,
        state: dmf.DMFState,
        index: CandidateIndex,
        cfg: ServingConfig = ServingConfig(),
        *,
        train: np.ndarray | None = None,
        seen: np.ndarray | None = None,
        nbr: graph_lib.NeighborTable | None = None,
        dmf_cfg: dmf.DMFConfig | None = None,
    ):
        self.state = dmf.DMFState(
            U=jnp.array(state.U), P=jnp.array(state.P), Q=jnp.array(state.Q))
        self.index = index
        self.cfg = cfg
        self.nbr = nbr
        self.dmf_cfg = dmf_cfg
        I, J = state.P.shape[0], state.P.shape[1]
        assert index.n_items == J, (index.n_items, J)
        if seen is None:
            assert train is not None, "need `train` pairs or a `seen` mask"
            seen = metrics_lib.masks_from_interactions(I, J, train)
        seen_np = np.asarray(seen).astype(bool)
        self.seen = jnp.asarray(seen_np.astype(np.int8))
        self._bucket_items = jnp.asarray(index.bucket_items)
        self._user_bucket = jnp.asarray(index.user_bucket)
        # graceful-degradation state (host-side, cheap): which requests
        # cannot be served from learned factors — unknown ids, cold-start
        # users (no interactions => their zero-init item factors score
        # garbage), users whose home-city candidate bucket is empty — and
        # the popularity-ranked slate they get instead (check-in counts
        # from the seen-filter, kept fresh by `ingest`).
        self._n_users = I
        self._cold = ~seen_np.any(axis=1)
        self._item_counts = seen_np.sum(axis=0).astype(np.int64)
        self._user_bucket_np = np.asarray(index.user_bucket)
        self._bucket_empty = (np.asarray(index.bucket_items) < 0).all(axis=1)
        self._refresh_popularity()
        self._sharded = cfg.n_shards > 1
        if self._sharded:
            # learner-sharded serving: the served views live row-sharded on
            # the mesh (the sharded V REPLACES the single-device V = P + Q
            # view — keeping both would double the engine's largest buffer);
            # each SPMD dispatch serves `microbatch` requests per shard,
            # each shard reading only its own users' rows.
            from jax.sharding import NamedSharding, PartitionSpec as PSpec

            from repro.sharding import dmf as sharded_dmf

            self._mesh = sharded_dmf.make_learner_mesh(cfg.n_shards)
            self._rows = sharded_dmf.rows_per_shard(I, cfg.n_shards)
            I_pad = self._rows * cfg.n_shards
            sh = NamedSharding(self._mesh, PSpec(sharded_dmf.AXIS))
            pad = sharded_dmf.pad_rows
            self._U_sh = jax.device_put(pad(self.state.U, I_pad), sh)
            self._V_sh = jax.device_put(
                pad(self.state.P + self.state.Q, I_pad), sh)
            self._seen_sh = jax.device_put(pad(self.seen, I_pad), sh)
            self._ub_sh = jax.device_put(pad(self._user_bucket, I_pad), sh)
            self._dispatch_sh = _make_sharded_dispatch(
                self._mesh, k=cfg.k, interpret=cfg.interpret, prune=cfg.prune)
        else:
            self.V = state.P + state.Q            # served per-learner view
        # persistent stream: successive ingest() calls must draw *fresh*
        # negatives, not replay the same ones (which would keep hammering
        # the same arbitrary items' scores down)
        self._rng = np.random.default_rng(
            dmf_cfg.seed if dmf_cfg is not None else 0)
        self.stats = EngineStats()

    # -------------------------------------------------------------- fallback
    def _refresh_popularity(self) -> None:
        """Rebuild the popularity slate: top-k items by check-in count,
        values = count / max count (a [0,1] pseudo-score, deliberately NOT
        on the factor-score scale — fallback responses are flagged)."""
        top = np.argsort(-self._item_counts, kind="stable")
        self._pop_items = top[: self.cfg.k].astype(np.int32)
        peak = max(int(self._item_counts.max()), 1)
        self._pop_vals = (
            self._item_counts[self._pop_items] / peak).astype(np.float32)

    def _fallback_mask(self, user_ids: np.ndarray) -> np.ndarray:
        """Per-request bool mask: True where the learned-factor path cannot
        produce a meaningful slate and the popularity fallback applies."""
        uids = np.asarray(user_ids)
        unknown = (uids < 0) | (uids >= self._n_users)
        safe = np.clip(uids, 0, self._n_users - 1)
        flags = unknown | self._cold[safe]
        if self.cfg.prune:
            flags = flags | self._bucket_empty[self._user_bucket_np[safe]]
        return flags

    # ------------------------------------------------------------------ serve
    def _microbatches(
        self, user_ids: Iterable[int], t_arrival: float | None = None
    ) -> Iterator[tuple[np.ndarray, int, np.ndarray]]:
        """Fixed-shape request batches: (padded ids (R,), n_real, arrival
        timestamps (n_real,) — stamped when each id was pulled from the
        stream, the request-level latency anchor). ``t_arrival`` overrides
        the pull-time stamps with one shared anchor — `recommend` passes its
        call time, because there the whole batch is queued up-front and later
        microbatches wait on the earlier ones."""
        R = self.cfg.microbatch
        buf = np.zeros(R, np.int32)
        arr = np.zeros(R, np.float64)
        n = 0
        for uid in user_ids:
            buf[n] = uid
            arr[n] = time.perf_counter() if t_arrival is None else t_arrival
            n += 1
            if n == R:
                yield buf.copy(), n, arr[:n].copy()
                n = 0
        if n:
            buf[n:] = buf[0]       # pad with a real user id (results dropped)
            yield buf.copy(), n, arr[:n].copy()

    # ------------------------------------------------------------ sharded serve
    def serve_wave(self, uids_local: np.ndarray) -> tuple[np.ndarray, np.ndarray, float]:
        """ONE lockstep SPMD dispatch over the whole mesh: ``uids_local`` is
        (n_shards, microbatch) shard-LOCAL row ids (pad unused slots with 0 —
        callers drop those results). Every shard computes its full microbatch
        whether its queue was full or empty; returns
        (vals (D, R, k), idx (D, R, k), wall seconds). This is the global-
        batch primitive the continuous-batching scheduler's per-shard
        independent dispatch (`serve_microbatch`) is measured against."""
        D, R, k = self.cfg.n_shards, self.cfg.microbatch, self.cfg.k
        t0 = time.perf_counter()
        with trace_lib.span("engine.serve_wave", shards=D, microbatch=R):
            vals, idx = self._dispatch_sh(
                self._U_sh, self._V_sh, self._seen_sh, self._ub_sh,
                self._bucket_items, jnp.asarray(uids_local))
            jax.block_until_ready(idx)
        dt = time.perf_counter() - t0
        self.stats.dispatch_seconds.append(dt)
        self.stats.n_dispatches += 1
        return (np.asarray(vals).reshape(D, R, k),
                np.asarray(idx).reshape(D, R, k), dt)

    def _sharded_dispatches(
        self, user_ids: np.ndarray
    ) -> Iterator[tuple[list[np.ndarray], np.ndarray, np.ndarray]]:
        """Route requests to their user's home shard and drain the per-shard
        queues SPMD: each dispatch takes up to `microbatch` requests from
        EVERY shard's queue at once (uids rebased to shard-local rows,
        padding = local row 0, results dropped). Yields
        (positions-per-shard, vals (D, R, k), idx (D, R, k)).

        Request-level latency: every request in the drain "arrived" when the
        drain started, so a request served by the w-th dispatch is charged
        the full wall time of dispatches 1..w — the lockstep queueing cost.
        """
        D, R = self.cfg.n_shards, self.cfg.microbatch
        shard = user_ids // self._rows
        queues = [np.nonzero(shard == d)[0] for d in range(D)]
        offs = [0] * D
        t_arrival = time.perf_counter()
        while any(o < len(q) for o, q in zip(offs, queues)):
            uids_l = np.zeros((D, R), np.int32)
            sel = []
            for d in range(D):
                take = queues[d][offs[d] : offs[d] + R]
                offs[d] += len(take)
                uids_l[d, : len(take)] = user_ids[take] % self._rows
                sel.append(take)
            vals, idx, _ = self.serve_wave(uids_l)
            n_real = int(sum(len(t) for t in sel))
            self.stats.n_requests += n_real
            self.stats.request_seconds.extend(
                [time.perf_counter() - t_arrival] * n_real)
            yield sel, vals, idx

    def _serve_sharded(self, user_ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Serve a whole batch SPMD, results in the caller's request order."""
        k = self.cfg.k
        out_v = np.zeros((len(user_ids), k), np.float32)
        out_i = np.full((len(user_ids), k), -1, np.int32)
        for sel, vals, idx in self._sharded_dispatches(user_ids):
            for d, take in enumerate(sel):
                if len(take):
                    out_v[take] = vals[d, : len(take)]
                    out_i[take] = idx[d, : len(take)]
        return out_v, out_i

    def serve_stream(
        self, user_ids: Iterable[int], ordered: bool = False,
        _t_arrival: float | None = None,
    ) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Drain a request stream; yields (user_ids, vals, idx) per
        microbatch — one jitted dispatch each, padding sliced off.

        In sharded mode (``n_shards > 1``) the stream is drained up-front,
        requests route to their home shard, and each yield is one SPMD
        dispatch covering up to `microbatch` requests per shard. By default
        the yield order follows the shard queues, not strict arrival order;
        ``ordered=True`` reassembles results by arrival index and yields the
        maximal arrival-contiguous prefix after each dispatch (same
        dispatches, results buffered — first yields may be delayed until the
        slowest-filling shard completes the requests ahead of them). The
        non-sharded path is always in arrival order."""
        if self._sharded:
            ids = np.asarray(list(user_ids), np.int64)
            if not ordered:
                for sel, vals, idx in self._sharded_dispatches(ids):
                    pos = np.concatenate([t for t in sel if len(t)])
                    v = np.concatenate(
                        [vals[d, : len(t)] for d, t in enumerate(sel) if len(t)])
                    i = np.concatenate(
                        [idx[d, : len(t)] for d, t in enumerate(sel) if len(t)])
                    yield ids[pos], v, i
                return
            n_total, k = len(ids), self.cfg.k
            out_v = np.zeros((n_total, k), np.float32)
            out_i = np.full((n_total, k), -1, np.int32)
            done = np.zeros(n_total, bool)
            emitted = 0
            for sel, vals, idx in self._sharded_dispatches(ids):
                for d, take in enumerate(sel):
                    if len(take):
                        out_v[take] = vals[d, : len(take)]
                        out_i[take] = idx[d, : len(take)]
                        done[take] = True
                stop = emitted
                while stop < n_total and done[stop]:
                    stop += 1
                if stop > emitted:
                    yield ids[emitted:stop], out_v[emitted:stop], out_i[emitted:stop]
                    emitted = stop
            assert emitted == n_total, "sharded drain left requests unserved"
            return
        for buf, n, arr in self._microbatches(user_ids, _t_arrival):
            uids = jnp.asarray(buf)
            t0 = time.perf_counter()
            with trace_lib.span("engine.dispatch", n_real=n,
                                prune=self.cfg.prune):
                if self.cfg.prune:
                    vals, idx = _dispatch_pruned(
                        self.state.U, self.V, self.seen,
                        self._bucket_items, self._user_bucket, uids,
                        k=self.cfg.k, interpret=self.cfg.interpret)
                else:
                    vals, idx = _dispatch_dense(
                        self.state.U, self.V, self.seen, uids,
                        k=self.cfg.k, interpret=self.cfg.interpret)
                jax.block_until_ready(idx)
            t1 = time.perf_counter()
            self.stats.dispatch_seconds.append(t1 - t0)
            self.stats.n_dispatches += 1
            self.stats.n_requests += n
            self.stats.request_seconds.extend((t1 - arr).tolist())
            yield buf[:n], np.asarray(vals)[:n], np.asarray(idx)[:n]

    def serve_microbatch(self, user_ids, return_flags: bool = False):
        """Per-shard INDEPENDENT dispatch primitive: serve ≤ `microbatch`
        requests in one jitted call over the raw factor state, with no SPMD
        lockstep across the mesh — this is what `scheduling.Scheduler` calls
        per shard queue, so one slow or empty queue never holds a global
        batch hostage. Works at any ``n_shards`` (the dispatch reads the
        unsharded state copy the engine keeps for ingest) and is bitwise
        identical per request to `recommend` / the SPMD path: same serve
        kernel, same rows, per-row independent.

        Returns ``(vals (n,k), idx (n,k), service_seconds)`` — plus the
        per-request fallback flags before the seconds if ``return_flags``.
        Fallback handling matches `recommend`: flagged requests (unknown /
        cold / empty-bucket users) are clamped pre-dispatch and overwritten
        with the popularity slate."""
        user_ids = np.asarray(user_ids)
        n, R, k = len(user_ids), self.cfg.microbatch, self.cfg.k
        assert n <= R, f"serve_microbatch takes ≤ microbatch ids ({n} > {R})"
        if n == 0:
            out = (np.empty((0, k), np.float32), np.empty((0, k), np.int32))
            return out + ((np.empty(0, bool),) if return_flags else ()) + (0.0,)
        flags = (self._fallback_mask(user_ids) if self.cfg.fallback
                 else np.zeros(n, bool))
        buf = np.zeros(R, np.int32)
        buf[:n] = np.where(flags, 0, user_ids)
        buf[n:] = buf[0]           # pad with a real user id (results dropped)
        t0 = time.perf_counter()
        with trace_lib.span("engine.serve_microbatch", n_real=n):
            vals, idx = _dispatch_rows(
                self.state.U, self.state.P, self.state.Q, self.seen,
                self._bucket_items, self._user_bucket, jnp.asarray(buf),
                k=k, interpret=self.cfg.interpret, prune=self.cfg.prune)
            jax.block_until_ready(idx)
        dt = time.perf_counter() - t0
        self.stats.dispatch_seconds.append(dt)
        self.stats.request_seconds.extend([dt] * n)
        self.stats.n_dispatches += 1
        self.stats.n_requests += n
        vals = np.array(np.asarray(vals)[:n])
        idx = np.array(np.asarray(idx)[:n])
        if flags.any():
            vals[flags] = self._pop_vals
            idx[flags] = self._pop_items
            self.stats.n_fallbacks += int(flags.sum())
        if return_flags:
            return vals, idx, flags, dt
        return vals, idx, dt

    def recommend(self, user_ids, return_flags: bool = False):
        """Convenience: serve a whole batch of user ids, results aligned to
        the input order (also in sharded mode).

        Graceful degradation (``cfg.fallback``, on by default): requests the
        factor path cannot serve — unknown ids, cold-start users, empty
        candidate buckets — return the popularity slate instead of garbage;
        their ids are clamped to row 0 before dispatch (essential in
        sharded mode, where an out-of-range id would route to no shard) and
        the dispatched rows are overwritten. ``return_flags=True`` appends
        the per-request fallback bool mask to the result."""
        user_ids = np.asarray(user_ids)
        k = self.cfg.k
        if len(user_ids) == 0:
            out = (np.empty((0, k), np.float32), np.empty((0, k), np.int32))
            return out + (np.empty(0, bool),) if return_flags else out
        flags = (self._fallback_mask(user_ids) if self.cfg.fallback
                 else np.zeros(len(user_ids), bool))
        safe_ids = np.where(flags, 0, user_ids)
        if self._sharded:
            vals, idx = self._serve_sharded(safe_ids.astype(np.int64))
        else:
            vals, idx = [], []
            t_call = time.perf_counter()
            for _, v, i in self.serve_stream(
                    (int(u) for u in safe_ids), _t_arrival=t_call):
                vals.append(v)
                idx.append(i)
            vals, idx = np.concatenate(vals), np.concatenate(idx)
        if flags.any():
            vals[flags] = self._pop_vals
            idx[flags] = self._pop_items
            self.stats.n_fallbacks += int(flags.sum())
        if return_flags:
            return vals, idx, flags
        return vals, idx

    @property
    def requests_per_sec(self) -> float:
        s = sum(self.stats.dispatch_seconds)
        return self.stats.n_requests / s if s > 0 else float("nan")

    # ----------------------------------------------------------------- ingest
    def ingest(
        self,
        events: np.ndarray,
        ocfg: online_lib.OnlineConfig = online_lib.OnlineConfig(),
        rng: np.random.Generator | None = None,
    ) -> online_lib.RefreshReport:
        """Stream new check-ins through the online refresh and patch the
        served state: U/P/Q via Eq. 9-11 + neighbor scatter, the V = P + Q
        view only on touched rows, the seen-filter only on affected rows
        (the new check-ins drop out of those users' candidate sets)."""
        assert self.nbr is not None and self.dmf_cfg is not None, (
            "engine built without nbr/dmf_cfg — online refresh unavailable")
        events = np.asarray(events)
        with trace_lib.span("engine.ingest", n_events=len(events)):
            self.state, report = online_lib.online_refresh(
                self.state, self.nbr, events, self.dmf_cfg, ocfg,
                rng if rng is not None else self._rng)
        if not self._sharded and len(report.touched_users):
            t = jnp.asarray(report.touched_users)
            self.V = self.V.at[t].set(self.state.P[t] + self.state.Q[t])
        if len(events):
            self.seen = self.seen.at[events[:, 0], events[:, 1]].set(1)
        if self._sharded:
            # apply the row patches to the sharded served views (global
            # row ids are unchanged by padding — the pad sits at the end)
            if len(report.touched_users):
                t = jnp.asarray(report.touched_users)
                self._V_sh = self._V_sh.at[t].set(
                    self.state.P[t] + self.state.Q[t])
            if len(report.affected_users):
                a = jnp.asarray(report.affected_users)
                self._U_sh = self._U_sh.at[a].set(self.state.U[a])
            if len(events):
                self._seen_sh = self._seen_sh.at[
                    events[:, 0], events[:, 1]].set(1)
        if len(events):
            # keep the degradation state fresh: a user with a first
            # check-in stops being cold, and popularity tracks the stream
            np.add.at(self._item_counts, events[:, 1].astype(np.int64), 1)
            self._cold[events[:, 0].astype(np.int64)] = False
            self._refresh_popularity()
        self.stats.n_refreshes += 1
        self.stats.n_events += int(len(events))
        return report
