"""Online factor refresh: streamed check-ins update served factors in place.

Following "Practical Privacy Preserving POI Recommendation" (Chen et al.)
and "Decentralized Collaborative Learning Framework for Next POI
Recommendation" (Long et al.), on-device inference comes with *incremental*
refresh: when user i checks in at POI j, the learner applies the paper's
Eqs. 9-11 local SGD step for (i, j) — plus a few sampled negatives, exactly
the training-time objective — and ships only the global-factor gradient
∂L/∂p^i_j to its ≤D-hop `walk_neighbor_table` receivers. Ratings never
leave the user; the privacy contract is unchanged from training (the same
`core/dmf._sparse_batch_update` executes the step).

Locality guarantee (unit-tested): one refresh touches
  * U rows:  only the users with new check-ins ("affected"),
  * Q rows:  only affected users,
  * P rows:  only the union of the affected users' neighbor-table receivers
             (which includes the senders themselves),
and nothing else — the served population keeps its factors bit-identical.

Events are padded to a fixed dispatch shape (``OnlineConfig.batch_cap``)
so every refresh reuses one compiled step; padded rows carry conf=0 and
valid=0 and contribute exactly nothing (see `_sparse_batch_update`). The
U/P/Q buffers are donated to the step — refresh is in-place at the XLA
level, no copy of the (I, J, K) factors per event batch.

DP (``cfg.dp``): the refresh runs the same privacy/mechanism.py clip+noise
pass over each outgoing gradient message as training — the online channel
is not a side door around the mechanism. Each `online_refresh` call draws
one fresh mechanism seed from its rng (DP off: no draw, stream unchanged)
and keys noise by the row's position in the refresh stream.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dmf
from repro.core import graph as graph_lib


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    batch_cap: int = 256    # fixed event-batch shape (events + negatives)
    steps: int = 4          # local SGD passes over the event batch
    neg_samples: int = 3    # m fresh unobserved negatives per check-in


@dataclasses.dataclass
class RefreshReport:
    affected_users: np.ndarray   # unique users with new check-ins
    touched_users: np.ndarray    # affected ∪ their neighbor-table receivers
    losses: list[float]          # per-step batch loss on the event batch
    n_events: int
    n_batches: int


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1, 2))
def _refresh_step(U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf, valid, rid,
                  dp_seed, cfg):
    return dmf._sparse_batch_update(
        U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf, cfg, valid=valid,
        rid=rid, dp_seed=dp_seed,
    )


def _event_batches(events: np.ndarray, cfg: dmf.DMFConfig, ocfg: OnlineConfig,
                   rng: np.random.Generator, rid_offset: int = 0):
    """Pack check-ins + per-event negatives into fixed-shape (cap,) batches.

    Negatives are freshly sampled unobserved items with confidence 1/m via
    the training-time `dmf.sample_with_negatives` (same objective by
    construction) — without them a refresh would only push scores up and
    drift the ranking calibration. ``rid_offset`` shifts the rows' DP
    noise-key ids so successive local passes over the same events never
    reuse a noise draw."""
    ui, vj, r, conf = dmf.sample_with_negatives(
        events, cfg.n_items, ocfg.neg_samples, rng)

    cap = ocfg.batch_cap
    total = len(ui)
    for s in range(0, total, cap):
        sl = slice(s, min(s + cap, total))
        b = sl.stop - sl.start
        pad = cap - b
        yield (
            jnp.asarray(np.pad(ui[sl], (0, pad)).astype(np.int32)),
            jnp.asarray(np.pad(vj[sl], (0, pad)).astype(np.int32)),
            jnp.asarray(np.pad(r[sl], (0, pad)).astype(np.float32)),
            jnp.asarray(np.pad(conf[sl], (0, pad)).astype(np.float32)),
            jnp.asarray((np.arange(cap) < b).astype(np.float32)),
            jnp.asarray(rid_offset + s + np.arange(cap, dtype=np.int32)),
        )


def touched_from_events(events: np.ndarray,
                        nbr: graph_lib.NeighborTable) -> tuple[np.ndarray, np.ndarray]:
    """(affected, touched): the users whose factors a refresh may write.
    Touched = affected ∪ {their positive-weight neighbor-table receivers};
    padded table slots (weight 0) are scatter no-ops and don't count."""
    affected = np.unique(np.asarray(events)[:, 0]).astype(np.int64)
    idx = np.asarray(nbr.idx)[affected]
    wgt = np.asarray(nbr.wgt)[affected]
    receivers = np.unique(idx[wgt > 0])
    touched = np.union1d(affected, receivers)
    return affected, touched


def online_refresh(
    state: dmf.DMFState,
    nbr: graph_lib.NeighborTable,
    events: np.ndarray,            # (n, 2) int (user, item) new check-ins
    cfg: dmf.DMFConfig,
    ocfg: OnlineConfig = OnlineConfig(),
    rng: np.random.Generator | None = None,
) -> tuple[dmf.DMFState, RefreshReport]:
    """Apply the Eq. 9-11 local step for the affected users and scatter the
    global-factor gradients to their neighbor-table receivers. Returns the
    refreshed state and a locality report.

    **Takes ownership of ``state``'s buffers**: they are donated to the
    refresh step (no (I, J, K) copy per event batch) and deleted by XLA —
    reading the old ``state`` afterwards raises. Pass a copy
    (``jnp.array(x)`` per field) if the caller still needs it;
    `ServingEngine` copies once at construction for exactly this reason."""
    events = np.asarray(events)
    if len(events) == 0:
        return state, RefreshReport(
            np.empty(0, np.int64), np.empty(0, np.int64), [], 0, 0)
    if cfg.dp and rng is None:
        # the fallback rng is freshly seeded from cfg.seed EVERY call: under
        # DP that would re-derive the same noise seed per refresh window,
        # and repeated noise cancels in update differences — the exact leak
        # the mechanism exists to prevent. A persistent stream is required
        # (ServingEngine.ingest holds one; pass your own otherwise).
        raise ValueError(
            "online_refresh with DP on needs an explicit persistent rng — "
            "the default would reuse the same noise stream every call")
    rng = rng or np.random.default_rng(cfg.seed)
    affected, touched = touched_from_events(events, nbr)

    dp_seed = 0
    if cfg.dp:
        from repro.privacy import mechanism
        dp_seed = mechanism.epoch_noise_seed(rng, cfg)
    dp_seed_j = jnp.asarray(dp_seed, jnp.int32)
    stream_len = len(events) * (1 + ocfg.neg_samples)

    U, P, Q = state.U, state.P, state.Q
    losses = []
    n_batches = 0
    for step in range(ocfg.steps):
        for ui, vj, r, conf, valid, rid in _event_batches(
                events, cfg, ocfg, rng, rid_offset=step * stream_len):
            U, P, Q, loss = _refresh_step(
                U, P, Q, nbr.idx, nbr.wgt, ui, vj, r, conf, valid, rid,
                dp_seed_j, cfg)
            losses.append(float(loss))
            n_batches += 1
    report = RefreshReport(
        affected_users=affected,
        touched_users=touched,
        losses=losses,
        n_events=int(len(events)),
        n_batches=n_batches,
    )
    return dmf.DMFState(U, P, Q), report
