"""Million-user serving: HBM-resident tiled factor store + quantized engine.

The per-learner factor model (each user i owns an item view v^i = p^i + q^i)
is an (I, J, K) tensor — 3.2 TB of fp32 at I=1M, J=100k, K=8, physically
impossible to materialize. But serving never READS more of v^i than the
user's candidate window: the engine scores exactly the POIs of the user's
geo cell. The `TiledFactorStore` therefore keeps, per user, ONLY that
window:

    slab (I, cap, K)   — v^i at the user's bucket items, column c of row i
                         being the factor of ``bucket_items[bucket(i), c]``
    seen (I, cap) int8 — the user's seen bits, same column alignment
    U    (I, K)        — user factors

With the hierarchical (geohash-cell) index capping buckets at ~128, the 1M
× 100k config fits in ~4 GB fp32 — and int8 codes (+ per-user scale) or
bf16 cut that by 4x / 2x again. A request gathers its (R, cap, K) windows
straight off the slab and runs the tiled serve kernel
(`ops.serve_topk_window` / `serve_topk_window_quant`) — identical compute
to the classic engine's pruned path, so the fp32 store path is bitwise
identical to `ServingEngine.recommend` on the shared support (pinned by
tests and BENCH_serving).

Quantization error budget (measured in BENCH_serving, asserted in tests):

    int8: codes = rint(v / scale), scale = max|v^i| / 127 per user
          ⇒ |Δv| ≤ scale/2        ⇒ |Δscore| ≤ ||u_i||₁ · scale/2
    bf16: round-to-nearest, 8-bit significand ⇒ |Δv| ≤ 2⁻⁸|v|
          ⇒ |Δscore| ≤ Σ_k |u_k·v_k| · 2⁻⁸

Row sharding: `shard_rows` slices the store along `sharding.dmf`'s
ceil-div row layout (`shard_row_slices`), so a fleet of per-shard engines
routes requests with the same ``user // rows_per_shard`` rule as the SPMD
serving mesh — shard-local results are bitwise identical to the unsharded
store (row-parallel, no cross-shard reads).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.kernels import ops
from repro.serving.candidates import CandidateIndex
from repro.serving.engine import EngineStats, ServingConfig

_BF16_EPS = 2.0 ** -8     # round-to-nearest relative error bound of bfloat16


def _bf16_dtype():
    import jax.numpy as jnp
    return jnp.bfloat16


def synthetic_world(
    n_users: int, n_items: int, n_cities: int, seed: int = 0,
    zipf_a: float = 0.8, city_sigma: float = 0.03,
):
    """Vectorized million-scale geography (the per-user Python loop in
    `data/synthetic_poi.generate` is unusable at I=1M): zipf-weighted city
    assignment for users and POIs, Gaussian coordinates around each city
    center. Returns (user_city, item_city, user_coords, item_coords)."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_cities + 1) ** zipf_a
    w /= w.sum()
    user_city = rng.choice(n_cities, size=n_users, p=w).astype(np.int32)
    item_city = rng.choice(n_cities, size=n_items, p=w).astype(np.int32)
    centers = rng.uniform(0.0, 1.0, size=(n_cities, 2))
    user_coords = (centers[user_city]
                   + city_sigma * rng.standard_normal((n_users, 2)))
    item_coords = (centers[item_city]
                   + city_sigma * rng.standard_normal((n_items, 2)))
    return user_city, item_city, user_coords.astype(np.float64), \
        item_coords.astype(np.float64)


@dataclasses.dataclass(frozen=True)
class SyntheticFactors:
    """Deterministic rank-structured factor generator for million-scale
    benches: v^i_j = B1_j · s_i + B2_j from O(J·K) tables, so the dense
    full-J item view of ANY user recomputes exactly (`dense_rows`) — that
    is what lets a 1M-user store be cross-checked bitwise against a small
    dense sub-engine on sampled users."""
    B1: np.ndarray        # (J, K) f32 shared item basis
    B2: np.ndarray        # (J, K) f32 shared item offset
    s_user: np.ndarray    # (I,) f32 per-user blend
    U: np.ndarray         # (I, K) f32 user factors

    @classmethod
    def create(cls, n_users: int, n_items: int, dim: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        return cls(
            B1=rng.standard_normal((n_items, dim)).astype(np.float32),
            B2=(0.1 * rng.standard_normal((n_items, dim))).astype(np.float32),
            s_user=rng.standard_normal(n_users).astype(np.float32),
            U=(rng.standard_normal((n_users, dim)).astype(np.float32)
               / np.float32(np.sqrt(dim))),
        )

    def item_rows(self, users: np.ndarray, items: np.ndarray) -> np.ndarray:
        """v^{users[r]} at ``items[r]`` — items (n, m) int, any values OK
        for negative ids' positions (callers mask). Returns (n, m, K) f32."""
        safe = np.maximum(items, 0)
        return (self.B1[safe] * self.s_user[users][:, None, None]
                + self.B2[safe])

    def dense_rows(self, users: np.ndarray) -> np.ndarray:
        """Full (len(users), J, K) item views — the oracle input for
        bitwise cross-checks of the tiled store at sampled users."""
        return (self.B1[None, :, :] * self.s_user[users][:, None, None]
                + self.B2[None, :, :])


@dataclasses.dataclass
class TiledFactorStore:
    """Per-user candidate-window factor slabs, HBM(host)-resident; see the
    module docstring. ``seen`` is column-aligned to
    ``index.bucket_items[index.user_bucket]``; ``cold``/``item_counts``
    carry the engine's graceful-degradation state (same semantics as
    `ServingEngine`: cold = user with no interactions anywhere)."""
    U: np.ndarray                     # (I, K) f32
    slab: np.ndarray                  # (I, cap, K) f32
    seen: np.ndarray                  # (I, cap) int8
    index: CandidateIndex
    cold: np.ndarray                  # (I,) bool
    item_counts: np.ndarray           # (J,) int64 check-in counts
    q_codes: np.ndarray | None = None   # (I, cap, K) int8
    q_scale: np.ndarray | None = None   # (I,) f32, dequant = codes · scale
    slab_bf16: np.ndarray | None = None  # (I, cap, K) bfloat16

    @property
    def n_users(self) -> int:
        return int(self.U.shape[0])

    @property
    def cap(self) -> int:
        return int(self.slab.shape[1])

    @property
    def dim(self) -> int:
        return int(self.U.shape[1])

    def nbytes(self) -> dict[str, int]:
        out = {"U": self.U.nbytes, "slab_fp32": self.slab.nbytes,
               "seen": self.seen.nbytes}
        if self.q_codes is not None:
            out["slab_int8"] = self.q_codes.nbytes + self.q_scale.nbytes
        if self.slab_bf16 is not None:
            out["slab_bf16"] = self.slab_bf16.nbytes
        return out

    # ------------------------------------------------------------ builders
    @classmethod
    def from_state(cls, state, index: CandidateIndex, seen: np.ndarray,
                   chunk_rows: int = 65536) -> "TiledFactorStore":
        """Build from a trained `DMFState` + dense (I, J) seen mask — the
        small-scale path used to cross-check the store against the classic
        engine. Gathers V = P + Q windows chunked (the full V never
        materializes here either)."""
        P = np.asarray(state.P)
        Q = np.asarray(state.Q)
        U = np.asarray(state.U, dtype=np.float32)
        seen = np.asarray(seen).astype(bool)
        I, cap = len(U), index.cap
        slab = np.empty((I, cap, P.shape[2]), np.float32)
        seen_w = np.zeros((I, cap), np.int8)
        for s in range(0, I, chunk_rows):
            e = min(s + chunk_rows, I)
            rows = np.arange(s, e)
            cand = index.bucket_items[index.user_bucket[rows]]
            safe = np.maximum(cand, 0)
            slab[s:e] = P[rows[:, None], safe] + Q[rows[:, None], safe]
            seen_w[s:e] = np.where(
                cand >= 0, seen[rows[:, None], safe], False).astype(np.int8)
        return cls(U=U, slab=slab, seen=seen_w, index=index,
                   cold=~seen.any(axis=1),
                   item_counts=seen.sum(axis=0).astype(np.int64))

    @classmethod
    def synthetic(cls, synth: SyntheticFactors, index: CandidateIndex,
                  seen_per_user: int = 4, seed: int = 0,
                  chunk_rows: int = 131072) -> "TiledFactorStore":
        """Million-scale builder: fill the slab from the rank-structured
        generator (chunked — peak extra memory is one chunk of windows) and
        sample ``seen_per_user`` seen bits per user inside their bucket."""
        rng = np.random.default_rng(seed)
        I, cap = len(synth.s_user), index.cap
        J, K = synth.B1.shape
        slab = np.empty((I, cap, K), np.float32)
        seen_w = np.zeros((I, cap), np.int8)
        counts = np.zeros(J, np.int64)
        for s in range(0, I, chunk_rows):
            e = min(s + chunk_rows, I)
            rows = np.arange(s, e)
            cand = index.bucket_items[index.user_bucket[rows]]
            slab[s:e] = synth.item_rows(rows, cand)
            size = index.bucket_size[index.user_bucket[rows]]
            if seen_per_user > 0:
                # sample positions within each user's real bucket extent
                pos = np.floor(rng.random((e - s, seen_per_user))
                               * np.maximum(size, 1)[:, None]).astype(np.int64)
                has = size > 0
                seen_w[np.repeat(rows, seen_per_user)[np.repeat(has, seen_per_user)],
                       pos[has].ravel()] = 1
                # counts from the SET bits (not the raw samples, which can
                # collide within a user): item_counts stays consistent with
                # the seen mask, sum(counts) == sum(seen)
                ri, ci = np.nonzero(seen_w[s:e])
                np.add.at(counts, cand[ri, ci], 1)
        return cls(U=synth.U, slab=slab, seen=seen_w, index=index,
                   cold=np.zeros(I, bool), item_counts=counts)

    # --------------------------------------------------------- quantization
    def quantize_int8(self, chunk_rows: int = 131072) -> None:
        """Per-user symmetric int8: scale_i = max|slab_i| / 127 (floored at
        a tiny eps so all-zero rows stay exact), codes = rint(v / scale)
        clipped to ±127 — elementwise error ≤ scale/2."""
        I, cap, K = self.slab.shape
        codes = np.empty((I, cap, K), np.int8)
        scale = np.empty(I, np.float32)
        for s in range(0, I, chunk_rows):
            e = min(s + chunk_rows, I)
            amax = np.abs(self.slab[s:e]).max(axis=(1, 2))
            sc = np.maximum(amax / 127.0, 1e-12).astype(np.float32)
            codes[s:e] = np.clip(
                np.rint(self.slab[s:e] / sc[:, None, None]),
                -127, 127).astype(np.int8)
            scale[s:e] = sc
        self.q_codes, self.q_scale = codes, scale

    def quantize_bf16(self) -> None:
        self.slab_bf16 = self.slab.astype(_bf16_dtype())

    def int8_score_bound(self, users: np.ndarray) -> np.ndarray:
        """Per-request analytic |Δscore| bound: ||u||₁ · scale/2."""
        assert self.q_scale is not None, "quantize_int8 first"
        users = np.asarray(users)
        return (np.abs(self.U[users]).sum(axis=1)
                * self.q_scale[users] * 0.5).astype(np.float64)

    def bf16_score_bound(self, users: np.ndarray) -> np.ndarray:
        """Per-request analytic |Δscore| bound: max_c Σ_k |u_k·v_kc| · 2⁻⁸."""
        users = np.asarray(users)
        u = np.abs(self.U[users])                          # (n, K)
        w = np.abs(self.slab[users])                       # (n, cap, K)
        return ((w * u[:, None, :]).sum(axis=2).max(axis=1)
                * _BF16_EPS).astype(np.float64)

    # ---------------------------------------------------------- row sharding
    def shard_rows(self, n_shards: int) -> list[tuple[int, "TiledFactorStore"]]:
        """Host-level row sharding: numpy VIEWS of the slabs per shard (no
        copy), user buckets rebased to shard-local rows. Returns
        [(row_start, shard_store), ...] along `sharding.dmf`'s ceil-div row
        layout so routing is ``user // rows_per_shard``."""
        from repro.sharding.dmf import shard_row_slices
        out = []
        for s, e in shard_row_slices(self.n_users, n_shards):
            idx = dataclasses.replace(
                self.index, user_bucket=self.index.user_bucket[s:e])
            out.append((s, TiledFactorStore(
                U=self.U[s:e], slab=self.slab[s:e], seen=self.seen[s:e],
                index=idx, cold=self.cold[s:e],
                item_counts=self.item_counts,
                q_codes=None if self.q_codes is None else self.q_codes[s:e],
                q_scale=None if self.q_scale is None else self.q_scale[s:e],
                slab_bf16=(None if self.slab_bf16 is None
                           else self.slab_bf16[s:e]),
            )))
        return out


class TiledServingEngine:
    """Microbatched serving straight off a `TiledFactorStore` — the
    million-scale sibling of `ServingEngine`, same `ServingConfig`, same
    `EngineStats`, same graceful degradation (unknown / cold / empty-bucket
    requests get the popularity slate, flagged). ``mode`` picks the factor
    precision: 'fp32' (bitwise identical to `ServingEngine.recommend` built
    on the same factors), 'int8' or 'bf16' (bounded score error, see the
    module docstring)."""

    def __init__(self, store: TiledFactorStore,
                 cfg: ServingConfig = ServingConfig(), *, mode: str = "fp32"):
        assert mode in ("fp32", "int8", "bf16"), mode
        if mode == "int8" and store.q_codes is None:
            store.quantize_int8()
        if mode == "bf16" and store.slab_bf16 is None:
            store.quantize_bf16()
        assert cfg.prune, "the tiled store IS the pruned candidate path"
        assert cfg.n_shards == 1, "shard via store.shard_rows + one engine each"
        self.store = store
        self.cfg = cfg
        self.mode = mode
        self.stats = EngineStats()
        self._bucket_empty = (store.index.bucket_items < 0).all(axis=1)
        # popularity fallback slate — same construction as
        # ServingEngine._refresh_popularity (stable argsort, count/max score)
        top = np.argsort(-store.item_counts, kind="stable")
        self._pop_items = top[: cfg.k].astype(np.int32)
        peak = max(int(store.item_counts.max()), 1)
        self._pop_vals = (
            store.item_counts[self._pop_items] / peak).astype(np.float32)

    def _fallback_mask(self, user_ids: np.ndarray) -> np.ndarray:
        uids = np.asarray(user_ids)
        n = self.store.n_users
        unknown = (uids < 0) | (uids >= n)
        safe = np.clip(uids, 0, n - 1)
        return (unknown | self.store.cold[safe]
                | self._bucket_empty[self.store.index.user_bucket[safe]])

    def _dispatch(self, uids: np.ndarray):
        """One fixed-shape microbatch over host-gathered windows: the only
        arrays that ever leave the HBM-resident store are the (R, cap, K)
        windows of the requests in flight."""
        import jax

        from repro.obs import trace as trace_lib
        st, k = self.store, self.cfg.k
        with trace_lib.span("tiled.dispatch", mode=self.mode):
            cand = st.index.bucket_items[st.index.user_bucket[uids]]
            u = st.U[uids]
            sw = st.seen[uids]
            if self.mode == "fp32":
                vals, idx = ops.serve_topk_window(
                    u, st.slab[uids], cand, sw, k,
                    interpret=self.cfg.interpret)
            elif self.mode == "int8":
                vals, idx = ops.serve_topk_window_quant(
                    u, st.q_codes[uids], st.q_scale[uids], cand, sw, k,
                    interpret=self.cfg.interpret)
            else:
                vals, idx = ops.serve_topk_window_quant(
                    u, st.slab_bf16[uids], np.ones(len(uids), np.float32),
                    cand, sw, k, interpret=self.cfg.interpret)
            jax.block_until_ready(idx)
        return np.asarray(vals), np.asarray(idx)

    def recommend(self, user_ids, return_flags: bool = False):
        """Serve a batch of user ids, results in input order — the same
        contract as `ServingEngine.recommend` (fallback slates flagged)."""
        user_ids = np.asarray(user_ids)
        R, k = self.cfg.microbatch, self.cfg.k
        n = len(user_ids)
        if n == 0:
            out = (np.empty((0, k), np.float32), np.empty((0, k), np.int32))
            return out + (np.empty(0, bool),) if return_flags else out
        flags = (self._fallback_mask(user_ids) if self.cfg.fallback
                 else np.zeros(n, bool))
        safe_ids = np.where(flags, 0, user_ids).astype(np.int64)
        vals = np.empty((n, k), np.float32)
        idx = np.empty((n, k), np.int32)
        t_call = time.perf_counter()
        for s in range(0, n, R):
            e = min(s + R, n)
            buf = np.empty(R, np.int64)
            buf[: e - s] = safe_ids[s:e]
            buf[e - s:] = buf[0]   # pad with a real id (results dropped)
            t0 = time.perf_counter()
            v, i = self._dispatch(buf)
            t1 = time.perf_counter()
            vals[s:e] = v[: e - s]
            idx[s:e] = i[: e - s]
            self.stats.dispatch_seconds.append(t1 - t0)
            self.stats.request_seconds.extend([t1 - t_call] * (e - s))
            self.stats.n_dispatches += 1
            self.stats.n_requests += e - s
        if flags.any():
            vals[flags] = self._pop_vals
            idx[flags] = self._pop_items
            self.stats.n_fallbacks += int(flags.sum())
        if return_flags:
            return vals, idx, flags
        return vals, idx

    @property
    def requests_per_sec(self) -> float:
        s = sum(self.stats.dispatch_seconds)
        return self.stats.n_requests / s if s > 0 else float("nan")
