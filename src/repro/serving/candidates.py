"""City-bucketed candidate index — the paper's Fig. 2 turned into a pruning
structure.

The paper observes ("location aggregation") that users check in almost
exclusively inside their home city, and the synthetic data gate reproduces
it (`cross_city_frac` ~ 3%). A production server exploits exactly this
structure: a request from user i only needs to score the POIs of city(i),
so per-request cost drops from O(J·K) to O(|city items|·K) — the move that
makes millions-of-users traffic plausible when J is in the millions while a
city holds thousands.

The index is a fixed-shape table so every microbatch compiles to one
dispatch shape:

* ``bucket_items (C, cap) int32`` — each city's POI ids in **ascending id
  order**, padded with -1 to a shared cap (a lane multiple). Ascending
  order is contractual: the serving kernel scans candidate tiles left to
  right and breaks score ties in favor of the earliest candidate, which
  then matches `jax.lax.top_k`'s lowest-index tie-break exactly — zero-init
  item factors make exact 0.0 score ties common, so this is load-bearing
  for the engine == dense-oracle equality guarantee.
* ``user_bucket (I,)`` — home-city bucket per user (the request router key).

Capacity overflow (a city larger than ``cap``) keeps the ``cap`` items of
highest priority (popularity when given, lowest ids otherwise) and records
the truncation — those users lose exactness vs the dense oracle, which the
engine reports rather than hides.

The seen-filter (the user's train mask) is intentionally *not* baked in
here: seen-ness is per-user mutable state (online check-ins arrive while
serving), owned by the engine and applied inside the serve kernel.
"""
from __future__ import annotations

import dataclasses

import numpy as np

LANE = 128


@dataclasses.dataclass(frozen=True)
class CandidateIndex:
    bucket_items: np.ndarray    # (C, cap) int32, -1 padded, ascending per row
    bucket_size: np.ndarray     # (C,) int32 — items actually indexed (≤ cap)
    city_size: np.ndarray       # (C,) int32 — true city sizes (pre-truncation)
    user_bucket: np.ndarray     # (I,) int32 home bucket per user
    n_items: int

    @property
    def cap(self) -> int:
        return int(self.bucket_items.shape[1])

    @property
    def n_buckets(self) -> int:
        return int(self.bucket_items.shape[0])

    @property
    def n_truncated_buckets(self) -> int:
        return int((self.city_size > self.bucket_size).sum())

    def user_fits(self) -> np.ndarray:
        """(I,) bool — True where the user's full city fits the bucket, i.e.
        the geo-pruned candidate set is lossless for that user."""
        return (self.city_size == self.bucket_size)[self.user_bucket]

    def eligible_mask_chunks(self, users: np.ndarray, rows_per_chunk: int = 256):
        """Yield ``(row_start, mask_chunk)`` over ``users`` in order, each
        chunk a dense (≤rows_per_chunk, J) bool eligibility block. This is
        the J=100k-safe oracle path: peak memory is O(rows_per_chunk · J)
        instead of O(len(users) · J), so dense-reference comparisons still
        run at million-user scale."""
        users = np.asarray(users)
        for s in range(0, len(users), rows_per_chunk):
            chunk = users[s : s + rows_per_chunk]
            items = self.bucket_items[self.user_bucket[chunk]]   # (r, cap)
            rows, cols = np.nonzero(items >= 0)
            elig = np.zeros((len(chunk), self.n_items), dtype=bool)
            elig[rows, items[rows, cols]] = True
            yield s, elig

    def eligible_mask(self, users: np.ndarray,
                      rows_per_chunk: int | None = None) -> np.ndarray:
        """(len(users), J) bool — candidate-eligibility rows, the dense-oracle
        counterpart of the bucket gather (tests / ref path). Built by
        vectorized scatter in row chunks (`eligible_mask_chunks`); the
        result is still dense — callers at J=100k scale should consume the
        chunk generator instead of materializing all rows."""
        users = np.asarray(users)
        out = np.zeros((len(users), self.n_items), dtype=bool)
        step = rows_per_chunk or max(len(users), 1)
        for s, elig in self.eligible_mask_chunks(users, step):
            out[s : s + len(elig)] = elig
        return out


def build_candidate_index(
    item_city: np.ndarray,
    user_city: np.ndarray,
    *,
    n_items: int | None = None,
    cap: int | None = None,
    pad_to: int = LANE,
    item_priority: np.ndarray | None = None,
) -> CandidateIndex:
    """Bucket POIs by city. ``cap`` bounds the per-bucket candidate count
    (default: the largest city, rounded up to ``pad_to`` — lossless);
    ``item_priority`` (higher = kept first, e.g. popularity counts) decides
    what survives truncation when a city overflows ``cap``."""
    item_city = np.asarray(item_city).reshape(-1)
    user_city = np.asarray(user_city).reshape(-1)
    J = int(n_items) if n_items is not None else int(len(item_city))
    assert len(item_city) == J, (len(item_city), J)
    # Bucket count covers BOTH label arrays: a city can legally hold users
    # but zero POIs (common at 100k-POI scale — sparse cities). Those users
    # get an all-empty bucket, which the engine routes to the popularity
    # fallback instead of crashing here. Empty label arrays (no users yet /
    # no items yet) build a valid one-empty-bucket index without touching
    # `.min()`/`.max()` on an empty array.
    if len(item_city):
        assert int(item_city.min()) >= 0, "negative item city"
    if len(user_city):
        assert int(user_city.min()) >= 0, "negative user city"
    C = max(
        int(item_city.max()) + 1 if len(item_city) else 0,
        int(user_city.max()) + 1 if len(user_city) else 0,
        1,
    )

    # group items by city via one stable sort — ascending item id within
    # each city falls out of stability, and build cost stays O(J log J)
    # at J=100k instead of the O(C·J) per-city scan
    order = np.argsort(item_city, kind="stable") if len(item_city) else (
        np.empty(0, dtype=np.int64))
    sorted_city = item_city[order]
    starts = np.searchsorted(sorted_city, np.arange(C), side="left")
    ends = np.searchsorted(sorted_city, np.arange(C), side="right")
    buckets = [order[s:e] for s, e in zip(starts, ends)]
    city_size = (ends - starts).astype(np.int32)
    max_city = int(city_size.max()) if C else 0
    if cap is None:
        cap = max_city
    cap = max(int(-(-max(cap, 1) // pad_to)) * pad_to, pad_to)

    bucket_items = np.full((C, cap), -1, dtype=np.int32)
    bucket_size = np.zeros(C, dtype=np.int32)
    for c, items in enumerate(buckets):
        if len(items) > cap:
            if item_priority is not None:
                keep = items[np.argsort(-np.asarray(item_priority)[items],
                                        kind="stable")[:cap]]
            else:
                keep = items[:cap]
            items = np.sort(keep)   # ascending-id order is contractual
        bucket_items[c, : len(items)] = items
        bucket_size[c] = len(items)
    return CandidateIndex(
        bucket_items=bucket_items,
        bucket_size=bucket_size,
        city_size=city_size,
        user_bucket=user_city.astype(np.int32),
        n_items=J,
    )


def index_from_dataset(ds, **kw) -> CandidateIndex:
    """Convenience: index straight from a `synthetic_poi.POIDataset`."""
    return build_candidate_index(
        ds.item_city, ds.user_city, n_items=ds.n_items, **kw
    )


@dataclasses.dataclass(frozen=True)
class HierarchicalIndex:
    """Geohash-style refinement of the flat city buckets (see
    `build_hierarchical_index`). ``flat`` is a normal `CandidateIndex` whose
    buckets are the LEAF CELLS — it plugs into the engine/store unchanged;
    the extra arrays describe the hierarchy for reporting and routing."""
    flat: CandidateIndex
    cell_of_item: np.ndarray    # (J,) int32 leaf cell per item
    cell_of_user: np.ndarray    # (I,) int32 leaf cell per user
    cell_city: np.ndarray       # (n_cells,) int32 source city of each cell
    cell_depth: np.ndarray      # (n_cells,) int32 splits below the city root

    @property
    def n_cells(self) -> int:
        return int(len(self.cell_city))

    @property
    def max_depth(self) -> int:
        return int(self.cell_depth.max()) if len(self.cell_depth) else 0

    def stats(self) -> dict:
        """Reporting block for benches: how much the hierarchy shrank the
        serving cap relative to flat city bucketing."""
        depth = self.cell_depth
        return {
            "n_cells": self.n_cells,
            "max_depth": self.max_depth,
            "mean_depth": float(depth.mean()) if len(depth) else 0.0,
            "cap": self.flat.cap,
            "n_empty_cells": int((self.flat.bucket_size == 0).sum()),
            "mean_cell_items": float(self.flat.bucket_size.mean()),
        }


def build_hierarchical_index(
    item_city: np.ndarray,
    user_city: np.ndarray,
    item_coords: np.ndarray,
    user_coords: np.ndarray,
    *,
    cell_cap: int = 128,
    cap: int | None = None,
    pad_to: int = LANE,
    max_depth: int = 16,
    item_priority: np.ndarray | None = None,
) -> HierarchicalIndex:
    """Layer a geohash-style spatial hierarchy on the flat city buckets.

    Flat city bucketing pads every user's candidate window to the LARGEST
    city — at 1M users / 100k POIs with a zipf city-size law the big-city
    cap is thousands, which makes the per-user store slab (I, cap, K)
    physically impossible. This builder recursively halves any city holding
    more than ``cell_cap`` POIs at the midpoint of its item bounding box,
    alternating lon/lat per level (exactly the bit-interleaving order of a
    geohash), until every leaf cell fits ``cell_cap`` or ``max_depth`` is
    reached. Users follow the same splits by their own coordinates, so each
    user's candidate set becomes the POIs of their geohash cell — a refined
    subset of their home city (the paper's Fig. 2 location-aggregation
    argument, applied one more level down).

    The output is a plain `CandidateIndex` over leaf cells (built by
    `build_candidate_index`, so the ascending-id tie contract and the
    fixed-shape table survive) plus the hierarchy metadata. Leaf cells with
    users but no POIs are legal and route to the popularity fallback, same
    as cold cities in the flat index. Degenerate geometry (all items at one
    point) stops splitting early; such oversized leaves are truncated by
    priority in the flat builder and reported as truncation there.
    """
    item_city = np.asarray(item_city).reshape(-1)
    user_city = np.asarray(user_city).reshape(-1)
    item_coords = np.asarray(item_coords, dtype=np.float64).reshape(-1, 2)
    user_coords = np.asarray(user_coords, dtype=np.float64).reshape(-1, 2)
    J, I = len(item_city), len(user_city)
    assert item_coords.shape == (J, 2), (item_coords.shape, J)
    assert user_coords.shape == (I, 2), (user_coords.shape, I)
    n_cities = max(
        int(item_city.max()) + 1 if J else 0,
        int(user_city.max()) + 1 if I else 0,
        1,
    )
    cell_of_item = np.zeros(J, dtype=np.int32)
    cell_of_user = np.zeros(I, dtype=np.int32)
    cell_city: list[int] = []
    cell_depth: list[int] = []

    def emit(cell_items: np.ndarray, cell_users: np.ndarray,
             city: int, depth: int) -> None:
        cid = len(cell_city)
        cell_of_item[cell_items] = cid
        cell_of_user[cell_users] = cid
        cell_city.append(city)
        cell_depth.append(depth)

    for c in range(n_cities):
        items_c = np.flatnonzero(item_city == c)
        users_c = np.flatnonzero(user_city == c)
        if len(items_c) == 0 and len(users_c) == 0:
            continue
        stack = [(items_c, users_c, 0)]
        while stack:
            it, us, depth = stack.pop()
            if len(it) <= cell_cap or depth >= max_depth:
                emit(it, us, c, depth)
                continue
            ax = depth % 2                      # alternate lon/lat per level
            lo = item_coords[it, ax].min()
            hi = item_coords[it, ax].max()
            mid = 0.5 * (lo + hi)
            left_i = item_coords[it, ax] <= mid
            if left_i.all() or not left_i.any():
                emit(it, us, c, depth)          # degenerate: co-located POIs
                continue
            left_u = user_coords[us, ax] <= mid
            stack.append((it[left_i], us[left_u], depth + 1))
            stack.append((it[~left_i], us[~left_u], depth + 1))

    flat = build_candidate_index(
        cell_of_item if J else np.empty(0, np.int32),
        cell_of_user if I else np.empty(0, np.int32),
        n_items=J, cap=cap, pad_to=pad_to, item_priority=item_priority,
    )
    return HierarchicalIndex(
        flat=flat,
        cell_of_item=cell_of_item,
        cell_of_user=cell_of_user,
        cell_city=np.asarray(cell_city, dtype=np.int32),
        cell_depth=np.asarray(cell_depth, dtype=np.int32),
    )
