"""City-bucketed candidate index — the paper's Fig. 2 turned into a pruning
structure.

The paper observes ("location aggregation") that users check in almost
exclusively inside their home city, and the synthetic data gate reproduces
it (`cross_city_frac` ~ 3%). A production server exploits exactly this
structure: a request from user i only needs to score the POIs of city(i),
so per-request cost drops from O(J·K) to O(|city items|·K) — the move that
makes millions-of-users traffic plausible when J is in the millions while a
city holds thousands.

The index is a fixed-shape table so every microbatch compiles to one
dispatch shape:

* ``bucket_items (C, cap) int32`` — each city's POI ids in **ascending id
  order**, padded with -1 to a shared cap (a lane multiple). Ascending
  order is contractual: the serving kernel scans candidate tiles left to
  right and breaks score ties in favor of the earliest candidate, which
  then matches `jax.lax.top_k`'s lowest-index tie-break exactly — zero-init
  item factors make exact 0.0 score ties common, so this is load-bearing
  for the engine == dense-oracle equality guarantee.
* ``user_bucket (I,)`` — home-city bucket per user (the request router key).

Capacity overflow (a city larger than ``cap``) keeps the ``cap`` items of
highest priority (popularity when given, lowest ids otherwise) and records
the truncation — those users lose exactness vs the dense oracle, which the
engine reports rather than hides.

The seen-filter (the user's train mask) is intentionally *not* baked in
here: seen-ness is per-user mutable state (online check-ins arrive while
serving), owned by the engine and applied inside the serve kernel.
"""
from __future__ import annotations

import dataclasses

import numpy as np

LANE = 128


@dataclasses.dataclass(frozen=True)
class CandidateIndex:
    bucket_items: np.ndarray    # (C, cap) int32, -1 padded, ascending per row
    bucket_size: np.ndarray     # (C,) int32 — items actually indexed (≤ cap)
    city_size: np.ndarray       # (C,) int32 — true city sizes (pre-truncation)
    user_bucket: np.ndarray     # (I,) int32 home bucket per user
    n_items: int

    @property
    def cap(self) -> int:
        return int(self.bucket_items.shape[1])

    @property
    def n_buckets(self) -> int:
        return int(self.bucket_items.shape[0])

    @property
    def n_truncated_buckets(self) -> int:
        return int((self.city_size > self.bucket_size).sum())

    def user_fits(self) -> np.ndarray:
        """(I,) bool — True where the user's full city fits the bucket, i.e.
        the geo-pruned candidate set is lossless for that user."""
        return (self.city_size == self.bucket_size)[self.user_bucket]

    def eligible_mask(self, users: np.ndarray) -> np.ndarray:
        """(len(users), J) bool — candidate-eligibility rows, the dense-oracle
        counterpart of the bucket gather (tests / ref path)."""
        users = np.asarray(users)
        elig = np.zeros((len(users), self.n_items), dtype=bool)
        for row, u in enumerate(users):
            items = self.bucket_items[self.user_bucket[u]]
            elig[row, items[items >= 0]] = True
        return elig


def build_candidate_index(
    item_city: np.ndarray,
    user_city: np.ndarray,
    *,
    n_items: int | None = None,
    cap: int | None = None,
    pad_to: int = LANE,
    item_priority: np.ndarray | None = None,
) -> CandidateIndex:
    """Bucket POIs by city. ``cap`` bounds the per-bucket candidate count
    (default: the largest city, rounded up to ``pad_to`` — lossless);
    ``item_priority`` (higher = kept first, e.g. popularity counts) decides
    what survives truncation when a city overflows ``cap``."""
    item_city = np.asarray(item_city)
    user_city = np.asarray(user_city)
    J = int(n_items) if n_items is not None else int(len(item_city))
    assert len(item_city) == J, (len(item_city), J)
    C = int(item_city.max()) + 1 if len(item_city) else 1
    assert user_city.min() >= 0 and int(user_city.max()) < C, "user city out of range"

    buckets = [np.flatnonzero(item_city == c) for c in range(C)]
    city_size = np.array([len(b) for b in buckets], dtype=np.int32)
    max_city = int(city_size.max()) if C else 0
    if cap is None:
        cap = max_city
    cap = max(int(-(-max(cap, 1) // pad_to)) * pad_to, pad_to)

    bucket_items = np.full((C, cap), -1, dtype=np.int32)
    bucket_size = np.zeros(C, dtype=np.int32)
    for c, items in enumerate(buckets):
        if len(items) > cap:
            if item_priority is not None:
                keep = items[np.argsort(-np.asarray(item_priority)[items],
                                        kind="stable")[:cap]]
            else:
                keep = items[:cap]
            items = np.sort(keep)   # ascending-id order is contractual
        bucket_items[c, : len(items)] = items
        bucket_size[c] = len(items)
    return CandidateIndex(
        bucket_items=bucket_items,
        bucket_size=bucket_size,
        city_size=city_size,
        user_bucket=user_city.astype(np.int32),
        n_items=J,
    )


def index_from_dataset(ds, **kw) -> CandidateIndex:
    """Convenience: index straight from a `synthetic_poi.POIDataset`."""
    return build_candidate_index(
        ds.item_city, ds.user_city, n_items=ds.n_items, **kw
    )
