# Serving subsystem: decentralized POI recommendation over trained DMFState.
#   candidates.py — city-bucketed candidate index (paper Fig. 2 pruning)
#                   + hierarchical geohash-cell index for million-user scale
#   engine.py     — microbatched ServingEngine (one jitted dispatch per batch)
#   store.py      — HBM-resident tiled factor store + quantized engine (1M users)
#   online.py     — Eq. 9-11 online factor refresh from streamed check-ins
from repro.serving.candidates import (
    CandidateIndex,
    HierarchicalIndex,
    build_candidate_index,
    build_hierarchical_index,
    index_from_dataset,
)
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.online import OnlineConfig, RefreshReport, online_refresh
from repro.serving.store import (
    SyntheticFactors,
    TiledFactorStore,
    TiledServingEngine,
    synthetic_world,
)

__all__ = [
    "CandidateIndex",
    "HierarchicalIndex",
    "build_candidate_index",
    "build_hierarchical_index",
    "index_from_dataset",
    "ServingConfig",
    "ServingEngine",
    "OnlineConfig",
    "RefreshReport",
    "online_refresh",
    "SyntheticFactors",
    "TiledFactorStore",
    "TiledServingEngine",
    "synthetic_world",
]
