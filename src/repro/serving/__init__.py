# Serving subsystem: decentralized POI recommendation over trained DMFState.
#   candidates.py — city-bucketed candidate index (paper Fig. 2 pruning)
#   engine.py     — microbatched ServingEngine (one jitted dispatch per batch)
#   online.py     — Eq. 9-11 online factor refresh from streamed check-ins
from repro.serving.candidates import (
    CandidateIndex,
    build_candidate_index,
    index_from_dataset,
)
from repro.serving.engine import ServingConfig, ServingEngine
from repro.serving.online import OnlineConfig, RefreshReport, online_refresh

__all__ = [
    "CandidateIndex",
    "build_candidate_index",
    "index_from_dataset",
    "ServingConfig",
    "ServingEngine",
    "OnlineConfig",
    "RefreshReport",
    "online_refresh",
]
