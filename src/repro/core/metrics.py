"""Evaluation metrics: P@k and R@k (paper §Experiments/Metrics).

    P@k = |S_i^T ∩ S_i^R| / k          R@k = |S_i^T ∩ S_i^R| / |S_i^T|

averaged over users with a non-empty test set; training items are excluded
from the recommendation candidate set (standard protocol).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def topk_recommend(scores: jnp.ndarray, train_mask: jnp.ndarray, k: int) -> jnp.ndarray:
    """Top-k item indices per user, excluding training items.

    scores: (I, J) float; train_mask: (I, J) bool (True = seen in training).
    """
    masked = jnp.where(train_mask, -jnp.inf, scores)
    _, idx = jax.lax.top_k(masked, k)
    return idx


def precision_recall_at_k(
    scores: np.ndarray,
    train_mask: np.ndarray,
    test_mask: np.ndarray,
    k: int,
) -> tuple[float, float]:
    """Mean P@k and R@k over users with >=1 test item."""
    rec = np.asarray(topk_recommend(jnp.asarray(scores), jnp.asarray(train_mask), k))
    return precision_recall_from_topk(rec, test_mask, k)


def topk_hits(rec: np.ndarray, test_mask: np.ndarray, k: int) -> np.ndarray:
    """(n,) int per-user hit counts in the first k recommendation slots —
    the chunkable integer core of P@k / R@k: hit counts from different user
    chunks concatenate into exactly the array a whole-matrix pass yields."""
    rec_k = np.asarray(rec[:, :k])
    filled = rec_k >= 0
    safe = np.where(filled, rec_k, 0)
    return (np.take_along_axis(test_mask, safe, axis=1) & filled).sum(axis=1)


def precision_recall_from_hits(
    hits: np.ndarray, n_test: np.ndarray, k: int
) -> tuple[float, float]:
    """Final P@k / R@k reduction over per-user hit counts and test-set
    sizes (the chunk-accumulated counterpart of
    `precision_recall_from_topk` — identical floats, by construction)."""
    valid = n_test > 0
    if not valid.any():
        return 0.0, 0.0
    p_at_k = float((hits[valid] / k).mean())
    r_at_k = float((hits[valid] / n_test[valid]).mean())
    return p_at_k, r_at_k


def precision_recall_from_topk(
    rec: np.ndarray,
    test_mask: np.ndarray,
    k: int,
) -> tuple[float, float]:
    """P@k / R@k from precomputed top-K indices (K >= k, descending score
    order, so the first k columns are the top-k). Slots that never filled
    (idx < 0, fewer than K candidates) count as misses."""
    assert rec.shape[1] >= k, (rec.shape, k)
    hits = topk_hits(rec, test_mask, k)
    n_test = test_mask.sum(axis=1)
    return precision_recall_from_hits(hits, n_test, k)


def evaluate_ranking_from_topk(rec, test_mask, ks=(5, 10)) -> dict[str, float]:
    """Like `evaluate_ranking` but from streaming top-k output — no (I, J)
    score matrix involved."""
    out = {}
    for k in ks:
        p, r = precision_recall_from_topk(rec, test_mask, k)
        out[f"P@{k}"] = p
        out[f"R@{k}"] = r
    return out


def evaluate_ranking(scores, train_mask, test_mask, ks=(5, 10)) -> dict[str, float]:
    out = {}
    for k in ks:
        p, r = precision_recall_at_k(scores, train_mask, test_mask, k)
        out[f"P@{k}"] = p
        out[f"R@{k}"] = r
    return out


def masks_from_interactions(n_users: int, n_items: int, pairs: np.ndarray) -> np.ndarray:
    """(I, J) bool mask from an (n, 2) array of (user, item) pairs."""
    m = np.zeros((n_users, n_items), dtype=bool)
    if len(pairs):
        m[pairs[:, 0], pairs[:, 1]] = True
    return m


def masks_from_interactions_rows(
    row_start: int, n_rows: int, n_items: int, pairs: np.ndarray
) -> np.ndarray:
    """Row window [row_start, row_start + n_rows) of the (I, J) interaction
    mask, without ever building the full matrix — the streaming-evaluate
    building block (rows equal the corresponding `masks_from_interactions`
    rows exactly). Pairs outside the window are ignored, so out-of-range
    windows (padded shard tails) yield all-False rows."""
    m = np.zeros((n_rows, n_items), dtype=bool)
    if len(pairs):
        sel = (pairs[:, 0] >= row_start) & (pairs[:, 0] < row_start + n_rows)
        p = pairs[sel]
        m[p[:, 0] - row_start, p[:, 1]] = True
    return m
