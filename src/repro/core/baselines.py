"""Centralized baselines the paper compares against.

* **MF** (Mnih & Salakhutdinov 2007): centralized least-square latent factor
  model — the same objective as Eq. 1, trained with SGD and the same
  unobserved-rating negative sampling as DMF (identical protocol, so the
  comparison isolates the decentralization).
* **BPR** (Rendle et al. 2009): pairwise-ranking latent factor model,
  trained on (user, positive, sampled-negative) triples with the sigmoid
  pairwise loss.
* **GDMF / LDMF** are the γ→∞ / β→∞ special cases of DMF and live in
  ``core.dmf`` (``mode="gdmf"|"ldmf"``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib


@dataclasses.dataclass(frozen=True)
class MFConfig:
    n_users: int
    n_items: int
    dim: int = 10
    alpha: float = 0.1      # user regularizer
    beta: float = 0.01      # item regularizer
    lr: float = 0.1
    neg_samples: int = 3
    batch_size: int = 256
    init_scale: float = 0.1
    seed: int = 0


@dataclasses.dataclass
class MFState:
    U: jnp.ndarray  # (I, K)
    V: jnp.ndarray  # (J, K)


def init_mf(cfg: MFConfig, rng: np.random.Generator | None = None) -> MFState:
    rng = rng or np.random.default_rng(cfg.seed)
    s = cfg.init_scale
    return MFState(
        U=jnp.asarray(rng.normal(0, s, (cfg.n_users, cfg.dim)), jnp.float32),
        V=jnp.asarray(rng.normal(0, s, (cfg.n_items, cfg.dim)), jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def _mf_step(U, V, ui, vj, r, conf, cfg: MFConfig):
    u, v = U[ui], V[vj]
    err = conf * (r - jnp.sum(u * v, -1))
    gu = -err[:, None] * v + cfg.alpha * u
    gv = -err[:, None] * u + cfg.beta * v
    loss = 0.5 * jnp.sum(conf * (r - jnp.sum(u * v, -1)) ** 2)
    return U.at[ui].add(-cfg.lr * gu), V.at[vj].add(-cfg.lr * gv), loss


def fit_mf(cfg: MFConfig, train: np.ndarray, epochs: int = 30, seed: int | None = None):
    from repro.core.dmf import DMFConfig, sample_epoch  # shared sampling protocol

    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    state = init_mf(cfg, rng)
    scfg = DMFConfig(
        n_users=cfg.n_users, n_items=cfg.n_items, dim=cfg.dim,
        neg_samples=cfg.neg_samples, batch_size=cfg.batch_size,
    )
    U, V = state.U, state.V
    losses = []
    B = cfg.batch_size
    for _ in range(epochs):
        ui, vj, r, conf = sample_epoch(train, scfg, rng)
        n = (len(ui) // B) * B
        tot = 0.0
        for s in range(0, n, B):
            U, V, l = _mf_step(
                U, V,
                jnp.asarray(ui[s:s+B]), jnp.asarray(vj[s:s+B]),
                jnp.asarray(r[s:s+B]), jnp.asarray(conf[s:s+B]), cfg,
            )
            tot += float(l)
        losses.append(tot / max(n, 1))
    return MFState(U, V), losses


@dataclasses.dataclass(frozen=True)
class BPRConfig:
    n_users: int
    n_items: int
    dim: int = 10
    reg: float = 0.01
    lr: float = 0.05
    batch_size: int = 256
    init_scale: float = 0.1
    seed: int = 0


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def _bpr_step(U, V, ui, vp, vn, cfg: BPRConfig):
    u, xp, xn = U[ui], V[vp], V[vn]
    diff = jnp.sum(u * (xp - xn), -1)
    sig = jax.nn.sigmoid(-diff)             # d(-log σ(diff))/d(diff) = -σ(-diff)
    loss = jnp.sum(jax.nn.softplus(-diff))
    gu = -sig[:, None] * (xp - xn) + cfg.reg * u
    gp = -sig[:, None] * u + cfg.reg * xp
    gn = sig[:, None] * u + cfg.reg * xn
    U = U.at[ui].add(-cfg.lr * gu)
    V = V.at[vp].add(-cfg.lr * gp)
    V = V.at[vn].add(-cfg.lr * gn)
    return U, V, loss


def fit_bpr(cfg: BPRConfig, train: np.ndarray, epochs: int = 30, seed: int | None = None):
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    s = cfg.init_scale
    U = jnp.asarray(rng.normal(0, s, (cfg.n_users, cfg.dim)), jnp.float32)
    V = jnp.asarray(rng.normal(0, s, (cfg.n_items, cfg.dim)), jnp.float32)
    B = cfg.batch_size
    losses = []
    for _ in range(epochs):
        perm = rng.permutation(len(train))
        pos = train[perm]
        neg = rng.integers(0, cfg.n_items, size=len(pos))
        n = (len(pos) // B) * B
        tot = 0.0
        for st in range(0, n, B):
            U, V, l = _bpr_step(
                U, V,
                jnp.asarray(pos[st:st+B, 0]), jnp.asarray(pos[st:st+B, 1]),
                jnp.asarray(neg[st:st+B]), cfg,
            )
            tot += float(l)
        losses.append(tot / max(n, 1))
    return MFState(U, V), losses


def mf_scores(state: MFState) -> np.ndarray:
    return np.asarray(state.U @ state.V.T)


def evaluate_mf(state: MFState, train, test, n_users, n_items, ks=(5, 10)):
    sc = mf_scores(state)
    train_mask = metrics_lib.masks_from_interactions(n_users, n_items, train)
    test_mask = metrics_lib.masks_from_interactions(n_users, n_items, test)
    return metrics_lib.evaluate_ranking(sc, train_mask, test_mask, ks)
