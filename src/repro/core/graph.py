"""User adjacency graph + random-walk propagation (paper Eqs. 2-4).

The paper builds a user adjacency graph from geography:

    w_{ii'} = I^{ii'} * f(d_{ii'})                         (Eq. 2)

with I^{ii'} the same-city indicator and f a distance-decay map. Each user
keeps at most N direct neighbors. Communication is propagated up to D hops
with random-walk weights

    P(n_i = k)  = w_{ik} / sum_{i'} w_{ii'}                (Eq. 3)
    P(n_i = k') ∝ sum_k w_{ik} w_{kk'}                     (Eq. 4)

i.e. the d-hop weights are the d-th power of the row-normalized adjacency.

Alg. 1 line 15 updates a neighbor i' of i with step  θ·|N^d(i)|·W_{ii'}·g.
Taken literally the |N^d(i)| factor *amplifies* with neighborhood size and
diverges for D ≥ 2 on dense graphs; Eq. 3/4 already define a probability, so
we default to the row-normalized walk weight (Ŵ^d)_{ii'} with optional per-hop
damping c^d, and keep the literal form behind ``paper_literal=True``
(documented deviation — see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    n_neighbors: int = 2        # N — max direct neighbors per user (paper: N=2)
    walk_length: int = 3        # D — max random-walk distance (paper sweeps 1..4)
    hop_damping: float = 1.0    # c — per-hop damping c^d on Ŵ^d
    uniform_weights: bool = True  # paper experiments "simply set w_{ii'}=1"
    paper_literal: bool = False   # keep Alg.1's |N^d(i)| amplification factor
    same_city_only: bool = True   # I^{ii'} indicator from Eq. 2


def pairwise_dist(coords: np.ndarray) -> np.ndarray:
    d2 = np.sum((coords[:, None, :] - coords[None, :, :]) ** 2, axis=-1)
    return np.sqrt(np.maximum(d2, 0.0))


def build_adjacency(
    coords: np.ndarray,       # (I, 2) user coordinates
    cities: np.ndarray,       # (I,) int city id per user
    cfg: GraphConfig,
) -> np.ndarray:
    """Dense (I, I) adjacency W per Eq. 2, truncated to top-N nearest neighbors.

    w in [0,1]; w=0 means no relationship. Diagonal is 0 (self handled
    separately by Alg. 1 line 11). Symmetrized by max(W, W^T) so the
    graph is undirected (if i picked i' as a nearest neighbor, they can
    communicate both ways).
    """
    I = coords.shape[0]
    dist = pairwise_dist(coords)
    same_city = cities[:, None] == cities[None, :]
    np.fill_diagonal(same_city, False)
    # distance -> relationship degree: monotone decreasing into (0, 1]
    if cfg.uniform_weights:
        w_full = same_city.astype(np.float64)
    else:
        w_full = same_city / (1.0 + dist)
    if not cfg.same_city_only:
        # cross-city fallback (not used by the paper; kept for ablations)
        w_cross = (~same_city) / (1.0 + dist)
        np.fill_diagonal(w_cross, 0.0)
        w_full = w_full + 1e-3 * w_cross
    # top-N truncation by distance among same-city users (cheaper to maintain)
    order = np.argsort(np.where(w_full > 0, dist, np.inf), axis=1)
    W = np.zeros((I, I), dtype=np.float32)
    rows = np.arange(I)[:, None]
    top = order[:, : cfg.n_neighbors]
    keep = np.take_along_axis(w_full, top, axis=1) > 0
    W[rows.repeat(cfg.n_neighbors, 1)[keep], top[keep]] = np.take_along_axis(
        w_full, top, axis=1
    )[keep].astype(np.float32)
    W = np.maximum(W, W.T)
    return W


def row_normalize(W: np.ndarray) -> np.ndarray:
    """Random-walk transition matrix Ŵ (Eq. 3). Isolated rows stay zero."""
    deg = W.sum(axis=1, keepdims=True)
    return np.where(deg > 0, W / np.maximum(deg, 1e-12), 0.0).astype(np.float32)


def walk_propagation_matrix(W: np.ndarray, cfg: GraphConfig) -> np.ndarray:
    """M (I, I): per-event propagation weights of the global-factor gradient.

    M[i, i'] is the coefficient applied by user i' to the gradient user i
    sends (Alg. 1 lines 13-15), including the sender's own full update
    (line 11) as M[i, i] = 1:

        M = I + sum_{d=1..D} c^d * Ŵ^d            (default, normalized)
        M = I + sum_{d=1..D} |N^d(i)| * W^d       (paper_literal)
    """
    I = W.shape[0]
    M = np.eye(I, dtype=np.float64)
    if cfg.paper_literal:
        Wd = np.eye(I)
        for d in range(1, cfg.walk_length + 1):
            Wd = Wd @ W
            nd = (Wd > 0).sum(axis=1, keepdims=True).astype(np.float64)  # |N^d(i)|
            M += nd * Wd
    else:
        What = row_normalize(W).astype(np.float64)
        Wd = np.eye(I)
        for d in range(1, cfg.walk_length + 1):
            Wd = Wd @ What
            M += (cfg.hop_damping ** d) * Wd
    return M.astype(np.float32)


class NeighborTable(NamedTuple):
    """Compact multi-hop neighborhood of the propagation matrix M.

    ``idx[i, s]`` lists the receivers of user i's gradient message (self
    first is not guaranteed — order follows column index) and ``wgt[i, s]``
    the walk weight M[i, idx[i, s]]. Rows are padded to the max realized
    ``1 + |N^D(i)|`` with the sender's own index at weight 0, so a padded
    slot scatter-adds exactly zero (a no-op) — see DESIGN.md §5.
    """

    idx: jnp.ndarray   # (I, S) int32
    wgt: jnp.ndarray   # (I, S) float32


def neighbor_table_from_dense(M: np.ndarray) -> NeighborTable:
    """Extract the (idx, wgt) neighbor table from a dense propagation matrix.

    M's zero pattern is exact (walk powers of a nonnegative sparse adjacency
    never produce spurious nonzeros), so nnz(row i) == 1 + |N^D(i)|.
    """
    M = np.asarray(M)
    I = M.shape[0]
    nz = M != 0.0
    S = max(int(nz.sum(axis=1).max()) if I else 0, 1)
    # stable argsort puts nonzero columns first, in ascending column order
    order = np.argsort(~nz, axis=1, kind="stable")[:, :S]
    taken = np.take_along_axis(nz, order, axis=1)
    self_idx = np.arange(I, dtype=np.int64)[:, None]
    idx = np.where(taken, order, self_idx)
    wgt = np.where(taken, np.take_along_axis(M, order, axis=1), 0.0)
    return NeighborTable(
        idx=jnp.asarray(idx, jnp.int32), wgt=jnp.asarray(wgt, jnp.float32)
    )


def walk_neighbor_table(W: np.ndarray, cfg: GraphConfig) -> NeighborTable:
    """Sparse export of ``walk_propagation_matrix``: per-sender receiver
    indices and weights, shape (I, S) with S = max realized 1 + |N^D(i)|.

    This is the structure the decentralized protocol actually ships — each
    learner knows only its D-hop neighborhood — and the asymptotic enabler
    for the sparse training path: per-rating propagation work is O(S·K),
    not O(I·K)."""
    return neighbor_table_from_dense(walk_propagation_matrix(W, cfg))


class PartitionedNeighborTable(NamedTuple):
    """`NeighborTable` split for a row-sharded learner mesh (DESIGN.md §8).

    Users are partitioned contiguously into ``n_shards`` shards of
    ``rows_per_shard`` rows each (the user axis padded up to
    ``n_shards * rows_per_shard``). Each sender row of the neighbor table is
    split by *destination shard*: slot (i, d, s) carries the weight and the
    **shard-local** row of receiver ``nbr.idx[i, s]`` iff that receiver
    lives on shard d, else (0, 0.0) — a weight-0 slot scatter-adds exactly
    zero, the same no-op convention as `NeighborTable` padding. This is the
    fixed-shape per-shard "outbox" schema: what shard s ships to shard d for
    sender i is precisely the (i, d, :) slice weighted by i's batch
    gradient, so the exchange is one `all_to_all` of static shape per step.
    """

    idx: jnp.ndarray   # (I_pad, n_shards, S) int32 — receiver rows, shard-local
    wgt: jnp.ndarray   # (I_pad, n_shards, S) float32
    rows_per_shard: int
    n_users: int       # real (unpadded) user count


def partition_neighbor_table(
    nbr: NeighborTable, n_shards: int, n_users: int | None = None
) -> PartitionedNeighborTable:
    """Split each user's (S,) receiver row by the receiver's home shard.

    Receivers keep their walk weight but are re-indexed to shard-local rows
    (``r % rows_per_shard``); slots whose receiver lives elsewhere become
    (idx 0, weight 0.0) no-ops. Row-sum over destinations reconstructs the
    original table exactly (unit-tested), so sharded propagation applies
    precisely the same scatter mass as the single-device path.
    """
    idx = np.asarray(nbr.idx)
    wgt = np.asarray(nbr.wgt)
    I, S = idx.shape
    if n_users is None:
        n_users = I
    rows = -(-I // n_shards)
    I_pad = rows * n_shards
    dest = idx // rows                       # (I, S) receiver home shard
    local = idx % rows                       # (I, S) shard-local receiver row
    live = wgt != 0.0
    pidx = np.zeros((I_pad, n_shards, S), np.int32)
    pwgt = np.zeros((I_pad, n_shards, S), np.float32)
    for d in range(n_shards):
        keep = live & (dest == d)
        pidx[:I, d] = np.where(keep, local, 0)
        pwgt[:I, d] = np.where(keep, wgt, 0.0)
    return PartitionedNeighborTable(
        idx=jnp.asarray(pidx), wgt=jnp.asarray(pwgt),
        rows_per_shard=rows, n_users=n_users,
    )


def dense_from_neighbor_table(nbr: NeighborTable, n_users: int) -> np.ndarray:
    """Reconstruct the dense (I, I) M — test/debug helper (inverse of
    ``neighbor_table_from_dense`` up to padded zero-weight slots)."""
    M = np.zeros((n_users, n_users), dtype=np.float32)
    idx = np.asarray(nbr.idx)
    wgt = np.asarray(nbr.wgt)
    rows = np.repeat(np.arange(n_users), idx.shape[1])
    np.add.at(M, (rows, idx.reshape(-1)), wgt.reshape(-1))
    return M


def neighbor_counts(W: np.ndarray, max_d: int) -> np.ndarray:
    """|N^d(i)| for d=1..max_d — used by the complexity benchmark."""
    I = W.shape[0]
    A = (W > 0).astype(np.float64)
    reached = np.eye(I, dtype=bool)
    counts = np.zeros((max_d, I), dtype=np.int64)
    Ad = np.eye(I)
    for d in range(max_d):
        Ad = Ad @ A
        new = (Ad > 0) & ~reached
        counts[d] = new.sum(axis=1)
        reached |= new
    return counts


def communication_bytes(W: np.ndarray, D: int, K: int, n_ratings: int) -> int:
    """Paper §Complexity: |O| * min(|C^i|, N^D(i)) * 4K bytes per epoch.

    We use the realized mean multi-hop neighborhood size over users (the
    per-event fan-out of the gradient message) times 4K bytes.
    """
    counts = neighbor_counts(W, D).sum(axis=0)  # |N^D(i)| per user
    mean_fanout = float(counts.mean())
    return int(round(n_ratings * mean_fanout * 4 * K))
