"""Decentralized Matrix Factorization — the paper's Algorithm 1 in JAX.

Model (paper Eqs. 5-8): each user i ("learner") privately holds
  * u_i            — user latent factor                  (K,)
  * p^i = P[i]     — his copy of the *common* item factors (J, K)
  * q^i = Q[i]     — his *personal* item factors           (J, K)
with the effective item factor v^i_j = p^i_j + q^i_j.

Objective (Eq. 6) with least-square loss (Eq. 7) and gradients (Eqs. 9-11):
  ∂L/∂u_i  = -(r - u·v) v + α u
  ∂L/∂p^i_j = -(r - u·v) u + β p^i_j
  ∂L/∂q^i_j = -(r - u·v) u + γ q^i_j

Per Alg. 1, when user i rates item j he updates (u_i, p^i_j, q^i_j) with SGD
and *sends the gradient of the global factor* ∂L/∂p^i_j to his d≤D-hop
neighbors, who apply it with random-walk weights — only gradients ever leave
a learner (the privacy mechanism). We vectorize this exactly: the
propagation matrix M (core/graph.py) carries M[i,i'] per (sender, receiver),
with M[i,i]=1 for the sender's own line-11 update, so one scatter

    P[:, j] -= θ · M[i, :]^T ⊗ ∂L/∂p^i_j

reproduces lines 11+15 for every receiver at once. The simulation is
faithful to the paper's own evaluation ("we mock decentralized learning").

Decentralized-semantics note: SGD is applied per *minibatch* (order-free sum
of per-rating contributions) rather than per single rating — required for
SPMD, standard minibatching of Alg. 1; the paper's per-rating updates are
recovered with batch_size=1.

Negative sampling (paper §Unobserved rating sample): for each observed
r_ij ∈ O we draw m unobserved (i, j') as r=0 with confidence 1/m; the
confidence scales the error term of the loss.

Modes (paper's ablations):
  * ``dmf``  — full model;
  * ``gdmf`` — γ→∞ limit: q^i ≡ 0, only the shared factor is learnt;
  * ``ldmf`` — β→∞ limit: p^i ≡ 0 and no exchange, purely local learning.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as graph_lib
from repro.core import metrics as metrics_lib


@dataclasses.dataclass(frozen=True)
class DMFConfig:
    n_users: int
    n_items: int
    dim: int = 10                    # K
    alpha: float = 0.1               # user regularizer (paper: 0.1)
    beta: float = 0.01               # global item regularizer
    gamma: float = 0.01              # personal item regularizer
    lr: float = 0.1                  # θ (paper: 0.1)
    neg_samples: int = 3             # m (paper: 3)
    batch_size: int = 256
    mode: str = "dmf"                # dmf | gdmf | ldmf
    init_scale: float = 0.1
    seed: int = 0
    use_pallas: bool = False         # fused Pallas step kernel (ops.dmf_fused_step)
    pallas_interpret: bool = True    # interpret=True on CPU; False on real TPU
    n_shards: int = 1                # learner-mesh width; >1 = SPMD epochs over
                                     # a row-sharded U/P/Q (sharding/dmf.py)
    dp_clip: float = float("inf")    # C — L2 bound per outgoing gradient message
    dp_sigma: float = 0.0            # σ — noise multiplier relative to C
    dp_seed: int = 0                 # DP mechanism base seed (privacy/mechanism.py)

    def __post_init__(self):
        assert self.mode in ("dmf", "gdmf", "ldmf"), self.mode
        assert self.n_shards >= 1, self.n_shards
        assert self.dp_sigma >= 0.0 and self.dp_clip > 0.0, (
            self.dp_sigma, self.dp_clip)
        import math
        assert self.dp_sigma == 0.0 or math.isfinite(self.dp_clip), (
            "dp_sigma > 0 needs a finite dp_clip: the noise std is σ·C")

    @property
    def dp(self) -> bool:
        """True iff outgoing gradient messages are clipped/noised
        (privacy/mechanism.py). False (the default σ=0, C=∞) compiles the
        exact un-noised program — bit-exact with the DP-less paths. Also
        False for ``ldmf``: purely-local learning exchanges nothing, so
        there is no mechanism to run, no rng seed draw, and no accountant
        — dp params are inert rather than producing an ε claim about
        releases that never happen."""
        if self.mode == "ldmf":
            return False
        from repro.privacy import mechanism
        return mechanism.dp_enabled(self)


@dataclasses.dataclass
class DMFState:
    U: jnp.ndarray   # (I, K)
    P: jnp.ndarray   # (I, J, K) per-learner copies of the common factor
    Q: jnp.ndarray   # (I, J, K) personal factors


# Registered as a pytree so the state checkpoints/restores as three leaves
# (checkpoint/ckpt.py flattens by key path) instead of one opaque object.
jax.tree_util.register_dataclass(
    DMFState, data_fields=["U", "P", "Q"], meta_fields=[])


def init_state(cfg: DMFConfig, rng: np.random.Generator | None = None) -> DMFState:
    """U random; P and Q zero.

    Zero item-factor init is the consensus-friendly choice for the
    decentralized setting: an item never touched by user i's D-hop
    neighborhood keeps score exactly u_i·0 = 0, i.e. neutral — with random
    init those items would carry O(|u||p0|) noise that pollutes top-k for
    every user (observed: random init halves P@5). U random breaks the
    u=v=0 saddle (p's first gradient is -e·u ≠ 0).
    """
    rng = rng or np.random.default_rng(cfg.seed)
    I, J, K = cfg.n_users, cfg.n_items, cfg.dim
    U = jnp.asarray(rng.normal(0, cfg.init_scale, (I, K)), dtype=jnp.float32)
    P = jnp.zeros((I, J, K), jnp.float32)
    Q = jnp.zeros((I, J, K), jnp.float32)
    return DMFState(U=U, P=P, Q=Q)


# ---------------------------------------------------------------------------
# One minibatch step of Algorithm 1 (lines 6-16), vectorized.
#
# Two implementations:
#   * `_batch_step` — dense reference (seed): propagates every gradient
#     through the full (I, I) walk matrix, O(I·B·K) per batch. Kept as the
#     equivalence oracle and for `fit(..., dense_reference=True)`.
#   * `_sparse_batch_update` — production path: gathers each sender's
#     compact neighbor row from a `graph.NeighborTable` and scatter-adds
#     into P, O(B·S·K) per batch (S = max 1+|N^D|; see DESIGN.md §5).
# ---------------------------------------------------------------------------
def _grads_and_loss(u, p, q, r, conf, cfg: DMFConfig):
    """Eqs. 9-11 gradients and batch loss for gathered (B, K) factors —
    the single definition shared by the dense and sparse step paths (the
    equivalence tests compare the two, so they must share this math)."""
    v = p + q
    err = conf * (r - jnp.sum(u * v, axis=-1))  # confidence-weighted residual
    gu = -err[:, None] * v + cfg.alpha * u
    gp = -err[:, None] * u + cfg.beta * p
    gq = -err[:, None] * u + cfg.gamma * q
    loss = 0.5 * jnp.sum(conf * (r - jnp.sum(u * v, -1)) ** 2)
    return gu, gp, gq, loss


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1, 2))
def _batch_step(
    U: jnp.ndarray,
    P: jnp.ndarray,
    Q: jnp.ndarray,
    M: jnp.ndarray,            # (I, I) propagation matrix (incl. self)
    ui: jnp.ndarray,           # (B,) user indices
    vj: jnp.ndarray,           # (B,) item indices
    r: jnp.ndarray,            # (B,) ratings in [0,1]
    conf: jnp.ndarray,         # (B,) confidence weights (1 for pos, 1/m neg)
    cfg: DMFConfig,
):
    theta = cfg.lr
    u = U[ui]                                  # (B, K)
    p = P[ui, vj]                              # (B, K)
    q = Q[ui, vj]                              # (B, K)
    gu, gp, gq, loss = _grads_and_loss(u, p, q, r, conf, cfg)

    U = U.at[ui].add(-theta * gu)
    if cfg.mode != "gdmf":
        Q = Q.at[ui, vj].add(-theta * gq)
    if cfg.mode != "ldmf":
        # lines 11 + 13-15: sender's own update plus the random-walk
        # propagated gradient-exchange to all d<=D-hop neighbors.
        A = M[ui]                              # (B, I) receiver weights
        upd = A.T[:, :, None] * gp[None, :, :]  # (I, B, K)
        P = P.at[:, vj].add(-theta * upd)
    return U, P, Q, loss


def _step_deltas(U, P, Q, ui, vj, r, conf, cfg: DMFConfig, valid=None):
    """Gather + Eqs. 9-11 for one minibatch: returns the lr-scaled U/Q
    deltas ``(du, dq)``, the raw global-factor gradient message ``gp``
    (scaled by -θ and the walk weight at scatter time), and the batch loss.

    The SINGLE definition of the per-row step math, shared by every fast
    path — the sparse scan, the online refresh, and the learner-sharded
    SPMD epoch (sharding/dmf.py) — so they cannot silently diverge from
    each other or from the fused Pallas kernel behind ``cfg.use_pallas``.

    ``valid`` (optional (B,) bool/float) marks real rows in a padded batch.
    Invalid rows contribute exactly nothing: conf=0 already zeroes their
    error term, but the α/β/γ regularizer pulls survive in the gradients,
    so all three deltas are masked here, before any scatter."""
    theta = cfg.lr
    if cfg.use_pallas:
        from repro.kernels import ops
        du, gp, dq, loss = ops.dmf_fused_step(
            U[ui], P[ui, vj], Q[ui, vj], r, conf,
            theta=theta, alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.gamma,
            interpret=cfg.pallas_interpret,
        )
    else:
        gu, gp, gq, loss = _grads_and_loss(U[ui], P[ui, vj], Q[ui, vj], r, conf, cfg)
        du = -theta * gu
        dq = -theta * gq
    if valid is not None:
        keep = valid.astype(du.dtype)[:, None]
        du = du * keep
        dq = dq * keep
        gp = gp * keep
    return du, gp, dq, loss


def _dp_noise_rows(rid, dp_seed, cfg: DMFConfig, k: int):
    """On-demand noise for a row set: the (len(rid), k) pre-scaled σC
    Gaussian block from the counter stream keyed by the rows' global
    stream ids — what the online refresh and the audit capture use per
    batch. The epoch scan instead generates the WHOLE epoch's block in one
    vectorized pass (see `_epoch_scan`) — same stream, same values, 70x
    fewer transcendental dispatches. Returns None when σ=0 (clip-only)."""
    from repro.kernels.dp_noise import gauss_counter
    from repro.privacy import mechanism
    std = mechanism.noise_std(cfg)
    if std == 0.0:
        return None
    return std * gauss_counter(
        dp_seed, jnp.asarray(rid, jnp.int32).reshape(-1, 1), k)


def _dp_message(gp, noise, cfg: DMFConfig, valid=None):
    """The DP mechanism's clip+noise over the outgoing message block — THE
    single place a P-gradient becomes an exchanged message on the jnp
    paths (the fused Pallas step applies the identical math in-kernel, and
    the sharded step runs this pre-`all_to_all`). ``noise`` is the rows'
    pre-scaled σC block (None = clip only); padded rows are re-masked
    because noise lands on their zero gradients too."""
    nrm = jnp.sqrt(jnp.sum(gp * gp, axis=-1, keepdims=True))
    gp = gp * jnp.minimum(1.0, cfg.dp_clip / nrm)   # inf/0 -> 1 (no-op)
    if noise is not None:
        gp = gp + noise
    if valid is not None:
        gp = gp * valid.astype(gp.dtype)[:, None]
    return gp


def _step_deltas_dp(U, P, Q, ui, vj, r, conf, cfg: DMFConfig, valid, noise):
    """`_step_deltas` with the DP mechanism on the outgoing gp message.

    On the Pallas path the clip + noise-add folds into the SAME fused step
    kernel (`ops.dmf_fused_step_dp`) — the DP epoch keeps the un-noised
    epoch's one-kernel-per-minibatch dispatch count. The jnp path applies
    `_dp_message` as a follow-on op (XLA fuses it into the step anyway)."""
    if cfg.use_pallas:
        from repro.kernels import ops
        z = noise if noise is not None else jnp.zeros_like(U[ui])
        du, gp, dq, loss = ops.dmf_fused_step_dp(
            U[ui], P[ui, vj], Q[ui, vj], r, conf, z,
            theta=cfg.lr, alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.gamma,
            clip=cfg.dp_clip, interpret=cfg.pallas_interpret)
        if valid is not None:
            keep = valid.astype(du.dtype)[:, None]
            du, gp, dq = du * keep, gp * keep, dq * keep
        return du, gp, dq, loss
    du, gp, dq, loss = _step_deltas(U, P, Q, ui, vj, r, conf, cfg, valid)
    return du, _dp_message(gp, noise, cfg, valid), dq, loss


def _sparse_batch_update_messages(U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf,
                                  cfg: DMFConfig, valid=None, rid=None,
                                  dp_seed=None, noise=None, recv_gate=None,
                                  prop_now=None, byz=None, amul=None,
                                  ashill=None, dirs=None, vjm=None, bkt=None,
                                  byz_cap=0, tele=False):
    """One minibatch of Alg. 1 against the sparse neighbor table.

    Identical math to `_batch_step`; only the line 13-15 propagation differs:
    instead of weighting gp by a full (I,) column of M, each sender's (S,)
    receiver row is gathered and scatter-added — padded self-index slots
    carry weight 0 and are exact no-ops.

    With DP on (``cfg.dp``), the propagated message is clipped+noised
    before the scatter — every receiver, the sender's own line-11 P update
    included, applies only the noised message. Returns the per-row sent
    messages too (the observed outbox stream the audit harness attacks);
    `_sparse_batch_update` drops them for the training callers.

    Fault gates (robustness/faults.py; both None on the fault-free paths):
    ``recv_gate`` (I,) zeroes scatter weights into offline receivers —
    messages to an absent learner are lost, its P rows bit-frozen.
    ``prop_now`` (B,) restricts a straggler row's scatter to the sender's
    own line-11 self slot: its neighbor deliveries come from the delay
    ring k epochs later (`_epoch_scan_churn`). All-ones gates multiply
    weights by 1.0 — bit-exact with the ungated path.

    Byzantine path (robustness/byzantine.py; ``byz`` a `DefenseConfig`,
    static): with ``byz is None`` (the default) NONE of the code below the
    `byz is not None` branch is traced — the compiled program is the
    pre-existing one. Otherwise the exchange is restructured: the sender's
    own line-11 self update stays honest (an attacker poisons its *peers*,
    not its own copy — and the global loss metric must stay comparable),
    outgoing messages are corrupted per the attack arrays (``amul``/
    ``ashill``/``dirs``/``vjm``), screened at the receiver boundary
    (finite + norm-cap, content zeroed — 0·NaN is NaN), and combined per
    (receiver, item) bucket by trimmed-mean/median instead of plain
    summation when ``byz.aggregation != "sum"`` (``bkt`` the host-compiled
    `MessageGroups` arrays). Returns the SENT (post-corruption) messages —
    the delay ring must buffer what was actually released.

    Telemetry (``tele``, static; obs/telemetry.py): when True a sixth
    return value carries the ``TELE_W`` read-only reduction vector over
    intermediates this step already computes — squared update norms,
    released-message mass, scattered-propagation mass, delivery counts,
    screening accept/reject. No rng draw, no factor write, so factor
    trajectories are bit-identical with ``tele=False`` — and False (the
    default) traces none of it: the compiled program is unchanged.
    """
    theta = cfg.lr
    if cfg.dp and cfg.mode != "ldmf":
        if noise is None:
            noise = _dp_noise_rows(rid, dp_seed, cfg, U.shape[-1])
        du, gp, dq, loss = _step_deltas_dp(
            U, P, Q, ui, vj, r, conf, cfg, valid, noise)
    else:
        du, gp, dq, loss = _step_deltas(U, P, Q, ui, vj, r, conf, cfg, valid)
    U = U.at[ui].add(du)
    if cfg.mode != "gdmf":
        Q = Q.at[ui, vj].add(dq)
    if tele:
        z = jnp.zeros((), du.dtype)
        u_sq = jnp.sum(du * du)
        q_sq = jnp.sum(dq * dq) if cfg.mode != "gdmf" else z
    if cfg.mode == "ldmf":
        if tele:   # purely local: nothing released, nothing scattered
            return U, P, Q, loss, gp, jnp.stack(
                [u_sq, q_sq, z, z, z, z, z])
        return U, P, Q, loss, gp
    if byz is None:
        # lines 11 + 13-15 via the neighbor table: sender b's gradient gp[b]
        # lands on its S receivers at item vj[b], weighted by the walk weight.
        nb = nbr_idx[ui]                           # (B, S) receiver users
        wb = nbr_wgt[ui]                           # (B, S) walk weights
        if prop_now is not None:
            # straggler rows (prop_now=0): keep only the self slot now
            selfm = (nb == ui[:, None]).astype(wb.dtype)
            wb = wb * jnp.maximum(prop_now[:, None], selfm)
        if recv_gate is not None:
            wb = wb * recv_gate[nb]                # offline receivers get 0
        upd = wb[:, :, None] * gp[:, None, :]      # (B, S, K)
        P = P.at[nb, vj[:, None]].add(-theta * upd)
        if tele:
            gp2 = jnp.sum(gp * gp, axis=-1)              # (B,)
            selfm_t = (nb == ui[:, None]).astype(wb.dtype)
            scatter_sq = theta * theta * jnp.sum(
                gp2 * jnp.sum(wb * wb, axis=1))
            n_msgs = jnp.sum((wb * (1.0 - selfm_t) > 0).astype(wb.dtype))
            return U, P, Q, loss, gp, jnp.stack(
                [u_sq, q_sq, jnp.sum(gp2), scatter_sq, n_msgs, z, z])
        return U, P, Q, loss, gp
    from repro.robustness import byzantine as byz_lib
    nb = nbr_idx[ui]                               # (B, S) receiver users
    wb = nbr_wgt[ui]                               # (B, S) walk weights
    selfm = (nb == ui[:, None]).astype(wb.dtype)
    # honest line-11 self update (padded tables may carry the self slot
    # more than once at weight 0 — summing the masked weights is exact)
    w_self = jnp.sum(wb * selfm, axis=1)
    if recv_gate is not None:
        w_self = w_self * recv_gate[ui]
    P = P.at[ui, vj].add(-theta * w_self[:, None] * gp)
    # sender boundary: corrupt the outgoing copy only
    gp_sent = gp
    if amul is not None:
        gp_sent = byz_lib.corrupt_messages(gp, amul, ashill, dirs[ui])
    vj_out = vjm if vjm is not None else vj
    wmsg = wb * (1.0 - selfm)
    if prop_now is not None:
        wmsg = wmsg * prop_now[:, None]
    if recv_gate is not None:
        wmsg = wmsg * recv_gate[nb]
    wmsg_pre = wmsg   # pre-screen delivery weights (telemetry baseline)
    gp_eff = gp_sent
    if byz.screen:
        ok = byz_lib.screen_ok(gp_sent, byz.norm_cap)   # (B,)
        gp_eff = jnp.where(ok[:, None] > 0, gp_sent, 0.0)
        wmsg = wmsg * ok[:, None]
    # 0·NaN = NaN: a zero-weight slot (straggler / offline receiver / padded)
    # whose sender bombed must deliver exactly 0, so the weight gates via
    # `where`, not multiplication. With screening on, gp_eff is already
    # zeroed wherever it was non-finite, so the plain multiply is safe —
    # and ±0 contributions leave the scatter-add bitwise unchanged.
    if byz.screen:
        upd = wmsg[:, :, None] * gp_eff[:, None, :]
    else:
        upd = jnp.where((wmsg > 0)[:, :, None],
                        wmsg[:, :, None] * gp_eff[:, None, :], 0.0)
    if byz.aggregation == "sum":
        P = P.at[nb, vj_out[:, None]].add(-theta * upd)
        scat = upd
    else:
        b_id, b_pos, b_recv, b_item = bkt
        K = gp.shape[-1]
        vals = upd.reshape(-1, K)
        validity = (wmsg > 0).astype(gp.dtype).reshape(-1)
        comb = byz_lib.robust_combine(
            vals, validity, b_id.reshape(-1), b_pos.reshape(-1),
            b_recv.shape[-1], byz_cap, byz)
        P = P.at[b_recv, b_item].add(-theta * comb)
        scat = comb
    if tele:
        n_pre = jnp.sum((wmsg_pre > 0).astype(wb.dtype))   # attempted
        n_post = jnp.sum((wmsg > 0).astype(wb.dtype))      # survived screen
        self_sq = jnp.sum((w_self[:, None] * gp) ** 2)
        scatter_sq = theta * theta * (self_sq + jnp.sum(scat * scat))
        return U, P, Q, loss, gp_sent, jnp.stack(
            [u_sq, q_sq, jnp.sum(gp_sent * gp_sent), scatter_sq,
             n_pre, n_post, n_pre - n_post])
    return U, P, Q, loss, gp_sent


def _sparse_batch_update(U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf, cfg: DMFConfig,
                         valid=None, rid=None, dp_seed=None, noise=None,
                         tele=False):
    out = _sparse_batch_update_messages(
        U, P, Q, nbr_idx, nbr_wgt, ui, vj, r, conf, cfg, valid, rid, dp_seed,
        noise, tele=tele)
    if tele:
        U, P, Q, loss, _, tvec = out
        return U, P, Q, loss, tvec
    U, P, Q, loss, _ = out
    return U, P, Q, loss


@functools.partial(jax.jit, static_argnames=("cfg", "tele"),
                   donate_argnums=(0, 1, 2))
def _epoch_scan(
    U: jnp.ndarray,
    P: jnp.ndarray,
    Q: jnp.ndarray,
    nbr_idx: jnp.ndarray,      # (I, S)
    nbr_wgt: jnp.ndarray,      # (I, S)
    ui: jnp.ndarray,           # (n_batches, B)
    vj: jnp.ndarray,
    r: jnp.ndarray,
    conf: jnp.ndarray,
    dp_seed: jnp.ndarray,      # () int32 per-epoch mechanism seed (traced)
    cfg: DMFConfig,
    tele: bool = False,        # static: emit the summed TELE_W reductions
):
    """A full epoch as one device-resident `lax.scan` over minibatches —
    one dispatch per epoch instead of a Python loop with a host sync
    (`float(loss)`) per batch. Returns stacked per-batch losses.

    DP (``cfg.dp``): the epoch's ENTIRE noise block is drawn here in one
    vectorized pass over the counter stream — row b·B+k of the stream gets
    `gauss_counter(dp_seed, b·B+k, :)` — and streamed into the scan per
    batch, where the step applies clip + add fused. Per-batch in-step
    generation would pay the log/cos dispatch cost n_batches times for the
    same bits (measured ~50% epoch overhead on CPU vs ~1 noise-gen ms
    amortized). With DP off (the default) `dp_seed` is a dead input XLA
    prunes and the compiled epoch is the exact PR 1 program."""
    nb, B = ui.shape
    from repro.privacy import mechanism
    noise_on = cfg.dp and cfg.mode != "ldmf" and mechanism.noise_std(cfg) > 0
    if noise_on:
        from repro.kernels.dp_noise import gauss_counter
        K = U.shape[-1]
        rid = jnp.arange(nb * B, dtype=jnp.int32).reshape(-1, 1)
        Z = (mechanism.noise_std(cfg)
             * gauss_counter(dp_seed, rid, K)).reshape(nb, B, K)
        xs = (ui, vj, r, conf, Z)
    else:
        xs = (ui, vj, r, conf)

    def body(carry, batch):
        U, P, Q = carry
        b_ui, b_vj, b_r, b_conf = batch[:4]
        out = _sparse_batch_update(
            U, P, Q, nbr_idx, nbr_wgt, b_ui, b_vj, b_r, b_conf, cfg,
            noise=batch[4] if noise_on else None, tele=tele,
        )
        if tele:
            U, P, Q, loss, tvec = out
            return (U, P, Q), (loss, tvec)
        U, P, Q, loss = out
        return (U, P, Q), loss

    (U, P, Q), ys = jax.lax.scan(body, (U, P, Q), xs)
    if tele:
        losses, tvecs = ys
        return U, P, Q, losses, tvecs.sum(axis=0)
    return U, P, Q, ys


@functools.partial(jax.jit,
                   static_argnames=("cfg", "use_ring", "byz", "use_attack",
                                    "byz_cap", "tele"),
                   donate_argnums=(0, 1, 2))
def _epoch_scan_churn(
    U: jnp.ndarray,
    P: jnp.ndarray,
    Q: jnp.ndarray,
    nbr_idx: jnp.ndarray,      # (I, S)
    nbr_wgt: jnp.ndarray,      # (I, S)
    ui: jnp.ndarray,           # (n_batches, B)
    vj: jnp.ndarray,
    r: jnp.ndarray,
    conf: jnp.ndarray,         # offline senders' rows already zeroed
    valid: jnp.ndarray,        # (n_batches, B) sender-online row mask
    prop_now: jnp.ndarray,     # (n_batches, B) full-scatter-this-epoch mask
    recv_gate: jnp.ndarray,    # (I,) receiver-online mask this epoch
    ring_gp: jnp.ndarray,      # (L, n, K) buffered released messages
    ring_ui: jnp.ndarray,      # (L·n,) buffered senders (flattened)
    ring_vj: jnp.ndarray,      # (L·n,) buffered item ids
    ring_deliver: jnp.ndarray,  # (L·n,) float mask: due exactly this epoch
    dp_seed: jnp.ndarray,      # () int32 per-epoch mechanism seed (traced)
    amul: jnp.ndarray,         # (n_batches, B) attack multipliers (dead if !use_attack)
    ashill: jnp.ndarray,       # (n_batches, B) shill-replacement mask
    vjm: jnp.ndarray,          # (n_batches, B) message item addressing
    dirs: jnp.ndarray,         # (I, K) premultiplied shill content
    b_id: jnp.ndarray,         # (n_batches, B, S) bucket ids (dead if sum agg)
    b_pos: jnp.ndarray,        # (n_batches, B, S) in-bucket positions
    b_recv: jnp.ndarray,       # (n_batches, NBK) bucket receiver rows
    b_item: jnp.ndarray,       # (n_batches, NBK) bucket item ids
    cfg: DMFConfig,
    use_ring: bool,
    byz=None,                  # robustness.byzantine.DefenseConfig | None
    use_attack: bool = False,
    byz_cap: int = 0,
    tele: bool = False,        # static: emit the summed TELE_W reductions
):
    """`_epoch_scan` under a fault schedule: same one-dispatch epoch, with
    (1) start-of-epoch delivery of the delay ring's messages due now —
    neighbor slots only (the straggler applied its own line-11 update at
    release), gated by the receivers' online mask NOW; (2) per-row fault
    gates threaded into every minibatch step; (3) the epoch's released
    message stream collected for the ring (only when ``use_ring``).

    Under the trivial schedule (all masks 1, ``use_ring=False``) every
    fault op is a multiply-by-1.0 — bitwise identity — so the compiled
    epoch produces exactly `_epoch_scan`'s outputs.

    Byzantine args (``byz``/``use_attack``/``byz_cap`` static): with
    ``byz=None`` every attack/defense input is statically dead and the
    trace is unchanged. A ring message due now is screened AT DELIVERY —
    a malicious message buffered k epochs ago must not dodge the gate by
    arriving late (the ring buffers SENT, i.e. corrupted, content)."""
    theta = cfg.lr
    if use_ring:
        gflat = ring_gp.reshape(-1, ring_gp.shape[-1])    # (L·n, K)
        nbd = nbr_idx[ring_ui]                            # (L·n, S)
        wbd = nbr_wgt[ring_ui]
        selfm = (nbd == ring_ui[:, None]).astype(wbd.dtype)
        wbd = (wbd * (1.0 - selfm) * recv_gate[nbd]
               * ring_deliver[:, None])
        if byz is not None:
            from repro.robustness import byzantine as byz_lib
            if byz.screen:
                okd = byz_lib.screen_ok(gflat, byz.norm_cap)
                gflat = jnp.where(okd[:, None] > 0, gflat, 0.0)
                wbd = wbd * okd[:, None]
                # screened gflat is finite: plain multiply, ±0-neutral
                upd = wbd[:, :, None] * gflat[:, None, :]
            else:
                upd = jnp.where((wbd > 0)[:, :, None],
                                wbd[:, :, None] * gflat[:, None, :], 0.0)
        else:
            upd = wbd[:, :, None] * gflat[:, None, :]
        P = P.at[nbd, ring_vj[:, None]].add(-theta * upd)
    nb, B = ui.shape
    from repro.privacy import mechanism
    noise_on = cfg.dp and cfg.mode != "ldmf" and mechanism.noise_std(cfg) > 0
    xs = [ui, vj, r, conf, valid, prop_now]
    if noise_on:
        from repro.kernels.dp_noise import gauss_counter
        K = U.shape[-1]
        rid = jnp.arange(nb * B, dtype=jnp.int32).reshape(-1, 1)
        Z = (mechanism.noise_std(cfg)
             * gauss_counter(dp_seed, rid, K)).reshape(nb, B, K)
        xs.append(Z)
    if use_attack:
        xs += [amul, ashill]
    robust = byz is not None and byz.aggregation != "sum"
    if byz is not None:
        xs.append(vjm)
    if robust:
        xs += [b_id, b_pos, b_recv, b_item]

    def body(carry, batch):
        U, P, Q = carry
        b_ui, b_vj, b_r, b_conf, b_val, b_prop = batch[:6]
        i = 6
        b_noise = None
        if noise_on:
            b_noise = batch[i]
            i += 1
        b_amul = b_ashill = b_vjm = bkt = None
        if use_attack:
            b_amul, b_ashill = batch[i], batch[i + 1]
            i += 2
        if byz is not None:
            b_vjm = batch[i]
            i += 1
        if robust:
            bkt = batch[i:i + 4]
        out = _sparse_batch_update_messages(
            U, P, Q, nbr_idx, nbr_wgt, b_ui, b_vj, b_r, b_conf, cfg,
            valid=b_val, noise=b_noise,
            recv_gate=recv_gate, prop_now=b_prop,
            byz=byz, amul=b_amul, ashill=b_ashill,
            dirs=dirs if use_attack else None, vjm=b_vjm, bkt=bkt,
            byz_cap=byz_cap, tele=tele,
        )
        if tele:
            U, P, Q, loss, gp, tvec = out
        else:
            U, P, Q, loss, gp = out
        y = [loss]
        if use_ring:
            y.append(gp)
        if tele:
            y.append(tvec)
        return (U, P, Q), (tuple(y) if len(y) > 1 else y[0])

    (U, P, Q), ys = jax.lax.scan(body, (U, P, Q), tuple(xs))
    tele_sum = None
    if tele:
        ys, tvecs = (ys[:-1], ys[-1])
        tele_sum = tvecs.sum(axis=0)
        ys = ys if use_ring else ys[0]
    if use_ring:
        losses, gps = ys
        out = (U, P, Q, losses, gps)
    else:
        out = (U, P, Q, ys, None)
    if tele:
        return out + (tele_sum,)
    return out


def train_epoch_churn(
    state: DMFState,
    prop,
    train: np.ndarray,
    cfg: DMFConfig,
    rng: np.random.Generator,
    t: int,
    plan,                       # robustness.faults.ChurnPlan
    ring,                       # robustness.faults.DelayRing | None
    accountant=None,
    attack=None,                # robustness.byzantine.AttackPlan | None
    byz=None,                   # robustness.byzantine.DefenseConfig | None
    tele: bool = False,         # append the epoch's TELE_W device stats
) -> tuple[DMFState, float]:
    """`train_epoch` under a compiled `ChurnPlan` for epoch ``t``: the SAME
    sampled stream (same rng consumption, per-epoch DP seed included), with
    offline senders' rows zeroed host-side (conf=0 + valid=0 ⇒ their U/Q
    rows bit-frozen and they release nothing), receivers gated by this
    epoch's online mask, stragglers' neighbor scatters deferred through
    ``ring``, and the accountant observing only the REALIZED stream.
    Reported loss normalizes by realized (online) rows. ``cfg.n_shards>1``
    dispatches to the SPMD counterpart (sharding/dmf.py).

    ``attack`` (a compiled `AttackPlan`) corrupts the epoch's outgoing
    messages at the sender boundary; ``byz`` (a `DefenseConfig`) turns on
    receiver-side screening / robust aggregation. Both None (the default)
    leaves the compiled epoch untouched. The delay ring buffers the SENT
    (post-corruption) stream under shill re-addressing (``vjm``)."""
    if cfg.n_shards > 1:
        from repro.sharding import dmf as sharded_dmf
        return sharded_dmf.train_epoch_churn_sharded(
            state, prop, train, cfg, rng, t, plan, ring,
            accountant=accountant, attack=attack, byz=byz, tele=tele)
    nbr = _as_neighbor_table(prop)
    ui, vj, r, conf = sample_epoch(train, cfg, rng)
    B = cfg.batch_size
    nb = len(ui) // B
    n = nb * B
    shape = (nb, B)
    ui2 = ui[:n].reshape(shape)
    vj2 = vj[:n].reshape(shape)
    _, dp_seed = epoch_dp_inputs(cfg, rng, n)
    on, sender_on, prop_now, due = plan.epoch_row_masks(t, ui2)
    conf2 = conf[:n].reshape(shape) * sender_on
    if accountant is not None:
        accountant.observe_epoch(ui2, valid=sender_on)
    use_ring = ring is not None
    if use_ring:
        r_ui = ring.ui.reshape(-1)
        r_vj = ring.vj.reshape(-1)
        r_del = (ring.due.reshape(-1) == t).astype(np.float32)
        ring_gp = ring.gp
    else:  # statically-skipped dummies (dead jit inputs)
        r_ui = np.zeros(1, np.int32)
        r_vj = np.zeros(1, np.int32)
        r_del = np.zeros(1, np.float32)
        ring_gp = jnp.zeros((1, 1, state.U.shape[-1]), jnp.float32)
    use_attack = attack is not None
    if use_attack:
        assert byz is not None   # fit() supplies DefenseConfig() (all-off)
        amul, ashill, vjm = attack.epoch_row_attack(
            t, ui2, vj2, sender_on=sender_on)
        dirs = jnp.asarray(attack.dirs)
    else:
        amul = ashill = np.zeros(1, np.float32)
        vjm = vj2
        dirs = jnp.zeros((1, state.U.shape[-1]), jnp.float32)
    robust = byz is not None and byz.aggregation != "sum"
    if robust:
        from repro.robustness import byzantine as byz_lib
        groups = byz_lib.group_messages(
            ui2, vjm, nbr.idx, nbr.wgt, cfg.n_items,
            sender_gate=sender_on.astype(bool) & prop_now.astype(bool),
            recv_on=on.astype(bool))
        gb = (jnp.asarray(groups.bucket_id), jnp.asarray(groups.pos),
              jnp.asarray(groups.recv), jnp.asarray(groups.item))
        byz_cap = groups.cap
    else:
        z1 = np.zeros(1, np.int32)
        gb = (z1, z1, z1, z1)
        byz_cap = 0
    out = _epoch_scan_churn(
        state.U, state.P, state.Q, nbr.idx, nbr.wgt,
        jnp.asarray(ui2), jnp.asarray(vj2),
        jnp.asarray(r[:n].reshape(shape)), jnp.asarray(conf2),
        jnp.asarray(sender_on.astype(np.float32)),
        jnp.asarray(prop_now.astype(np.float32)),
        jnp.asarray(on.astype(np.float32)),
        ring_gp, jnp.asarray(r_ui), jnp.asarray(r_vj), jnp.asarray(r_del),
        jnp.asarray(dp_seed, jnp.int32),
        jnp.asarray(amul), jnp.asarray(ashill), jnp.asarray(vjm), dirs,
        gb[0], gb[1], gb[2], gb[3],
        cfg, use_ring, byz, use_attack, byz_cap, tele=tele,
    )
    U, P, Q, losses, gps = out[:5]
    if use_ring:
        ring.write(t, gps.reshape(n, -1), ui2,
                   vjm if byz is not None else vj2, due)
    total = float(np.asarray(losses, dtype=np.float64).sum())
    realized = int(sender_on.sum())
    l = total / max(realized, 1)
    if tele:
        return DMFState(U, P, Q), l, np.asarray(out[5])
    return DMFState(U, P, Q), l


def sample_with_negatives(
    pos: np.ndarray, n_items: int, m: int, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Positives + m sampled unobserved negatives per positive with
    confidence 1/m (paper §Unobserved rating sample), shuffled together.
    The single definition of the sampling convention — shared by training
    epochs and the online-refresh event stream (serving/online.py), so the
    two objectives cannot silently diverge."""
    n = len(pos)
    neg_u = np.repeat(pos[:, 0], m)
    neg_j = rng.integers(0, n_items, size=n * m)
    ui = np.concatenate([pos[:, 0], neg_u])
    vj = np.concatenate([pos[:, 1], neg_j])
    r = np.concatenate([np.ones(n, np.float32), np.zeros(n * m, np.float32)])
    conf = np.concatenate(
        [np.ones(n, np.float32), np.full(n * m, 1.0 / m, np.float32)]
    )
    order = rng.permutation(len(ui))
    return ui[order], vj[order], r[order], conf[order]


def sample_epoch(
    train: np.ndarray, cfg: DMFConfig, rng: np.random.Generator
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Shuffled positives + m sampled unobserved negatives with confidence 1/m."""
    pos = train[rng.permutation(len(train))]
    return sample_with_negatives(pos, cfg.n_items, cfg.neg_samples, rng)


def train_epoch_dense(
    state: DMFState,
    M: jnp.ndarray,
    train: np.ndarray,
    cfg: DMFConfig,
    rng: np.random.Generator,
) -> tuple[DMFState, float]:
    """Seed reference path: Python per-batch loop over the dense (I, I) M,
    with a host sync per batch. O(I·B·K) per batch — kept as the
    equivalence oracle for the sparse-scan path and for ablations."""
    ui, vj, r, conf = sample_epoch(train, cfg, rng)
    B = cfg.batch_size
    n = (len(ui) // B) * B
    U, P, Q = state.U, state.P, state.Q
    total = 0.0
    for s in range(0, n, B):
        U, P, Q, loss = _batch_step(
            U, P, Q, M,
            jnp.asarray(ui[s : s + B]),
            jnp.asarray(vj[s : s + B]),
            jnp.asarray(r[s : s + B]),
            jnp.asarray(conf[s : s + B]),
            cfg,
        )
        total += float(loss)
    return DMFState(U, P, Q), total / max(n, 1)


def _as_neighbor_table(prop) -> graph_lib.NeighborTable:
    if isinstance(prop, graph_lib.NeighborTable):
        return prop
    return graph_lib.neighbor_table_from_dense(np.asarray(prop))


def epoch_dp_inputs(cfg: DMFConfig, rng: np.random.Generator, n: int):
    """Per-epoch DP mechanism inputs for an n-row stream: the rows' global
    stream ids (the shard-count-invariant noise keys) and the fresh
    per-epoch seed. DP off: zeros, and — crucially — NO rng draw, so the
    un-noised paths' rng stream stays bit-exact."""
    rid = np.arange(n, dtype=np.int32)
    if not cfg.dp:
        return rid, 0
    from repro.privacy import mechanism
    return rid, mechanism.epoch_noise_seed(rng, cfg)


def train_epoch(
    state: DMFState,
    prop,                       # graph.NeighborTable, or dense (I, I) M
    train: np.ndarray,
    cfg: DMFConfig,
    rng: np.random.Generator,
    accountant=None,
    tele: bool = False,         # append the epoch's TELE_W device stats
) -> tuple[DMFState, float]:
    """Sparse-neighborhood scan epoch: one jitted dispatch for the whole
    epoch, O(B·S·K) propagation per batch. Passing a dense M converts it
    per call — convert once via `graph.walk_neighbor_table` in loops.

    With ``cfg.n_shards > 1`` the epoch runs learner-sharded: same minibatch
    stream, rows routed to each user's home shard, one SPMD dispatch over
    the ``learners`` mesh (sharding/dmf.py). The returned state's learner
    axis stays padded+sharded between epochs; `fit` unpads at the end, or
    call `sharding.dmf.unpad_state` yourself.

    ``accountant`` (a `privacy.GaussianAccountant`) observes the epoch's
    realized minibatch stream for per-learner ε(δ) tracking when DP is on.
    """
    if cfg.n_shards > 1:
        from repro.sharding import dmf as sharded_dmf
        return sharded_dmf.train_epoch_sharded(
            state, prop, train, cfg, rng, accountant=accountant, tele=tele)
    nbr = _as_neighbor_table(prop)
    ui, vj, r, conf = sample_epoch(train, cfg, rng)
    B = cfg.batch_size
    nb = len(ui) // B
    n = nb * B
    shape = (nb, B)
    _, dp_seed = epoch_dp_inputs(cfg, rng, n)
    if accountant is not None:
        accountant.observe_epoch(ui[:n].reshape(shape))
    out = _epoch_scan(
        state.U, state.P, state.Q, nbr.idx, nbr.wgt,
        jnp.asarray(ui[:n].reshape(shape)),
        jnp.asarray(vj[:n].reshape(shape)),
        jnp.asarray(r[:n].reshape(shape)),
        jnp.asarray(conf[:n].reshape(shape)),
        jnp.asarray(dp_seed, jnp.int32),
        cfg, tele=tele,
    )
    U, P, Q, losses = out[:4]
    total = float(np.asarray(losses, dtype=np.float64).sum())
    l = total / max(n, 1)
    if tele:
        return DMFState(U, P, Q), l, np.asarray(out[4])
    return DMFState(U, P, Q), l


@functools.partial(jax.jit, static_argnames=())
def scores(state_U: jnp.ndarray, state_P: jnp.ndarray, state_Q: jnp.ndarray) -> jnp.ndarray:
    """(I, J) predicted preference û_i^T (p^i_j + q^i_j) — computed on-device
    per learner in deployment; materialized densely here for evaluation."""
    V = state_P + state_Q                     # (I, J, K)
    return jnp.einsum("ik,ijk->ij", state_U, V)


def test_loss(state: DMFState, test: np.ndarray) -> float:
    u = state.U[test[:, 0]]
    v = state.P[test[:, 0], test[:, 1]] + state.Q[test[:, 0], test[:, 1]]
    pred = jnp.sum(u * v, -1)
    return float(0.5 * jnp.mean((1.0 - pred) ** 2))


@dataclasses.dataclass
class FitResult:
    state: DMFState
    train_losses: list
    test_losses: list
    privacy: dict | None = None   # accountant summary when cfg.dp (ε(δ) etc.)
    diverged_at: int | None = None  # epoch whose update went non-finite
                                    # (only set under on_nonfinite="halt")
    telemetry: list | None = None   # per-epoch event dicts when
                                    # fit(telemetry=True) (obs/telemetry.py)


class DivergenceError(RuntimeError):
    """Training produced a non-finite loss or factor update
    (``fit(on_nonfinite="raise")``)."""


def _epoch_finite(state: DMFState, loss: float) -> bool:
    """Epoch health check: loss AND factors finite. Three all-reduces —
    only paid under on_nonfinite={"raise","halt"}."""
    if not np.isfinite(loss):
        return False
    return bool(jnp.isfinite(state.U).all() & jnp.isfinite(state.P).all()
                & jnp.isfinite(state.Q).all())


def fit(
    cfg: DMFConfig,
    train: np.ndarray,
    M: np.ndarray,
    epochs: int = 30,
    test: np.ndarray | None = None,
    callback: Callable | None = None,
    seed: int | None = None,
    dense_reference: bool = False,
    dp_delta: float = 1e-5,
    churn=None,
    checkpoint_dir=None,
    checkpoint_every: int = 0,
    resume_from=None,
    attack=None,
    defense=None,
    on_nonfinite: str = "warn",
    telemetry: bool = False,
    telemetry_out=None,
    log_every: int = 0,
) -> FitResult:
    """Train `epochs` epochs of Alg. 1. `M` may be a dense (I, I) propagation
    matrix or a `graph.NeighborTable`; the sparse scan path is the default,
    `dense_reference=True` forces the seed dense per-batch loop (oracle).

    With DP on (``cfg.dp_sigma > 0``) a `privacy.GaussianAccountant`
    observes every epoch's realized minibatch stream; its per-learner
    ε(``dp_delta``) summary lands in `FitResult.privacy`.

    Fault tolerance (robustness/): ``churn`` is a `ChurnConfig` (compiled
    here) or pre-compiled `ChurnPlan` — epochs then run the fault-injected
    path (offline learners bit-frozen, stragglers' messages delivered
    late). ``checkpoint_dir`` + ``checkpoint_every`` snapshot the FULL loop
    state (factors, rng stream, delay ring, accountant) every N completed
    epochs; ``resume_from`` (a step dir or checkpoint root) restores one
    and continues — bit-identical to the uninterrupted run, DP included
    (the counter-keyed noise replays from the restored rng stream).

    Byzantine robustness (robustness/byzantine.py): ``attack`` is an
    `AttackConfig` (compiled here) or pre-compiled `AttackPlan` injecting
    malicious outgoing messages; ``defense`` is a `DefenseConfig` turning
    on receiver-side screening and/or robust aggregation. Either one
    routes epochs through the churn machinery (a trivial all-online plan
    when ``churn`` is None); both None leaves every compiled program
    bit-exact with the defenseless stack.

    Observability (obs/, DESIGN.md §14): ``telemetry=True`` (or a
    ``telemetry_out`` JSONL path) collects one event dict per epoch —
    loss, update norms, released/scattered message mass, message counts
    per shard, DP ε-so-far, churn online count, delay-ring occupancy,
    screening accept/reject — into `FitResult.telemetry`. The device
    half is read-only reductions inside the same one-dispatch epoch (no
    rng draws): factor trajectories are bit-identical with telemetry
    off, which in turn compiles the exact uninstrumented program.
    ``log_every=N`` logs a progress line every N epochs via
    ``logging.getLogger("repro.dmf")`` (includes ε when DP is on); span
    tracing is global — see `obs.trace.configure_tracing`.

    ``on_nonfinite`` — divergence sentinel: "warn" (default) emits a
    RuntimeWarning on a non-finite epoch loss and keeps going (the
    pre-existing numerics); "raise" raises `DivergenceError`; "halt"
    stops training, returns the LAST finite state and sets
    `FitResult.diverged_at` to the offending epoch (that epoch's loss
    stays in `train_losses` as the evidence)."""
    assert on_nonfinite in ("warn", "raise", "halt"), on_nonfinite
    tele_on = bool(telemetry) or telemetry_out is not None
    assert not (tele_on and dense_reference), (
        "telemetry rides the sparse/sharded epoch programs")
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    state = init_state(cfg, rng)
    accountant = None
    if cfg.dp and cfg.dp_sigma > 0.0:   # ldmf: no releases, no ε claim
        from repro.privacy import GaussianAccountant
        accountant = GaussianAccountant(
            n_users=cfg.n_users, sigma=cfg.dp_sigma, delta=dp_delta)
    plan = None
    ring = None
    if churn is not None:
        from repro.robustness import faults
        assert not dense_reference, "churn runs the sparse/sharded paths"
        plan = (churn.compile(cfg.n_users, epochs)
                if isinstance(churn, faults.ChurnConfig) else churn)
        assert plan.n_users == cfg.n_users, (plan.n_users, cfg.n_users)
        assert plan.n_epochs >= epochs, (plan.n_epochs, epochs)
        # the per-epoch stream length is schedule-independent, so the ring
        # shape is known up front
        nb = (len(train) * (1 + cfg.neg_samples)) // cfg.batch_size
        ring = faults.DelayRing.create(plan.k_max, nb * cfg.batch_size,
                                       cfg.dim)
    attack_plan = None
    byz = None
    if attack is not None:
        from repro.robustness import byzantine
        attack_plan = (attack.compile(cfg.n_users, epochs, cfg.dim)
                       if isinstance(attack, byzantine.AttackConfig)
                       else attack)
        assert attack_plan.n_users == cfg.n_users, (
            attack_plan.n_users, cfg.n_users)
        assert attack_plan.n_epochs >= epochs, (attack_plan.n_epochs, epochs)
        assert attack_plan.config.target_item < cfg.n_items
        if attack_plan.is_trivial():
            attack_plan = None
    if defense is not None and defense.active:
        byz = defense
    if attack_plan is not None and byz is None:
        from repro.robustness.byzantine import DefenseConfig
        byz = DefenseConfig()    # undefended channel, byz path on
    if (attack_plan is not None or byz is not None) and plan is None:
        # the byzantine exchange runs on the churn epoch program — use the
        # trivial all-online schedule (bit-exact gates), no delay ring
        from repro.robustness import faults
        assert not dense_reference, "byzantine runs the sparse/sharded paths"
        plan = faults.no_churn(cfg.n_users, epochs)
    if dense_reference:
        assert not isinstance(M, graph_lib.NeighborTable), (
            "dense_reference needs the dense M"
        )
        assert cfg.n_shards == 1, "dense_reference is the single-device oracle"
        assert not cfg.dp, "dense_reference is the un-noised oracle path"
        prop = jnp.asarray(M)
        epoch_fn = train_epoch_dense
    elif cfg.n_shards > 1:
        from repro.sharding import dmf as sharded_dmf
        prop = sharded_dmf.make_shard_plan(_as_neighbor_table(M), cfg)
        epoch_fn = train_epoch
    else:
        prop = _as_neighbor_table(M)
        epoch_fn = train_epoch
    collector = None
    if tele_on:
        from repro.obs import telemetry as tele_lib
        collector = tele_lib.EpochCollector(jsonl_path=telemetry_out,
                                            n_shards=cfg.n_shards)
    logger = None
    if log_every:
        import logging
        logger = logging.getLogger("repro.dmf")
    from repro.obs import trace as trace_lib
    tr_losses, te_losses = [], []
    start = 0
    if resume_from is not None:
        from repro.robustness import recovery
        state, rng, ring, start, tr_losses, te_losses = (
            recovery.load_training(resume_from, like_state=state,
                                   ring=ring, accountant=accountant))
    diverged_at = None
    warned = False
    for t in range(start, epochs):
        if on_nonfinite == "halt":
            # donated buffers: the epoch consumes `state`, so the fallback
            # copy must be taken up front (only paid in halt mode)
            prev = DMFState(jnp.copy(state.U), jnp.copy(state.P),
                            jnp.copy(state.Q))
        t0 = time.perf_counter() if tele_on else 0.0
        dstats = None
        with trace_lib.span("fit.epoch", epoch=t):
            if plan is not None:
                out = train_epoch_churn(state, prop, train, cfg, rng, t,
                                        plan, ring, accountant=accountant,
                                        attack=attack_plan, byz=byz,
                                        tele=tele_on)
            elif epoch_fn is train_epoch_dense:
                out = epoch_fn(state, prop, train, cfg, rng)
            else:
                out = epoch_fn(state, prop, train, cfg, rng,
                               accountant=accountant, tele=tele_on)
        if tele_on:
            state, l, dstats = out
        else:
            state, l = out
        tr_losses.append(l)
        if on_nonfinite == "warn":
            if not warned and not np.isfinite(l):
                import warnings
                warnings.warn(
                    f"epoch {t}: non-finite training loss {l!r} — training "
                    "has diverged (see fit(on_nonfinite=...))",
                    RuntimeWarning, stacklevel=2)
                warned = True
        elif not _epoch_finite(state, l):
            if on_nonfinite == "raise":
                raise DivergenceError(
                    f"epoch {t}: non-finite loss or factors (loss={l!r})")
            state = prev             # halt: last finite state wins
            diverged_at = t
            break
        if test is not None:
            te_losses.append(test_loss(state, test))
        if collector is not None:
            collector.record(
                t, train_loss=l, device_stats=dstats,
                test_loss=te_losses[-1] if test is not None else None,
                accountant=accountant, plan=plan, ring=ring, byz=byz,
                wall_s=time.perf_counter() - t0)
        if logger is not None and ((t + 1) % log_every == 0
                                   or t == epochs - 1):
            msg = f"epoch {t + 1}/{epochs} train_loss={l:.6f}"
            if test is not None:
                msg += f" test_loss={te_losses[-1]:.6f}"
            if accountant is not None and accountant.eps_trajectory:
                msg += f" eps={accountant.eps_trajectory[-1]:.4f}"
            logger.info(msg)
        if callback is not None:
            callback(t, state, l)
        if (checkpoint_dir is not None and checkpoint_every > 0
                and (t + 1) % checkpoint_every == 0):
            from repro.robustness import recovery
            snap = state
            if cfg.n_shards > 1:
                from repro.sharding import dmf as sharded_dmf
                snap = sharded_dmf.unpad_state(state, cfg.n_users)
            recovery.save_training(
                checkpoint_dir, step=t + 1, state=snap, rng=rng, ring=ring,
                accountant=accountant, train_losses=tr_losses,
                test_losses=te_losses)
    if cfg.n_shards > 1 and not dense_reference:
        from repro.sharding import dmf as sharded_dmf
        state = sharded_dmf.unpad_state(state, cfg.n_users)
    if collector is not None:
        collector.close()
    return FitResult(state, tr_losses, te_losses,
                     privacy=accountant.summary() if accountant else None,
                     diverged_at=diverged_at,
                     telemetry=collector.events if collector else None)


def evaluate(
    state: DMFState, train: np.ndarray, test: np.ndarray, n_users: int, n_items: int,
    ks=(5, 10), interpret: bool = True, n_shards: int = 1,
    chunk_users: int | None = None,
) -> dict[str, float]:
    """Ranking metrics via the streaming top-k kernel: the (I, J) score
    matrix never materializes — per-user running top-k is carried across
    item tiles (ops.recommend_topk_peruser). ``n_shards > 1`` runs the
    kernel learner-sharded over the mesh (row-parallel, same results).

    ``chunk_users`` streams the USER axis too: each chunk builds only its
    own V = P + Q rows and train/test mask rows (O(chunk · J) peak, from
    the interaction pairs directly), so the full (I, J, K) V view, the
    (I, J) masks and the factors never co-materialize — the regime that
    makes evaluation feasible when I is in the millions while the (I, S)
    neighbor table from training is still resident. Per-user hit counts
    are integers and the final reduction sees them in the same global user
    order, so results are IDENTICAL floats to the unchunked path."""
    from repro.kernels import ops
    if n_shards > 1:
        from repro.sharding import dmf as sharded_dmf
        return sharded_dmf.evaluate_sharded(
            state, train, test, n_users, n_items, n_shards, ks=ks,
            interpret=interpret, chunk_users=chunk_users)
    kmax = max(ks)
    if chunk_users is None:
        train_mask = metrics_lib.masks_from_interactions(n_users, n_items, train)
        test_mask = metrics_lib.masks_from_interactions(n_users, n_items, test)
        V = state.P + state.Q                 # (I, J, K) per-learner factors
        _, idx = ops.recommend_topk_peruser(
            state.U, V, jnp.asarray(train_mask), kmax, interpret=interpret
        )
        return metrics_lib.evaluate_ranking_from_topk(
            np.asarray(idx), test_mask, ks)
    hits: dict[int, list[np.ndarray]] = {k: [] for k in ks}
    n_test_parts: list[np.ndarray] = []
    step = max(int(chunk_users), 1)
    for s in range(0, n_users, step):
        e = min(s + step, n_users)
        tm = metrics_lib.masks_from_interactions_rows(s, e - s, n_items, train)
        ts = metrics_lib.masks_from_interactions_rows(s, e - s, n_items, test)
        V = state.P[s:e] + state.Q[s:e]       # only this chunk's item view
        _, idx = ops.recommend_topk_peruser(
            state.U[s:e], V, jnp.asarray(tm), kmax, interpret=interpret)
        rec = np.asarray(idx)
        for k in ks:
            hits[k].append(metrics_lib.topk_hits(rec, ts, k))
        n_test_parts.append(ts.sum(axis=1))
    n_test = np.concatenate(n_test_parts) if n_test_parts else np.zeros(0, int)
    out = {}
    for k in ks:
        p, r = metrics_lib.precision_recall_from_hits(
            np.concatenate(hits[k]) if hits[k] else np.zeros(0, int), n_test, k)
        out[f"P@{k}"] = p
        out[f"R@{k}"] = r
    return out


def evaluate_dense(
    state: DMFState, train: np.ndarray, test: np.ndarray, n_users: int, n_items: int,
    ks=(5, 10),
) -> dict[str, float]:
    """Seed reference evaluation through the dense (I, J) score matrix —
    oracle for the streaming path."""
    sc = np.asarray(scores(state.U, state.P, state.Q))
    train_mask = metrics_lib.masks_from_interactions(n_users, n_items, train)
    test_mask = metrics_lib.masks_from_interactions(n_users, n_items, test)
    return metrics_lib.evaluate_ranking(sc, train_mask, test_mask, ks)
