"""DMF's decentralized protocol mapped onto a TPU pod (DESIGN.md §4).

The paper's three mechanisms become, for pod-scale training:

1. **Learners** — mesh coordinates along ``learner_axis`` ("data" for
   per-shard learners, "pod" for one learner per pod). Every learner holds
   its own model replica: parameters gain a leading learner dim L, sharded
   over ``learner_axis`` (per-device memory equals plain DP).
2. **Nearby-user communication + random walk** — after each local update,
   the *global* parameter partition is mixed with a doubly-stochastic ring
   weighting; ``walk_length`` (the paper's D) rounds of mixing apply Ŵ^D.
   ``jnp.roll`` along the learner-sharded dim lowers to
   ``collective-permute`` — neighbor-only traffic, never an all-reduce.
3. **Global/local decomposition (p vs q^i)** — parameters matching
   ``personal_predicate`` (default: norm scales and biases) are *never*
   mixed: each learner keeps its personal copy, exactly like q^i_j in
   Eq. 5. Everything else is the shared p.

Gradient-exchange privacy note: as in the paper, only derived quantities of
the shared partition cross learner boundaries; raw batches and personal
parameters never do. (Mixing post-update parameters is gradient exchange
plus a consensus term — the Nedic–Ozdaglar form the paper builds on.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    learner_axis: str = "data"       # mesh axis acting as the learner ring
    walk_length: int = 2             # D — rounds of neighbor mixing per step
    self_weight: float = 0.5         # ring mixing: self + left/right neighbors
    personal_predicate: Callable | None = None   # path -> bool (True = q^i)


def default_personal(path_str: str) -> bool:
    """The q^i partition: per-learner norms/biases (cheap, personal)."""
    leaf = path_str.split("/")[-1]
    return leaf.startswith(("ln", "norm", "final_norm", "b", "gate")) or "norm" in leaf


def _is_personal(cfg: GossipConfig, path) -> bool:
    pred = cfg.personal_predicate or default_personal
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
    return pred("/".join(keys))


def ring_mix(x: jnp.ndarray, cfg: GossipConfig) -> jnp.ndarray:
    """One Ŵ-round: doubly-stochastic ring mixing along leading learner dim.

    x: (L, ...). roll on the learner-sharded axis -> collective-permute.
    """
    w_self = cfg.self_weight
    w_nbr = (1.0 - w_self) / 2.0
    return (
        w_self * x
        + w_nbr * jnp.roll(x, 1, axis=0)
        + w_nbr * jnp.roll(x, -1, axis=0)
    ).astype(x.dtype)


def mix_global(params, cfg: GossipConfig):
    """Apply Ŵ^D to the global (p) partition; personal (q^i) untouched."""

    def mix_leaf(path, x):
        if _is_personal(cfg, path):
            return x
        for _ in range(cfg.walk_length):
            x = ring_mix(x, cfg)
        return x

    return jax.tree_util.tree_map_with_path(mix_leaf, params)


def stack_params(params, n_learners: int):
    """Broadcast params to a leading learner dim (identical init, like DMF's
    shared p initialization)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_learners, *x.shape)), params
    )


def stacked_specs(spec_tree, learner_axis: str):
    """Prepend the learner axis to every logical spec tuple."""
    is_leaf = lambda s: isinstance(s, tuple) and all(
        isinstance(x, str) or x is None for x in s
    )
    # learner axis resolved directly as a mesh axis name: mark with special
    # logical name understood by rules.resolve via LOGICAL_RULES override
    return jax.tree_util.tree_map(
        lambda s: (f"__mesh__{learner_axis}", *s), spec_tree, is_leaf=is_leaf
    )


def consensus_error(params, cfg: GossipConfig) -> jnp.ndarray:
    """Max relative deviation of the global partition across learners —
    the convergence diagnostic for tests/monitoring."""
    errs = []

    def f(path, x):
        if _is_personal(cfg, path):
            return
        mean = jnp.mean(x, axis=0, keepdims=True)
        num = jnp.max(jnp.abs(x - mean))
        den = jnp.maximum(jnp.max(jnp.abs(mean)), 1e-8)
        errs.append(num / den)

    jax.tree_util.tree_map_with_path(f, params)
    return jnp.max(jnp.stack(errs)) if errs else jnp.zeros(())
