"""Deterministic fault injection: learner churn + stale gradient exchange.

The committed training paths assume all I learners participate in every
epoch, synchronously — the one thing real phones never do. This module
models the deviations the decentralized-device literature cares about
("Decentralized Collaborative Learning Framework for Next POI
Recommendation"; gossip-convergence analysis in "Matrix Factorization
Method for Decentralized Recommender Systems"):

* **Dropout** — per-epoch i.i.d. Bernoulli offline probability;
* **Sessions** — power-law (Pareto-tailed) online-session lengths with
  offline gaps, the heavy-tailed availability traces real fleets show;
* **Late joiners** — cold-start learners that enter mid-training and have
  no state before their join epoch;
* **Stragglers** — per-learner delay classes: a class-k learner computes
  locally on time but its *outgoing* P-gradient messages reach receivers
  k epochs late (stale gradient exchange).

Everything compiles AHEAD of the run to fixed-shape numpy arrays
(`ChurnPlan`: an (epochs, I) participation mask + an (I,) delay class),
from the schedule's OWN seed — the training rng stream is never touched,
so a no-churn schedule leaves the fault-free run bit-exact.

Fault semantics (the contract DESIGN.md §10 documents and
tests/test_robustness.py pins):

* An offline learner is **bit-frozen**: its rows send no updates (its
  ratings are masked out of the epoch) and receive none (scatter weights
  into offline receivers are zeroed). Messages addressed to an offline
  learner are LOST, not queued — rejoining learners catch up through the
  protocol itself, receiving fresh gradients from the epoch they return.
* A straggler's own line-11 update applies immediately (local compute is
  never late); only the cross-learner deliveries lag, via the `DelayRing`
  below: messages released in epoch t with delay k are scatter-applied at
  the START of epoch t+k, gated by the receivers' online mask *then*.
* Delayed messages buffer AFTER the DP mechanism (clip+noise at release
  time), so the ring only ever holds already-released messages — staleness
  does not touch the privacy contract.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Schedule parameters. `compile(n_users, epochs)` realizes them into a
    `ChurnPlan`; the draw order (sessions → dropout → late join → delay
    classes) is fixed, so a seed fully determines the plan."""

    dropout: float = 0.0            # per-epoch Bernoulli offline probability
    session_alpha: float = 0.0      # >0: Pareto tail index of session lengths
    session_scale: float = 4.0      # min online-session length (epochs)
    offline_scale: float = 1.0      # min offline-gap length (epochs)
    late_frac: float = 0.0          # fraction of learners joining mid-run
    late_by: float = 0.5            # joins land uniformly in [1, late_by·T]
    delay_classes: tuple = (0,)     # straggler classes (epochs of staleness)
    delay_probs: tuple | None = None  # class probabilities (default uniform)
    seed: int = 0

    def __post_init__(self):
        assert 0.0 <= self.dropout < 1.0, self.dropout
        assert 0.0 <= self.late_frac <= 1.0, self.late_frac
        assert all(int(d) == d and d >= 0 for d in self.delay_classes), (
            self.delay_classes)
        if self.delay_probs is not None:
            assert len(self.delay_probs) == len(self.delay_classes)

    def compile(self, n_users: int, epochs: int) -> "ChurnPlan":
        rng = np.random.default_rng(self.seed)
        online = np.ones((epochs, n_users), dtype=bool)
        # 1. power-law sessions: alternate online/offline runs per learner
        if self.session_alpha > 0:
            for i in range(n_users):
                t, up = 0, bool(rng.random() < 0.8)   # most start online
                while t < epochs:
                    scale = self.session_scale if up else self.offline_scale
                    length = int(np.ceil(scale * (1.0 + rng.pareto(
                        self.session_alpha))))
                    if not up:
                        online[t: t + length, i] = False
                    t += length
                    up = not up
        # 2. i.i.d. per-epoch dropout on top of the session process
        if self.dropout > 0:
            online &= rng.random((epochs, n_users)) >= self.dropout
        # 3. late joiners: offline (and stateless) before their join epoch
        n_late = int(round(self.late_frac * n_users))
        join = np.zeros(n_users, np.int32)
        if n_late > 0:
            late_users = rng.choice(n_users, size=n_late, replace=False)
            hi = max(2, int(round(self.late_by * epochs)))
            join[late_users] = rng.integers(1, hi + 1, size=n_late)
            for u in late_users:
                online[: join[u], u] = False
        # 4. straggler delay classes
        classes = np.asarray(self.delay_classes, np.int32)
        probs = (None if self.delay_probs is None
                 else np.asarray(self.delay_probs, np.float64))
        delay = rng.choice(classes, size=n_users, p=probs).astype(np.int32)
        return ChurnPlan(online=online, delay=delay, join_epoch=join,
                         config=self)


def no_churn(n_users: int, epochs: int) -> "ChurnPlan":
    """The trivial plan: everyone online every epoch, zero staleness. The
    robust epoch path under this plan is bit-exact with the fault-free
    paths (tests/test_robustness.py pins it, single-device and sharded)."""
    return ChurnConfig().compile(n_users, epochs)


@dataclasses.dataclass(frozen=True)
class ChurnPlan:
    """A compiled schedule: pure data, safe to hash/ship/replay."""

    online: np.ndarray       # (epochs, I) bool — participation mask
    delay: np.ndarray        # (I,) int32 — per-learner staleness class
    join_epoch: np.ndarray   # (I,) int32 — 0 for from-the-start learners
    config: ChurnConfig | None = None

    @property
    def n_epochs(self) -> int:
        return int(self.online.shape[0])

    @property
    def n_users(self) -> int:
        return int(self.online.shape[1])

    @property
    def k_max(self) -> int:
        """Ring depth: the largest staleness any learner's messages carry."""
        return int(self.delay.max()) if self.delay.size else 0

    @property
    def participation_rate(self) -> float:
        return float(self.online.mean()) if self.online.size else 1.0

    def is_trivial(self) -> bool:
        return bool(self.online.all()) and self.k_max == 0

    def epoch_row_masks(self, t: int, ui: np.ndarray):
        """Per-row fault gates for epoch ``t`` of a sampled (nb, B) sender
        stream ``ui``:

        * ``sender_on`` — row's sender is online (False ⇒ the row is fully
          inert: conf is zeroed host-side and valid=0 kills the
          regularizer pulls, freezing the learner's U/Q rows);
        * ``prop_now`` — sender online AND delay class 0 ⇒ the full
          neighbor scatter happens this epoch (stragglers scatter only
          their own line-11 self-slot now);
        * ``due``     — delivery epoch of the row's buffered message
          (t + delay for online stragglers, -1 = never buffered).
        """
        assert 0 <= t < self.n_epochs, (t, self.n_epochs)
        on = self.online[t]
        sender_on = on[ui]
        d = self.delay[ui]
        prop_now = sender_on & (d == 0)
        due = np.where(sender_on & (d > 0), t + d, -1).astype(np.int32)
        return on, sender_on, prop_now, due


@dataclasses.dataclass
class DelayRing:
    """Fixed-shape stale-message buffer, carried across epochs by `fit`.

    Slot ``t % slots`` holds ALL of epoch t's delayed released messages
    (one row per stream position — ``gp`` is the post-DP message content,
    ``ui``/``vj``/``due`` its addressing). Since every delay class is
    ≤ ``slots``, a slot being overwritten at epoch t was written at
    t - slots and all its rows had due ≤ t — already delivered — so the
    ring is collision-free by construction. Delivery each epoch scans all
    slots with a ``due == t`` mask: exact, fixed-shape, one scatter.

    ``gp`` is a device array (written by the jitted epoch, which also
    performs delivery); the addressing arrays are host numpy, precomputable
    from the sampled stream before dispatch.
    """

    gp: jnp.ndarray   # (slots, n, K) float32 — released message content
    ui: np.ndarray    # (slots, n) int32 — global sender ids
    vj: np.ndarray    # (slots, n) int32 — item ids
    due: np.ndarray   # (slots, n) int32 — delivery epoch, -1 = empty

    @classmethod
    def create(cls, k_max: int, n: int, dim: int) -> "DelayRing | None":
        """Ring for staleness ≤ k_max over an n-row epoch stream; None when
        k_max == 0 (no stragglers ⇒ no buffer, no extra compute)."""
        if k_max <= 0:
            return None
        return cls(
            gp=jnp.zeros((k_max, n, dim), jnp.float32),
            ui=np.zeros((k_max, n), np.int32),
            vj=np.zeros((k_max, n), np.int32),
            due=np.full((k_max, n), -1, np.int32),
        )

    @property
    def slots(self) -> int:
        return int(self.ui.shape[0])

    def write(self, t: int, gp_new: jnp.ndarray, ui: np.ndarray,
              vj: np.ndarray, due: np.ndarray) -> None:
        """Record epoch t's released messages into its ring slot (called
        AFTER the epoch dispatch delivered everything due at t).

        Copy-on-write, never in place: views of these arrays are handed to
        `jnp.asarray` each epoch, and jax CPU transfers may be ZERO-COPY —
        mutating the buffer would race the still-in-flight async epoch
        reading it (observed as one-in-several-runs resume mismatches)."""
        s = t % self.slots
        self.gp = self.gp.at[s].set(gp_new)
        for name, new in (("ui", ui), ("vj", vj), ("due", due)):
            arr = getattr(self, name).copy()
            arr[s] = new.reshape(-1)
            setattr(self, name, arr)
