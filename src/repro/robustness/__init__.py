"""Fault injection and fault tolerance for decentralized training.

The paper's learners are phones: they drop offline, straggle, and join
mid-training. This package makes that realism first-class:

* `faults`   — seeded, deterministic `ChurnConfig`/`ChurnPlan` (per-epoch
  Bernoulli dropout, power-law session lengths, straggler delay classes,
  late-joining cold-start learners) compiled to fixed-shape per-epoch
  participation masks, plus the `DelayRing` buffer that applies a
  straggler's outgoing gradient messages k epochs late.
* `recovery` — crash-consistent training checkpoints: snapshot + restore
  of the FULL loop state (factors, rng stream, delay ring, DP accountant)
  so `dmf.fit(resume_from=...)` is bit-identical to the uninterrupted run.
* `byzantine` — adversarial realism on top of the crash realism: seeded
  `AttackConfig`/`AttackPlan` message-corruption schedules (NaN bombs,
  norm inflation, sign flips, targeted shilling, colluding groups) and
  the receiver-side `DefenseConfig` (finite+norm screening, trimmed-mean
  / median robust aggregation) applied at every delivery site.
"""
from repro.robustness.faults import (  # noqa: F401
    ChurnConfig,
    ChurnPlan,
    DelayRing,
    no_churn,
)
from repro.robustness.byzantine import (  # noqa: F401
    AGGREGATIONS,
    FAMILIES,
    AttackConfig,
    AttackPlan,
    DefenseConfig,
    no_attack,
)
from repro.robustness import recovery  # noqa: F401
