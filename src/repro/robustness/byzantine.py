"""Byzantine-robust gradient exchange: attack injection + receiver defenses.

PR 6 covered *crash* faults (churn, staleness, resume); this module covers
the *adversarial* half: in DMF every learner's P matrix is updated by
scatter-adding whatever gradient messages arrive, so a single compromised
phone can poison every D-hop neighbor. Three pieces:

* **Attack injection** — `AttackConfig.compile(...) -> AttackPlan`,
  mirroring `ChurnConfig -> ChurnPlan`: a seeded, deterministic plan of
  which learners are malicious from which epoch, realized per epoch as
  fixed-shape per-row corruption arrays applied to *outgoing* messages at
  the sender boundary (after the DP mechanism — a malicious sender is not
  assumed to run it honestly; the corruption REPLACES its release).
  Families:
    - ``nan`` / ``inf``      — non-finite bombs (one poisoned scatter
                               NaNs a receiver row forever);
    - ``norm_inflate``       — honest direction scaled by ``scale`` (λ);
    - ``sign_flip``          — negated gradient (norm-preserving, so it
                               passes any norm gate — the case for robust
                               aggregation);
    - ``shill``              — targeted item promotion: every message the
                               attacker sends is re-addressed to
                               ``target_item`` with content −scale·d̂, so
                               receivers' P[:, target] is pushed toward
                               the chosen direction d̂. ``collude=True``
                               gives all attackers ONE shared direction
                               (a colluding group), else each draws its
                               own.

* **Receiver-side screening** — `screen_ok`: a finite-check + L2 norm-cap
  gate evaluated on every incoming message BEFORE the P scatter (and on
  every stale `DelayRing` message at delivery). Rejected messages are
  zeroed content-AND-weight (0·NaN would still poison, so the content is
  `where`-ed out, not just the weight). The cap τ is calibrated from the
  DP mechanism (`privacy.mechanism.screening_threshold`): honest clipped+
  noised messages pass with probability ≥ 1−p by a chi-square tail bound.
  The decision depends only on (message content, τ), both shard-count
  invariant, so screening is too.

* **Robust aggregation** — when a receiver gets multiple messages for the
  same (item, step), `robust_combine` replaces plain summation with a
  coordinate-wise trimmed-mean or median over a fixed-shape per-(receiver,
  item) bucket buffer. Bucket membership is precompiled host-side per
  epoch (`group_messages` / `group_messages_sharded` — the sampled stream
  and the graph tables are host-known), padded to a stable (NBK, cap)
  shape, so the combine is one sort + masked reduction inside the same
  per-epoch dispatch. Values are sorted coordinate-wise before reduction,
  which makes the float summation order canonical — the combined update is
  invariant to the shard count that delivered the messages. The combined
  update is scaled by the valid-message count (``c · trimmed_mean``), so
  with no attackers it matches plain summation up to reassociation.

No-attack + defenses-off compiles the EXACT pre-existing epoch program:
`dmf.fit` only routes through the byzantine code when an attack plan or an
*active* `DefenseConfig` is present, so the default path stays bit-exact
with PRs 1-8 at every shard count (tests/test_byzantine.py pins it).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

FAMILIES = ("none", "nan", "inf", "norm_inflate", "sign_flip", "shill")
AGGREGATIONS = ("sum", "trim", "median")


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """Adversary schedule parameters. `compile(n_users, epochs, dim)`
    realizes them into an `AttackPlan`; the draw order (malicious set →
    shill directions) is fixed, so a seed fully determines the plan."""

    family: str = "none"        # one of FAMILIES
    frac: float = 0.0           # fraction of learners malicious
    scale: float = 10.0         # λ for norm_inflate; push magnitude for shill
    target_item: int = 0        # shill: the promoted POI
    collude: bool = True        # shill: one shared direction vs per-attacker
    start_epoch: int = 0        # attackers behave honestly before this epoch
    seed: int = 0

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        assert 0.0 <= self.frac <= 1.0, self.frac
        assert self.scale > 0.0, self.scale
        assert self.target_item >= 0 and self.start_epoch >= 0

    def compile(self, n_users: int, epochs: int, dim: int) -> "AttackPlan":
        rng = np.random.default_rng(self.seed)
        n_mal = int(round(self.frac * n_users))
        malicious = np.zeros(n_users, bool)
        if n_mal > 0 and self.family != "none":
            malicious[rng.choice(n_users, size=n_mal, replace=False)] = True
        active = np.zeros((epochs, n_users), bool)
        if self.start_epoch < epochs:
            active[self.start_epoch:] = malicious[None, :]
        dirs = np.zeros((n_users, dim), np.float32)
        if self.family == "shill" and malicious.any():
            k = 1 if self.collude else int(malicious.sum())
            d = rng.normal(size=(k, dim))
            d /= np.linalg.norm(d, axis=1, keepdims=True)
            # premultiplied message content: the scatter applies -θ·w·msg,
            # so msg = -scale·d̂ pushes P[:, target] toward +d̂
            dirs[malicious] = (-self.scale * d).astype(np.float32)
        return AttackPlan(active=active, malicious=malicious, dirs=dirs,
                          config=self)


@dataclasses.dataclass(frozen=True)
class AttackPlan:
    """A compiled adversary schedule: pure data, safe to hash/ship/replay."""

    active: np.ndarray      # (epochs, I) bool — attacker live this epoch
    malicious: np.ndarray   # (I,) bool — the compromised set
    dirs: np.ndarray        # (I, K) float32 — premultiplied shill content
    config: AttackConfig

    @property
    def n_epochs(self) -> int:
        return int(self.active.shape[0])

    @property
    def n_users(self) -> int:
        return int(self.active.shape[1])

    @property
    def n_malicious(self) -> int:
        return int(self.malicious.sum())

    def is_trivial(self) -> bool:
        return not bool(self.active.any())

    def epoch_row_attack(self, t: int, ui: np.ndarray, vj: np.ndarray,
                         sender_on: np.ndarray | None = None):
        """Fixed-shape per-row corruption arrays for epoch ``t`` of a
        sampled sender stream ``ui`` (any shape; ``vj`` matches):

        * ``amul``  — multiplicative corruption of the outgoing message
          (1 = honest; λ / −1 / NaN / Inf per family). Rows whose sender
          is offline (``sender_on=0``) are forced back to 1: an absent
          learner releases nothing, and 0·NaN would still poison.
        * ``ashill`` — 1 where the row's message is REPLACED by the
          sender's premultiplied shill direction (``AttackPlan.dirs``);
        * ``vj_msg`` — the message's item addressing: ``target_item`` for
          shill rows, the honest ``vj`` otherwise.
        """
        assert 0 <= t < self.n_epochs, (t, self.n_epochs)
        ui = np.asarray(ui)
        safe = np.minimum(ui, self.n_users - 1)    # padded routed slots
        mal = self.active[t][safe] & (ui < self.n_users)
        if sender_on is not None:
            mal = mal & np.asarray(sender_on).astype(bool)
        fam = self.config.family
        amul = np.ones(ui.shape, np.float32)
        if fam == "norm_inflate":
            amul[mal] = np.float32(self.config.scale)
        elif fam == "sign_flip":
            amul[mal] = -1.0
        elif fam == "nan":
            amul[mal] = np.nan
        elif fam == "inf":
            amul[mal] = np.inf
        shill = mal & (fam == "shill")
        vjm = np.where(shill, self.config.target_item, vj).astype(np.int32)
        return amul, shill.astype(np.float32), vjm


def no_attack(n_users: int, epochs: int, dim: int) -> AttackPlan:
    """The trivial plan: nobody malicious — `fit` normalizes it to None."""
    return AttackConfig().compile(n_users, epochs, dim)


@dataclasses.dataclass(frozen=True)
class DefenseConfig:
    """Receiver-side defense switches. Hashable (a static jit argument):
    the compiled epoch specializes on it. ``active == False`` (the default)
    means the epoch never enters the byzantine code path at all."""

    screen: bool = False            # finite-check + norm-cap gate
    norm_cap: float = float("inf")  # τ; inf ⇒ finite-check only
    aggregation: str = "sum"        # sum | trim | median
    trim_frac: float = 0.2          # per-side trim fraction (trim mode)

    def __post_init__(self):
        assert self.aggregation in AGGREGATIONS, self.aggregation
        assert 0.0 <= self.trim_frac < 0.5, self.trim_frac
        assert self.norm_cap > 0.0, self.norm_cap

    @property
    def active(self) -> bool:
        return self.screen or self.aggregation != "sum"


# ---------------------------------------------------------------------------
# Device-side pieces (pure jnp; imported lazily by core/dmf and sharding/dmf)
# ---------------------------------------------------------------------------
def corrupt_messages(gp: jnp.ndarray, amul: jnp.ndarray, ashill: jnp.ndarray,
                     shill_msg: jnp.ndarray) -> jnp.ndarray:
    """Apply the compiled per-row corruption at the sender boundary:
    ``gp (B,K)`` honest released messages, ``amul/ashill (B,)``,
    ``shill_msg (B,K)`` the rows' premultiplied shill content."""
    out = gp * amul[:, None]
    return jnp.where(ashill[:, None] > 0, shill_msg, out)


def screen_ok(gp: jnp.ndarray, norm_cap: float) -> jnp.ndarray:
    """Per-message accept mask (float 0/1): every coordinate finite AND
    ‖m‖₂ ≤ τ. NaN compares false, so bombs fail both gates. ``gp`` is
    (..., K); the mask drops the last axis."""
    ok = jnp.all(jnp.isfinite(gp), axis=-1)
    if math.isfinite(norm_cap):
        nrm2 = jnp.sum(gp * gp, axis=-1)
        ok = ok & (nrm2 <= jnp.float32(norm_cap) ** 2)
    return ok.astype(gp.dtype)


def _sort_cols(vs: jnp.ndarray) -> jnp.ndarray:
    """Ascending sort along axis 1 via an odd-even transposition network.

    ``cap`` is a small static width (multiple of 4, typically 4-8), so the
    network unrolls to cap rounds of elementwise min/max that XLA fuses
    into the surrounding scan body — an order of magnitude cheaper inside
    the epoch loop than `jnp.sort`'s general comparator sort, which
    dominated the robust-aggregation epoch on the CPU backend.
    """
    cap = vs.shape[1]
    cols = [vs[:, i] for i in range(cap)]
    for r in range(cap):
        for i in range(r % 2, cap - 1, 2):
            a, b = cols[i], cols[i + 1]
            cols[i] = jnp.minimum(a, b)
            cols[i + 1] = jnp.maximum(a, b)
    return jnp.stack(cols, axis=1)


def robust_combine(vals: jnp.ndarray, validity: jnp.ndarray,
                   bucket_id: jnp.ndarray, pos: jnp.ndarray,
                   n_buckets: int, cap: int,
                   defense: DefenseConfig) -> jnp.ndarray:
    """Coordinate-wise robust combine over fixed-shape message buckets.

    ``vals (M, K)`` weighted screened messages, ``validity (M,)`` 0/1,
    ``bucket_id (M,)`` in [0, n_buckets] (n_buckets = overflow row for
    host-invalid slots, which carry value 0), ``pos (M,) < cap`` unique
    within a bucket by construction (`group_messages`). Returns the
    (n_buckets, K) combined per-bucket updates:

        c · trimmed_mean(values)   (aggregation="trim")
        c · median(values)         (aggregation="median")

    scaled by the valid count c so magnitudes stay sum-comparable — with
    no outliers and no trimming pressure the combine equals plain
    summation up to float reassociation. Invalid slots sort to +inf and
    are excluded by the count-derived keep window; empty buckets combine
    to exactly 0. Sorting each coordinate makes the reduction order
    canonical, so the result is invariant to which shard delivered which
    message.
    """
    K = vals.shape[-1]
    # one fused scatter for values + validity (scatters serialize on the
    # CPU backend — two halves the epoch's robust-path scatter count)
    aug = jnp.concatenate([vals, validity[:, None]], axis=-1)
    buf_aug = jnp.zeros((n_buckets + 1, cap, K + 1), vals.dtype)
    buf_aug = buf_aug.at[bucket_id, pos].add(aug)
    buf, m = buf_aug[..., :K], buf_aug[..., K]
    c = jnp.sum(m, axis=1)                                   # (NB+1,)
    ci = c.astype(jnp.int32)[:, None]
    vs = jnp.where(m[..., None] > 0, buf, jnp.inf)
    vs = _sort_cols(vs)                                      # (NB+1, cap, K)
    if defense.aggregation == "trim":
        k = jnp.floor(defense.trim_frac * c).astype(jnp.int32)[:, None]
        p = jnp.arange(cap)[None, :]
        keep = (p >= k) & (p < ci - k)
        s = jnp.sum(jnp.where(keep[..., None], vs, 0.0), axis=1)
        denom = jnp.maximum(ci - 2 * k, 1).astype(vals.dtype)
        comb = c[:, None] * s / denom
    else:  # median
        lo = jnp.clip((ci[:, 0] - 1) // 2, 0, cap - 1)[:, None, None]
        hi = jnp.clip(ci[:, 0] // 2, 0, cap - 1)[:, None, None]
        vlo = jnp.take_along_axis(vs, jnp.broadcast_to(
            lo, (vs.shape[0], 1, K)), axis=1)[:, 0]
        vhi = jnp.take_along_axis(vs, jnp.broadcast_to(
            hi, (vs.shape[0], 1, K)), axis=1)[:, 0]
        comb = c[:, None] * 0.5 * (vlo + vhi)
    comb = jnp.where(c[:, None] > 0, comb, 0.0)
    return comb[:n_buckets]


# ---------------------------------------------------------------------------
# Host-side bucket assignment (the sampled stream and graph tables are
# host-known, so group membership compiles ahead of the dispatch — the
# device only scatters into the precomputed fixed-shape buffer).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MessageGroups:
    """Per-epoch bucket assignment: ``bucket_id``/``pos`` address each
    candidate message slot into a (groups, NBK(+1 overflow), cap) buffer;
    ``recv``/``item`` are each bucket's scatter target."""

    bucket_id: np.ndarray   # (..., slots) int32 in [0, NBK]
    pos: np.ndarray         # (..., slots) int32 < cap
    recv: np.ndarray        # (..., NBK) int32 receiver rows
    item: np.ndarray        # (..., NBK) int32 item ids
    cap: int                # max messages per bucket (padded)

    @property
    def n_buckets(self) -> int:
        return int(self.recv.shape[-1])


def _round_up(x: int, m: int) -> int:
    return -(-max(x, 1) // m) * m


def _cumcount(inv: np.ndarray, n_groups: int):
    """Stable position of each element within its group + group sizes."""
    order = np.argsort(inv, kind="stable")
    counts = np.bincount(inv, minlength=n_groups)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.empty(inv.size, np.int64)
    pos[order] = np.arange(inv.size) - starts[inv[order]]
    return pos, counts


def _assign_buckets(grp, recv, item, valid, n_groups, n_rows, n_items,
                    cap_multiple=4, bucket_multiple=64):
    """Shared bucket assignment: flat slot arrays keyed by
    (group, receiver, item). Returns (bid, pos, brecv, bitem, cap) with
    NBK/cap rounded up to stable multiples (rarely recompiles)."""
    grp = np.asarray(grp).reshape(-1)
    recv = np.asarray(recv).reshape(-1)
    item = np.asarray(item).reshape(-1)
    valid = np.asarray(valid).reshape(-1).astype(bool)
    key = (grp.astype(np.int64) * n_rows + recv) * n_items + item
    flat = np.where(valid, key, -1)
    uniq, inv = np.unique(flat, return_inverse=True)
    pos, counts = _cumcount(inv, len(uniq))
    vmask = uniq >= 0
    ubatch = np.where(vmask, uniq // (np.int64(n_rows) * n_items), -1)
    # uniq is sorted and keys are group-major, so groups are contiguous
    start = np.searchsorted(ubatch, np.arange(n_groups))
    bucket_of_uniq = np.arange(len(uniq)) - start[np.maximum(ubatch, 0)]
    if vmask.any():
        nbk = int(np.bincount(ubatch[vmask], minlength=n_groups).max())
        cap = int(counts[vmask].max())
    else:
        nbk, cap = 1, 1
    NBK = _round_up(nbk, bucket_multiple)
    cap = _round_up(cap, cap_multiple)
    bid = np.where(valid, bucket_of_uniq[inv], NBK).astype(np.int32)
    p = np.where(valid, pos, 0).astype(np.int32)
    brecv = np.zeros((n_groups, NBK), np.int32)
    bitem = np.zeros((n_groups, NBK), np.int32)
    brecv[ubatch[vmask], bucket_of_uniq[vmask]] = (
        (uniq[vmask] // n_items) % n_rows).astype(np.int32)
    bitem[ubatch[vmask], bucket_of_uniq[vmask]] = (
        uniq[vmask] % n_items).astype(np.int32)
    return bid, p, brecv, bitem, cap


def group_messages(ui, vj_msg, nbr_idx, nbr_wgt, n_items,
                   sender_gate=None, recv_on=None) -> MessageGroups:
    """Single-device bucket assignment for one epoch's (nb, B) stream.

    A candidate slot is each (row, neighbor-table slot) pair; slots that
    cannot carry a message THIS epoch (padded weight-0 slots, the sender's
    own line-11 self slot, gated senders — offline or straggling — and
    offline receivers) go to the overflow bucket with value 0. Device-side
    screening later zeroes a slot's validity without moving it.
    """
    nbr_idx = np.asarray(nbr_idx)
    nbr_wgt = np.asarray(nbr_wgt)
    ui = np.asarray(ui)
    nb, B = ui.shape
    I, S = nbr_idx.shape
    recv = nbr_idx[ui]                           # (nb, B, S)
    w = nbr_wgt[ui]
    valid = (w > 0) & (recv != ui[..., None])
    if sender_gate is not None:
        valid &= np.asarray(sender_gate).astype(bool)[..., None]
    if recv_on is not None:
        valid &= np.asarray(recv_on).astype(bool)[recv]
    grp = np.broadcast_to(np.arange(nb)[:, None, None], recv.shape)
    item = np.broadcast_to(np.asarray(vj_msg)[..., None], recv.shape)
    bid, pos, brecv, bitem, cap = _assign_buckets(
        grp, recv, item, valid, nb, I, int(n_items))
    return MessageGroups(
        bucket_id=bid.reshape(nb, B, S), pos=pos.reshape(nb, B, S),
        recv=brecv, item=bitem, cap=cap)


def group_messages_sharded(ui_local, vj_msg, valid_rows, part_idx, part_wgt,
                           rows: int, n_shards: int, n_items: int,
                           prop_now=None, online=None) -> MessageGroups:
    """Bucket assignment per (batch, destination shard) for the sharded
    epoch: enumerates the post-`all_to_all` incoming slots of every shard
    in their exact received order — (source shard, routed row, table slot)
    — so the device indexes line up with the flattened (D, Bs, S) tensors.

    ``ui_local (nb, D, Bs)`` routed local sender rows, ``vj_msg`` routed
    message items, ``valid_rows`` routed row validity (padding AND offline
    senders), ``part_idx/part_wgt (I_pad, D, S)`` the destination-
    partitioned table, ``online (I_pad,)`` the receivers' global mask.
    Receiver ids in the result are SHARD-LOCAL rows (what the local
    scatter needs).
    """
    pidx = np.asarray(part_idx)
    pwgt = np.asarray(part_wgt)
    ui_local = np.asarray(ui_local)
    nb, D, Bs = ui_local.shape
    S = pidx.shape[2]
    g = np.arange(D)[None, :, None] * rows + ui_local       # global senders
    w = pwgt[g]                                             # (nb,Dsrc,Bs,Ddst,S)
    ri = pidx[g]
    dest = np.arange(D)[None, None, None, :, None]
    grecv = dest * rows + ri
    valid = (w > 0) & (grecv != g[..., None, None])
    valid &= np.asarray(valid_rows).astype(bool)[..., None, None]
    if prop_now is not None:
        valid &= np.asarray(prop_now).astype(bool)[..., None, None]
    if online is not None:
        valid &= np.asarray(online).astype(bool)[grecv]
    item = np.broadcast_to(
        np.asarray(vj_msg)[..., None, None], ri.shape)
    # (nb, Dsrc, Bs, Ddst, S) -> (nb, Ddst, Dsrc, Bs, S): received order
    ri_t = np.moveaxis(ri, 3, 1)
    val_t = np.moveaxis(valid, 3, 1)
    item_t = np.moveaxis(item, 3, 1)
    grp = (np.arange(nb)[:, None] * D + np.arange(D)[None, :])
    grp = np.broadcast_to(grp[:, :, None, None, None], ri_t.shape)
    bid, pos, brecv, bitem, cap = _assign_buckets(
        grp, ri_t, item_t, val_t, nb * D, rows, int(n_items))
    M = D * Bs * S
    return MessageGroups(
        bucket_id=bid.reshape(nb, D, M), pos=pos.reshape(nb, D, M),
        recv=brecv.reshape(nb, D, -1), item=bitem.reshape(nb, D, -1),
        cap=cap)
