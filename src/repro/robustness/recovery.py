"""Crash-consistent training checkpoints for `dmf.fit`.

A resumable snapshot needs more than the factors: bit-identical
resume-after-crash requires the FULL loop state —

* the `DMFState` factors (saved UNPADDED: the sharded epochs re-pad and
  re-place rows every epoch, so an unpadded snapshot restores onto any
  mesh width, and the padded rows are provably zero anyway);
* the numpy `Generator` stream (`bit_generator.state` is a plain JSON
  dict), so every later epoch re-samples the same minibatches, negatives,
  and per-epoch DP seeds;
* the `DelayRing` of in-flight stale messages, so stragglers' buffered
  gradients still land on their due epoch;
* the `GaussianAccountant` ledger, so ε keeps composing from the realized
  participation observed before the crash.

Given those, every epoch function is a pure function of (state, sampled
stream), and the DP noise is counter-keyed by (epoch seed, row id) rather
than by an ambient rng — so replaying from a snapshot reproduces the
uninterrupted run bit-for-bit (tests/test_robustness.py pins it, DP and
churn on, single-device and sharded).

Layout: ``<root>/step_<t>/`` with the arrays in the `checkpoint.ckpt`
manifest format plus a ``training_state.json`` sidecar for the scalars
(step, rng state, loss history, accountant counters).
"""
from __future__ import annotations

import json
import pathlib

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt

SIDECAR = "training_state.json"


def _array_tree(state, ring, accountant):
    tree = {"state": {"U": state.U, "P": state.P, "Q": state.Q}}
    if ring is not None:
        tree["ring"] = {"gp": ring.gp, "ui": ring.ui, "vj": ring.vj,
                        "due": ring.due}
    if accountant is not None:
        tree["accountant"] = {"rdp": accountant._rdp,
                              "messages": accountant.messages}
    return tree


def save_training(root, step: int, state, rng: np.random.Generator,
                  ring=None, accountant=None, train_losses=(),
                  test_losses=()) -> pathlib.Path:
    """Snapshot the full training loop after ``step`` completed epochs.
    ``state`` must be unpadded (global learner axis) — `dmf.fit` unpads
    sharded state before calling."""
    path = pathlib.Path(root) / f"step_{step}"
    ckpt.save(path, _array_tree(state, ring, accountant), step=step)
    meta = {
        "step": int(step),
        "rng_state": rng.bit_generator.state,
        "train_losses": [float(x) for x in train_losses],
        "test_losses": [float(x) for x in test_losses],
        "has_ring": ring is not None,
        "accountant": None if accountant is None else {
            "epochs": int(accountant.epochs),
            "eps_trajectory": [float(e) for e in accountant.eps_trajectory],
        },
    }
    (path / SIDECAR).write_text(json.dumps(meta, indent=1))
    return path


def resolve_step_dir(path) -> pathlib.Path:
    """Accept either a ``step_<t>`` directory or a checkpoint root.

    Given a root, picks the latest step whose leaves VERIFY against their
    manifest sha256s (checkpoint/ckpt.py): a torn or bit-rotted latest
    snapshot is skipped with a warning and resume falls back to the newest
    intact one — a crash mid-`save_training` must not brick the run it
    exists to protect. An explicitly named step dir is returned as-is
    (restore will raise `CorruptCheckpointError` if it is bad — an
    explicit ask should fail loudly, not silently resolve elsewhere)."""
    path = pathlib.Path(path)
    if (path / SIDECAR).exists():
        return path
    steps = ckpt.steps(path)
    if not steps:
        raise FileNotFoundError(f"no training checkpoints under {path}")
    for step in reversed(steps):
        cand = path / f"step_{step}"
        if ckpt.verify(cand) and (cand / SIDECAR).exists():
            if step != steps[-1]:
                import warnings
                warnings.warn(
                    f"checkpoint step_{steps[-1]} under {path} is corrupted"
                    f" or incomplete — falling back to step_{step}",
                    RuntimeWarning, stacklevel=2)
            return cand
    raise ckpt.CorruptCheckpointError(
        f"every checkpoint under {path} fails integrity verification")


def load_training(path, like_state, ring=None, accountant=None):
    """Restore a `save_training` snapshot.

    ``like_state``/``ring``/``accountant`` provide the restore shapes (and,
    for ring/accountant, the objects mutated in place — pass the same
    freshly-constructed objects `fit` would otherwise start from).
    Returns ``(state, rng, ring, step, train_losses, test_losses)``.
    """
    from repro.core import dmf as dmf_lib

    path = resolve_step_dir(path)
    meta = json.loads((path / SIDECAR).read_text())
    if meta["has_ring"] != (ring is not None):
        raise ValueError(
            f"checkpoint at {path} was written with has_ring="
            f"{meta['has_ring']} but resume constructed ring={ring}")
    out = ckpt.restore(path, _array_tree(like_state, ring, accountant))
    state = dmf_lib.DMFState(
        U=jnp.asarray(out["state"]["U"]),
        P=jnp.asarray(out["state"]["P"]),
        Q=jnp.asarray(out["state"]["Q"]),
    )
    if ring is not None:
        ring.gp = jnp.asarray(out["ring"]["gp"])
        ring.ui = np.asarray(out["ring"]["ui"])
        ring.vj = np.asarray(out["ring"]["vj"])
        ring.due = np.asarray(out["ring"]["due"])
    if accountant is not None:
        acc = meta["accountant"]
        assert acc is not None, "checkpoint has no accountant ledger"
        accountant._rdp[:] = np.asarray(out["accountant"]["rdp"])
        accountant.messages[:] = np.asarray(out["accountant"]["messages"])
        accountant.epochs = int(acc["epochs"])
        accountant.eps_trajectory = [float(e) for e in acc["eps_trajectory"]]
    rng = np.random.default_rng()
    rng.bit_generator.state = meta["rng_state"]
    return (state, rng, ring, int(meta["step"]),
            list(meta["train_losses"]), list(meta["test_losses"]))
