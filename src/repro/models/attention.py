"""Attention: GQA and MLA, train/prefill (blockwise flash-style) and decode
(including sequence-sharded KV caches for 32k-512k contexts).

Sharding contract (see sharding/rules.py):
* q/k/v head dims carry logical axis "heads" / "kv_heads";
* decode KV caches carry logical axis "seq_kv" on the sequence dim — resolved
  to the *model* axis for decode_32k (batch already fills the data axis) and
  to ("data","model") for long_500k (batch=1); the partial-softmax combine
  over cache shards is a log-sum-exp `psum` (ring-free, one small collective
  per layer), implemented in `sharded_decode_attend` via shard_map by the
  caller (launch/serve.py) or left to XLA SPMD when the cache is replicated.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure JAX, O(S·chunk) memory.
# ---------------------------------------------------------------------------
def blockwise_attention(
    q: jnp.ndarray,        # (B, Sq, H, hd)
    k: jnp.ndarray,        # (B, Sk, KV, hd)
    v: jnp.ndarray,        # (B, Sk, KV, vd)
    *,
    causal: bool = True,
    q_offset: int = 0,     # absolute position of q[0] (prefill continuation)
    q_chunk: int = 1024,
    kv_chunk: int = 2048,
    softmax_scale: float | None = None,
    triangular: bool = False,
    window: int = 0,       # >0: sliding-window (band) causal attention
) -> jnp.ndarray:
    """Nested q×kv chunked attention with online softmax: the (Sq, Sk) score
    matrix is never materialized beyond a (q_chunk, kv_chunk) tile.

    Baseline schedule scans *all* kv chunks for every q chunk (fully-masked
    causal tiles are computed then masked — ~2x attention-FLOPs waste; the
    triangular schedule is a §Perf hillclimb, see EXPERIMENTS.md)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    if (triangular and causal and not window and Sq == Sk and q_offset == 0
            and Sq % max(q_chunk, 1) == 0 and Sq > q_chunk):
        return triangular_attention(q, k, v, q_chunk=q_chunk,
                                    softmax_scale=softmax_scale)
    vd = v.shape[-1]
    G = H // KV
    scale = softmax_scale or 1.0 / math.sqrt(hd)
    qf = (q * scale).reshape(B, Sq, KV, G, hd).astype(jnp.float32)

    if Sk <= kv_chunk and Sq <= q_chunk:
        return _dense_attend(qf, k, v, causal, q_offset, window).reshape(B, Sq, H, vd).astype(q.dtype)

    # pad Sq to a multiple of q_chunk (cheap; cross-attn with ragged Sq)
    pad_q = (-Sq) % q_chunk
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    Sqp = qf.shape[1]
    nq = Sqp // q_chunk
    assert Sk % kv_chunk == 0, f"Sk={Sk} not divisible by kv_chunk={kv_chunk}"
    nk = Sk // kv_chunk

    qc = qf.reshape(B, nq, q_chunk, KV, G, hd).swapaxes(0, 1)   # (nq,B,qc,KV,G,hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd).swapaxes(0, 1)
    vc = v.reshape(B, nk, kv_chunk, KV, vd).swapaxes(0, 1)

    def q_body(qstart, qb):
        qpos = q_offset + qstart + jnp.arange(q_chunk)

        def kv_body(carry, xs):
            acc, m, l, kstart = carry
            kb, vb = xs
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb.astype(jnp.float32))
            if causal:
                kvpos = kstart + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kvpos[None, :]
                if window:
                    mask &= (qpos[:, None] - kvpos[None, :]) < window
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskv->bkgqv", p, vb.astype(jnp.float32)
            )
            return (acc, m_new, l, kstart + kv_chunk), None

        acc0 = jnp.zeros((B, KV, G, q_chunk, vd), jnp.float32)
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        (acc, m, l, _), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0, jnp.zeros((), jnp.int32)), (kc, vc)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)       # (B,KV,G,qc,vd)
        return qstart + q_chunk, out.transpose(0, 3, 1, 2, 4)  # (B,qc,KV,G,vd)

    _, outs = jax.lax.scan(q_body, jnp.zeros((), jnp.int32), qc)
    out = outs.swapaxes(0, 1).reshape(B, Sqp, H, vd)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def triangular_attention(
    q: jnp.ndarray,        # (B, S, H, hd)   self-attention, Sq == Sk
    k: jnp.ndarray,        # (B, S, KV, hd)
    v: jnp.ndarray,        # (B, S, KV, vd)
    *,
    q_chunk: int = 2048,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    """§Perf hillclimb: causal attention that only computes the lower
    triangle — an unrolled Python loop over q chunks, where chunk i attends
    kv[: (i+1)·qc] (static slice). Halves attention FLOPs vs the baseline
    blockwise schedule (which computes then masks the upper triangle) at the
    cost of nq einsum instances in the HLO (nq = S/q_chunk, kept small)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    vd = v.shape[-1]
    G = H // KV
    scale = softmax_scale or 1.0 / math.sqrt(hd)
    qf = (q * scale).reshape(B, S, KV, G, hd).astype(jnp.float32)
    assert S % q_chunk == 0, (S, q_chunk)
    nq = S // q_chunk
    outs = []
    for i in range(nq):
        qb = qf[:, i * q_chunk : (i + 1) * q_chunk]
        end = (i + 1) * q_chunk
        kb, vb = k[:, :end], v[:, :end]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb.astype(jnp.float32))
        qpos = i * q_chunk + jnp.arange(q_chunk)
        mask = qpos[:, None] >= jnp.arange(end)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskv->bkgqv", p, vb.astype(jnp.float32))
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, vd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _dense_attend(qf, k, v, causal, q_offset, window: int = 0):
    # qf: (B,Sq,KV,G,hd) pre-scaled f32
    B, Sq, KV, G, hd = qf.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        if window:
            mask &= (qpos[:, None] - jnp.arange(Sk)[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskv->bkgqv", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4)  # (B,Sq,KV,G,vd)


def decode_attend(
    q: jnp.ndarray,          # (B, H, hd) — single new token
    cache_k: jnp.ndarray,    # (B, S, KV, hd)
    cache_v: jnp.ndarray,    # (B, S, KV, vd)
    length: jnp.ndarray,     # () int — valid prefix length (== pos of new token + 1)
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    """One-token attention against a (possibly seq-sharded) KV cache.

    When the cache's sequence dim is sharded, XLA SPMD evaluates the einsums
    shard-locally and the softmax normalization induces the cross-shard
    reduction; the masked positions contribute exp(NEG_INF)=0.
    """
    B, S, KV, hd = cache_k.shape
    H = q.shape[1]
    G = H // KV
    scale = softmax_scale or 1.0 / math.sqrt(hd)
    qf = (q * scale).reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, cache_k.astype(jnp.float32))
    mask = jnp.arange(S)[None, None, None, :] < length
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskv->bkgv", p, cache_v.astype(jnp.float32))
    return out.reshape(B, H, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA projections
# ---------------------------------------------------------------------------
def init_gqa(key, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s, so = 0.02, 0.02 / math.sqrt(2 * cfg.n_layers)
    params = {
        "wq": jax.random.normal(ks[0], (d, H, hd)) * s,
        "wk": jax.random.normal(ks[1], (d, KV, hd)) * s,
        "wv": jax.random.normal(ks[2], (d, KV, hd)) * s,
        "wo": jax.random.normal(ks[3], (H, hd, d)) * so,
    }
    specs = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qkv_bias:  # Qwen1.5
        params |= {
            "bq": jnp.zeros((H, hd)),
            "bk": jnp.zeros((KV, hd)),
            "bv": jnp.zeros((KV, hd)),
        }
        specs |= {
            "bq": ("heads", None),
            "bk": ("kv_heads", None),
            "bv": ("kv_heads", None),
        }
    return params, specs


def gqa_qkv(params, x, positions, cfg: ModelConfig, dtype):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_out(params, o, dtype):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).
# KV is compressed to a kv_lora_rank latent c_kv plus a shared decoupled
# RoPE key; the *cache stores only (c_kv, k_rope)* — the paper-family's
# memory saving. Decode uses the absorbed form (attention in latent space).
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    hd, vd = cfg.head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    s, so = 0.02, 0.02 / math.sqrt(2 * cfg.n_layers)
    params = {
        # queries: per-head nope + rope parts
        "wq": jax.random.normal(ks[0], (d, H, hd)) * s,
        "wq_rope": jax.random.normal(ks[1], (d, H, rd)) * s,
        # compressed kv path
        "w_dkv": jax.random.normal(ks[2], (d, r)) * s,          # down
        "w_kr": jax.random.normal(ks[3], (d, rd)) * s,          # shared rope key
        "kv_norm": jnp.ones((r,)),
        "w_uk": jax.random.normal(ks[4], (r, H, hd)) * s,       # up: keys
        "w_uv": jax.random.normal(ks[5], (r, H, vd)) * s,       # up: values
        "wo": jax.random.normal(ks[6], (H, vd, d)) * so,
    }
    specs = {
        "wq": ("embed", "heads", None),
        "wq_rope": ("embed", "heads", None),
        "w_dkv": ("embed", None),
        "w_kr": ("embed", None),
        "kv_norm": (None,),
        "w_uk": (None, "heads", None),
        "w_uv": (None, "heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.q_lora_rank:
        params |= {
            "w_dq": jax.random.normal(ks[7], (d, cfg.q_lora_rank)) * s,
            "q_norm": jnp.ones((cfg.q_lora_rank,)),
        }
        specs |= {"w_dq": ("embed", None), "q_norm": (None,)}
        params["wq"] = jax.random.normal(ks[0], (cfg.q_lora_rank, H, hd)) * s
        params["wq_rope"] = jax.random.normal(ks[1], (cfg.q_lora_rank, H, rd)) * s
        specs["wq"] = (None, "heads", None)
        specs["wq_rope"] = (None, "heads", None)
    return params, specs


def mla_compress(params, x, positions, cfg: ModelConfig, dtype):
    """x -> (c_kv normed, k_rope) — exactly what the MLA cache stores."""
    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dtype))
    c_kv = layers.rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    k_r = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(dtype))
    k_r = layers.apply_rope(k_r[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_r


def mla_queries(params, x, positions, cfg: ModelConfig, dtype):
    if cfg.q_lora_rank:
        xq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(dtype))
        xq = layers.rms_norm(xq, params["q_norm"], cfg.norm_eps)
    else:
        xq = x
    q = jnp.einsum("bsr,rhk->bshk", xq, params["wq"].astype(dtype))
    q_r = jnp.einsum("bsr,rhk->bshk", xq, params["wq_rope"].astype(dtype))
    q_r = layers.apply_rope(q_r, positions, cfg.rope_theta)
    return q, q_r


def mla_attend_full(params, x, positions, cfg: ModelConfig, dtype, kv_chunk: int):
    """Training/prefill MLA: expand keys/values per head from the latent."""
    q, q_r = mla_queries(params, x, positions, cfg, dtype)
    c_kv, k_r = mla_compress(params, x, positions, cfg, dtype)
    k = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"].astype(dtype))
    v = jnp.einsum("bsr,rhv->bshv", c_kv, params["w_uv"].astype(dtype))
    H = cfg.n_heads
    k_full = jnp.concatenate([k, jnp.broadcast_to(k_r[:, :, None, :], q_r.shape)], -1)
    q_full = jnp.concatenate([q, q_r], -1)
    scale = 1.0 / math.sqrt(cfg.head_dim + cfg.rope_head_dim)
    o = blockwise_attention(
        q_full, k_full, v, causal=True, kv_chunk=kv_chunk, softmax_scale=scale,
        triangular=cfg.triangular_attention,
    )
    out = jnp.einsum("bshv,hvd->bsd", o, params["wo"].astype(dtype))
    return out, (c_kv, k_r)


def mla_decode(params, x, cache_ckv, cache_kr, length, positions, cfg: ModelConfig, dtype):
    """Absorbed-form single-token MLA decode against the latent cache.

    q_abs[h] = q[h] @ W_uk[h]^T lives in latent space: scores are
    q_abs·c_kv + q_rope·k_rope; output o = (p·c_kv) @ W_uv — per-token cost
    O(H·(hd·r) + S·(r+rd)) with only the (r+rd)-wide cache in memory.
    """
    q, q_r = mla_queries(params, x, positions, cfg, dtype)  # (B,1,H,*)
    q, q_r = q[:, 0], q_r[:, 0]                             # (B,H,*)
    q_abs = jnp.einsum("bhk,rhk->bhr", q, params["w_uk"].astype(dtype))
    scale = 1.0 / math.sqrt(cfg.head_dim + cfg.rope_head_dim)
    s = jnp.einsum("bhr,bsr->bhs", q_abs.astype(jnp.float32), cache_ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhk,bsk->bhs", q_r.astype(jnp.float32), cache_kr.astype(jnp.float32))
    s = s * scale
    S = cache_ckv.shape[1]
    mask = jnp.arange(S)[None, None, :] < length
    p = jax.nn.softmax(jnp.where(mask, s, NEG_INF), axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, cache_ckv.astype(jnp.float32)).astype(dtype)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, params["w_uv"].astype(dtype))
    return jnp.einsum("bhv,hvd->bd", o, params["wo"].astype(dtype))[:, None, :]


# ---------------------------------------------------------------------------
# Cross-attention (Llama-3.2-Vision style image layers)
# ---------------------------------------------------------------------------
def init_cross_attn(key, cfg: ModelConfig):
    params, specs = init_gqa(key, cfg)
    params["gate"] = jnp.zeros(())   # tanh-gated residual, zero-init
    specs["gate"] = ()
    return params, specs


def cross_attend(params, x, media: jnp.ndarray, cfg: ModelConfig, dtype):
    """x: (B,S,D) text; media: (B,M,D) precomputed patch embeddings (stub
    frontend per DESIGN.md). No RoPE; no causal mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bmd,dhk->bmhk", media, params["wk"].astype(dtype))
    v = jnp.einsum("bmd,dhk->bmhk", media, params["wv"].astype(dtype))
    o = blockwise_attention(q, k, v, causal=False, kv_chunk=max(k.shape[1], 16))
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dtype))
    return jnp.tanh(params["gate"]).astype(dtype) * out
