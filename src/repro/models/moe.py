"""Mixture-of-Experts FFN (DeepSeek-V2 / Jamba style: shared + routed top-k).

TPU-native expert parallelism (DESIGN.md §5): experts are sharded over the
``model`` mesh axis; token activations enter the block replicated over
``model`` (batch-sharded over ``data``), so device (d, m) already holds all
of data-shard d's tokens *and* expert-shard m's experts — **no all-to-all is
needed**: each device computes the routes that land on its own experts and
the partial outputs are combined by the block's existing tensor-parallel
``psum``. Routes are grouped with a capacity-bounded sort + per-expert
``dynamic_slice`` (static shapes; overflow drops, standard capacity
semantics).

Two code paths with identical math:
* ``moe_ffn_local``   — single-device (smoke tests, and the oracle in tests)
* ``moe_ffn_sharded`` — shard_map over the ``model`` axis (dry-run/cluster)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def init_moe(key, cfg: ModelConfig):
    d, E, F = cfg.d_model, cfg.n_routed_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    s, so = 0.02, 0.02 / math.sqrt(2 * cfg.n_layers)
    params = {
        "router": jax.random.normal(ks[0], (d, E)) * s,
        "wi": jax.random.normal(ks[1], (E, d, F)) * s,
        "wg": jax.random.normal(ks[2], (E, d, F)) * s,
        "wo": jax.random.normal(ks[3], (E, F, d)) * so,
    }
    specs = {
        "router": ("embed_nodiv", None),
        "wi": ("experts", "embed", "expert_ff"),
        "wg": ("experts", "embed", "expert_ff"),
        "wo": ("experts", "expert_ff", "embed"),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        params |= {
            "shared_wi": jax.random.normal(ks[4], (d, Fs)) * s,
            "shared_wg": jax.random.normal(ks[5], (d, Fs)) * s,
            "shared_wo": jax.random.normal(ks[4], (Fs, d)) * so,
        }
        specs |= {
            "shared_wi": ("embed", "ff"),
            "shared_wg": ("embed", "ff"),
            "shared_wo": ("ff", "embed"),
        }
    return params, specs


def _route(params, x2d: jnp.ndarray, cfg: ModelConfig):
    """Router: softmax-then-topk (DeepSeek-V2). Returns (weights (T,k),
    expert ids (T,k), aux load-balance loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    E = cfg.n_routed_experts
    me = probs.mean(0)                                      # mean router prob
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (
        x2d.shape[0] * cfg.moe_top_k
    )
    aux = E * jnp.sum(me * ce)
    return w, idx, aux


def _grouped_expert_ffn(
    params_wi, params_wg, params_wo,   # (E_loc, d, F), (E_loc, F, d)
    x2d: jnp.ndarray,                  # (T, d) tokens (local)
    w: jnp.ndarray,                    # (T, k) combine weights
    idx: jnp.ndarray,                  # (T, k) global expert ids
    first_expert: jnp.ndarray,         # () id of params_wi[0]
    capacity: int,
    dtype,
) -> jnp.ndarray:
    """Capacity-bounded sorted dispatch for the E_loc experts in params.

    Sort all (token, choice) routes by expert id; for each local expert,
    dynamic-slice a capacity-sized window starting at its first route
    (searchsorted), mask entries belonging to other experts (this implements
    both the grouping and capacity dropping), gather→FFN→scatter-add.
    """
    T, k = idx.shape
    E_loc = params_wi.shape[0]
    eid = idx.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)
    ww = w.reshape(-1)
    order = jnp.argsort(eid)
    eid_s, tok_s, w_s = eid[order], tok[order], ww[order]
    starts = jnp.searchsorted(eid_s, first_expert + jnp.arange(E_loc))

    def one_expert(y, e_i):
        st = starts[e_i]
        es = jax.lax.dynamic_slice(eid_s, (st,), (capacity,))
        ts = jax.lax.dynamic_slice(tok_s, (st,), (capacity,))
        ws = jax.lax.dynamic_slice(w_s, (st,), (capacity,))
        valid = (es == first_expert + e_i).astype(dtype)
        xs = x2d[ts] * valid[:, None]                      # (C, d)
        h = jnp.einsum("cd,df->cf", xs, params_wi[e_i].astype(dtype))
        g = jnp.einsum("cd,df->cf", xs, params_wg[e_i].astype(dtype))
        o = jnp.einsum("cf,fd->cd", jax.nn.silu(g) * h, params_wo[e_i].astype(dtype))
        y = y.at[ts].add(o * (ws.astype(dtype) * valid)[:, None])
        return y, None

    y0 = jnp.zeros_like(x2d)
    y, _ = jax.lax.scan(one_expert, y0, jnp.arange(E_loc))
    return y


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.moe_top_k / cfg.n_routed_experts * cfg.capacity_factor))
    # clamp to the total route count (tiny decode batches); at least 1 slot
    return max(1, min(c, n_tokens * cfg.moe_top_k))


def _shared_ffn(params, x, dtype):
    h = jnp.einsum("...d,df->...f", x, params["shared_wi"].astype(dtype))
    g = jnp.einsum("...d,df->...f", x, params["shared_wg"].astype(dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * h, params["shared_wo"].astype(dtype))


def moe_ffn_local(params, x: jnp.ndarray, cfg: ModelConfig, dtype):
    """Single-device path (also the test oracle). x: (B, S, d)."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    w, idx, aux = _route(params, x2d, cfg)
    cap = moe_capacity(cfg, x2d.shape[0])
    y = _grouped_expert_ffn(
        params["wi"], params["wg"], params["wo"], x2d, w, idx,
        jnp.zeros((), jnp.int32), cap, dtype,
    )
    if cfg.n_shared_experts:
        y = y + _shared_ffn(params, x2d, dtype)
    return y.reshape(B, S, d), aux


def moe_ffn_sharded(params, x: jnp.ndarray, cfg: ModelConfig, dtype, mesh,
                    weight_stationary: bool = False):
    """Expert-parallel path: shard_map over the full mesh; experts split on
    ``model``; tokens split on batch axes; no token exchange (see module
    docstring). Output psum over ``model``; aux psum-averaged over batch axes.

    ``weight_stationary=True`` (decode-time, §Perf hillclimb): expert weights
    are ADDITIONALLY sharded over the data axis on the hidden (F) dim and
    stay resident; the (tiny) token activations are all-gathered over the
    batch axes instead, and partial outputs psum over the whole mesh. This
    replaces the per-token FSDP *weight* all-gather (GBs) with an
    *activation* all-gather (MBs) — the classic move-activations-not-weights
    inference sharding."""
    B, S, d = x.shape
    E = cfg.n_routed_experts
    axes = mesh.axis_names
    model_ax = "model"
    batch_axes = tuple(a for a in axes if a != model_ax)
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if B % n_batch != 0:   # e.g. batch=1 long-context decode: replicate tokens
        batch_axes = ()
    n_model = mesh.shape[model_ax]
    assert E % n_model == 0, (E, n_model)
    E_loc = E // n_model

    routed_specs = {
        "router": P(),
        "wi": P(model_ax, None, None),
        "wg": P(model_ax, None, None),
        "wo": P(model_ax, None, None),
    }
    # ws: weights 2D-sharded (experts->model, F->all batch axes) and resident;
    # token sharding (x_axes) is independent — batch=1 long-context decode
    # keeps tokens replicated but still wants resident F-sharded weights.
    all_batch = tuple(a for a in axes if a != model_ax)
    ws_axes = all_batch if weight_stationary else ()
    if ws_axes:
        F = cfg.moe_d_ff
        n_ws = 1
        for a in ws_axes:
            n_ws *= mesh.shape[a]
        if F % n_ws != 0:
            ws_axes = ()  # divisibility fallback: plain EP
    x_axes = batch_axes  # () when B not divisible (tokens replicated)
    if ws_axes:
        routed_specs = {
            "router": P(),
            "wi": P(model_ax, None, ws_axes),
            "wg": P(model_ax, None, ws_axes),
            "wo": P(model_ax, ws_axes, None),
        }
    in_specs = (routed_specs, P(x_axes if x_axes else None, None, None))
    out_specs = (P(x_axes if x_axes else None, None, None), P())

    def body(p, xb):
        Bl, Sl, _ = xb.shape
        if ws_axes and x_axes:
            # gather the (small) token batch; weights stay put
            xb = jax.lax.all_gather(xb, x_axes, axis=0, tiled=True)
        Bg = xb.shape[0]
        x2d = xb.reshape(-1, d)
        w, idx, aux = _route(p, x2d, cfg)
        cap = moe_capacity(cfg, x2d.shape[0])
        m_idx = jax.lax.axis_index(model_ax)
        first = (m_idx * E_loc).astype(jnp.int32)
        y = _grouped_expert_ffn(
            p["wi"], p["wg"], p["wo"], x2d, w, idx, first, cap, dtype
        )
        if ws_axes:
            # partial over local F slice and local experts -> full sum
            y = jax.lax.psum(y, (model_ax, *ws_axes))
            if x_axes:  # keep this shard's batch slice
                b_idx = jax.lax.axis_index(x_axes)
                y = jax.lax.dynamic_slice_in_dim(
                    y.reshape(Bg, Sl, d), b_idx * Bl, Bl, axis=0
                ).reshape(Bl * Sl, d)
        else:
            y = jax.lax.psum(y, model_ax)
        if x_axes:
            aux = jax.lax.pmean(aux, x_axes)
        return y.reshape(Bl, Sl, d), aux

    sub = {k: params[k] for k in routed_specs}
    from repro.launch.mesh import shard_map

    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )(sub, x)
    if cfg.n_shared_experts:
        # shared experts: plain tensor-parallel FFN, outside the shard_map
        y = y + _shared_ffn(params, x, dtype)
    return y, aux
