"""Common layers: RMSNorm, RoPE, dense MLP, embeddings, chunked loss.

Parameter trees are plain dicts; every ``init_*`` returns ``(params, specs)``
where ``specs`` mirrors the tree with tuples of *logical* axis names
(resolved to mesh axes in ``repro.sharding.rules``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int):
    return jnp.ones((d,), jnp.float32), ("embed_nodiv",)


# --- RoPE -------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd) rotated pairwise; positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs           # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- dense (SwiGLU) MLP -----------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 0.02
    params = {
        "wi": jax.random.normal(k1, (d_model, d_ff), dtype) * s,
        "wg": jax.random.normal(k2, (d_model, d_ff), dtype) * s,
        "wo": jax.random.normal(k3, (d_ff, d_model), dtype) * s,
    }
    specs = {
        "wi": ("embed", "ff"),
        "wg": ("embed", "ff"),
        "wo": ("ff", "embed"),
    }
    return params, specs


def mlp(params, x: jnp.ndarray, dtype) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dtype))
    g = jnp.einsum("...d,df->...f", x, params["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dtype))


# --- embeddings / unembedding ----------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return (
        jax.random.normal(key, (vocab, d_model), dtype) * 0.02,
        ("vocab", "embed_nodiv"),
    )


def init_lm_head(key, d_model: int, vocab: int, dtype=jnp.float32):
    return (
        jax.random.normal(key, (d_model, vocab), dtype) * 0.02,
        ("embed_nodiv", "vocab"),
    )


def chunked_cross_entropy(
    h: jnp.ndarray,            # (B, S, D) final hidden states
    lm_head: jnp.ndarray,      # (D, V)
    labels: jnp.ndarray,       # (B, S) int32, -1 = ignore
    chunk: int = 1024,
) -> jnp.ndarray:
    """Mean CE, computing logits chunk-by-chunk over the sequence so the
    (B, S, V) logits tensor is never materialized (memory-roofline relevant
    for 128k-256k vocabularies)."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def one(hc, lc):
        logits = jnp.einsum("bsd,dv->bsv", hc, lm_head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        hc, lc = xs
        l, m = one(hc, lc)
        return (carry[0] + l, carry[1] + m), None

    hc = h[:, : n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
    lc = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    if rem:
        l, m = one(h[:, n * chunk :], labels[:, n * chunk :])
        tot, cnt = tot + l, cnt + m
    return tot / jnp.maximum(cnt, 1.0)
