"""Model configuration schema covering all assigned architecture families.

A model is a stack of ``n_periods`` repeated *periods*; a period is a short
list of layer descriptors (attention / mamba / cross-attention, each with an
FFN that is dense or MoE). Uniform models have a 1-layer period; Jamba uses
an 8-layer period (1 attn : 7 mamba); the vision model a 5-layer period
(1 cross : 4 self). Parameters of each period-position are stacked over
periods so the forward pass scans over periods (HLO size ~ one period).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: Literal["attn", "mamba", "cross"] = "attn"
    moe: bool = False                 # MoE FFN instead of dense FFN
    sliding_window: int = 0           # >0: sliding-window attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    # core dims
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # attention
    attn_type: Literal["gqa", "mla"] = "gqa"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # MLA (DeepSeek/MiniCPM3)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0               # 0 -> head_dim
    # MoE
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001  # load-balance loss weight
    # SSM (Mamba2 SSD)
    ssm_d_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # stacking pattern
    period: tuple[LayerSpec, ...] = (LayerSpec(),)
    # modality frontends (stubs by design — see DESIGN.md carve-out)
    n_image_tokens: int = 0           # vlm: precomputed patch embeddings
    n_codebooks: int = 0              # audio: EnCodec codebooks
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 2048            # blockwise-attention KV chunk for long seq
    loss_chunk: int = 1024            # cross-entropy chunking over tokens
    # §Perf variants (see EXPERIMENTS.md):
    triangular_attention: bool = False  # skip fully-masked causal tiles
    serve_weight_stationary: bool = False  # decode: resident 2D-sharded experts

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by period "
            f"{len(self.period)}"
        )

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def is_ssm_only(self) -> bool:
        return all(l.kind == "mamba" for l in self.period)

    @property
    def has_attention(self) -> bool:
        return any(l.kind in ("attn", "cross") for l in self.period)

    @property
    def has_moe(self) -> bool:
        return any(l.moe for l in self.period)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def supports_long_context_decode(self) -> bool:
        """Sub-quadratic/sub-linear-memory decode path available?

        True for SSM-only (O(1) state) and hybrid (sequence-sharded KV for
        the sparse attention layers). Pure full-attention stacks skip
        long_500k per instructions (DESIGN.md §5).
        """
        frac_attn = sum(l.kind != "mamba" for l in self.period) / len(self.period)
        return frac_attn < 0.5 or all(
            l.sliding_window > 0 for l in self.period if l.kind != "mamba"
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 periods,
    d_model<=512, <=4 experts)."""
    kw: dict = dict(
        n_layers=2 * len(cfg.period),
        d_model=256,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=64,
        v_head_dim=64,   # must track head_dim (frozen post_init already ran)
        d_ff=512,
        vocab_size=512,
        compute_dtype="float32",
        remat=False,
        attn_chunk=512,
        loss_chunk=256,
    )
    if cfg.attn_type == "mla":
        kw.update(kv_lora_rank=64, rope_head_dim=32, q_lora_rank=0)
    if cfg.n_routed_experts:
        kw.update(
            n_routed_experts=4,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            moe_top_k=2,
            moe_d_ff=128,
        )
    if cfg.ssm_d_state:
        kw.update(ssm_d_state=16, ssm_head_dim=32, ssm_chunk=64)
    if cfg.n_image_tokens:
        kw.update(n_image_tokens=16)
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
