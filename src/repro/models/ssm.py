"""Mamba2 — SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear across chunks); decode is the O(1) recurrent state update. Heads are
the parallelism unit (logical axis "ssm_heads" -> mesh "model").

Shapes: d_inner = expand*d_model, H = d_inner/head_dim (P=head_dim),
state N = ssm_d_state, G = ssm_n_groups (B/C shared per group, GVA-style).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig


class SSMCache(NamedTuple):
    conv: jnp.ndarray   # (B, W-1, conv_dim) rolling conv window
    state: jnp.ndarray  # (B, H, P, N) recurrent SSM state


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_d_state


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H, P, N, G = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    cdim = conv_dim(cfg)
    ks = jax.random.split(key, 6)
    s, so = 0.02, 0.02 / math.sqrt(2 * cfg.n_layers)
    # in_proj emits [z (di), x (di), B (G*N), C (G*N), dt (H)]
    params = {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * G * N + H)) * s,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, cdim)) * s,
        "conv_b": jnp.zeros((cdim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),       # A = -exp(A_log)
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))),  # softplus^-1
        "norm": jnp.ones((di,)),
        "out_proj": jax.random.normal(ks[2], (di, d)) * so,
    }
    specs = {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, specs


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, G, N, H = cfg.ssm_d_inner, cfg.ssm_n_groups, cfg.ssm_d_state, cfg.ssm_n_heads
    z, x, Bc, Cc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, x, Bc, Cc, dt


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum' for 1-SS matrix: L[..., i, j] = sum_{j<k<=i} x[k]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,     # (B, L, H, P)
    dt: jnp.ndarray,    # (B, L, H)  (post-softplus)
    A: jnp.ndarray,     # (H,) negative
    Bm: jnp.ndarray,    # (B, L, G, N)
    Cm: jnp.ndarray,    # (B, L, G, N)
    chunk: int,
    init_state: jnp.ndarray | None = None,   # (B, H, P, N)
):
    """Chunked SSD scan. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    rep = H // G

    # to chunks, f32 for stability
    xb = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtb = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bb = Bm.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)
    Cb = Cm.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)
    dA = dtb * A.astype(jnp.float32)                       # (B,nc,c,H)

    dA_cs = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum
    # 1) intra-chunk (diagonal blocks): y = (C B^T ∘ L) x with decay matrix L
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # (B,nc,H,c,c)
    CB = jnp.einsum("bkcgn,bksgn->bkgcs", Cb, Bb)          # (B,nc,G,c,s)
    CB = jnp.repeat(CB, rep, axis=2)                       # -> (B,nc,H,c,s)
    att = CB * Lmat * dtb.transpose(0, 1, 3, 2)[..., None, :]  # × dt_s
    y_diag = jnp.einsum("bkhcs,bkshp->bkchp", att, xb)

    # 2) per-chunk final states: S_n = sum_s exp(dA_cs[c_end]-dA_cs[s]) dt_s B_s x_s
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (B,nc,c,H)
    sB = jnp.repeat(Bb, rep, axis=3)                       # (B,nc,c,H,N)
    states = jnp.einsum(
        "bkch,bkchn,bkchp->bkhpn",
        decay_states * dtb, sB, xb,
    )

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])              # (B,nc,H)

    def scan_fn(s_prev, inp):
        st, dec = inp                                      # (B,H,P,N), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # 4) off-diagonal contribution from carried state
    state_decay = jnp.exp(dA_cs)                           # (B,nc,c,H)
    sC = jnp.repeat(Cb, rep, axis=3)                       # (B,nc,c,H,N)
    y_off = jnp.einsum("bkchn,bkhpn,bkch->bkchp", sC, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, L, H, P)
    return y, final_state


def mamba_forward(
    params, x: jnp.ndarray, cfg: ModelConfig, dtype,
) -> tuple[jnp.ndarray, SSMCache]:
    """Full-sequence Mamba2 block (train / prefill). Returns output and the
    decode cache (conv tail + final SSM state)."""
    B, L, _ = x.shape
    H, P, N, G = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    di, W = cfg.ssm_d_inner, cfg.ssm_conv_width
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(dtype))
    z, xr, Bc, Cc, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)       # (B, L, cdim)
    conv_tail = conv_in[:, max(L - (W - 1), 0):, :]
    if conv_tail.shape[1] < W - 1:  # L < W-1 (tiny smoke shapes)
        conv_tail = jnp.pad(conv_tail, ((0, 0), (W - 1 - conv_tail.shape[1], 0), (0, 0)))
    # causal depthwise conv1d
    pad = jnp.pad(conv_in, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + L, :] * params["conv_w"][i].astype(dtype) for i in range(W)
    ) + params["conv_b"].astype(dtype)
    conv = jax.nn.silu(conv)
    xr, Bc, Cc = jnp.split(conv, [di, di + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, state = ssd_chunked(
        xr.reshape(B, L, H, P),
        dt,
        A,
        Bc.reshape(B, L, G, N),
        Cc.reshape(B, L, G, N),
        chunk=min(cfg.ssm_chunk, L),
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xr.reshape(B, L, H, P).astype(jnp.float32)
    y = y.reshape(B, L, di).astype(dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(dtype))
    return out, SSMCache(conv=conv_tail.astype(dtype), state=state.astype(jnp.float32))


def mamba_decode(
    params, x: jnp.ndarray, cache: SSMCache, cfg: ModelConfig, dtype,
) -> tuple[jnp.ndarray, SSMCache]:
    """One-token recurrent update: state' = state*exp(dt A) + dt B ⊗ x."""
    B = x.shape[0]
    H, P, N, G = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_n_groups
    di, W = cfg.ssm_d_inner, cfg.ssm_conv_width
    zxbcdt = jnp.einsum("bd,de->be", x[:, 0], params["in_proj"].astype(dtype))
    z, xr, Bc, Cc, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xr, Bc, Cc], axis=-1)       # (B, cdim)
    win = jnp.concatenate([cache.conv, conv_in[:, None, :]], axis=1)  # (B, W, cdim)
    conv = jnp.einsum("bwc,wc->bc", win, params["conv_w"].astype(dtype)) + params[
        "conv_b"
    ].astype(dtype)
    conv = jax.nn.silu(conv)
    xr, Bc, Cc = jnp.split(conv, [di, di + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                # (B,H)
    xh = xr.reshape(B, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bc.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cc.reshape(B, G, N), rep, axis=1).astype(jnp.float32)
    state = cache.state * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, di).astype(dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(dtype))
    return out[:, None, :], SSMCache(conv=win[:, 1:, :], state=state)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    H, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim(cfg)), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
    )
