"""Composable decoder stack covering all assigned architecture families.

The model is a scan over ``n_periods`` stacked periods (HLO size is
independent of depth — essential for 1-core dry-run compiles of 60-100L
models). Within a period, layers follow ``cfg.period``:

    layer = x + mixer(norm(x));  x = x + ffn(norm(x))      (ffn optional)

mixers: GQA self-attention, MLA self-attention, Mamba2-SSD, gated
cross-attention (VLM image layers). ffns: dense SwiGLU or MoE.

Three entry points (matching the assigned input shapes):
  * ``loss_fn``     — training forward + chunked CE     (train_4k)
  * ``prefill``     — forward returning logits + caches  (prefill_32k)
  * ``decode_step`` — 1 token against a cache            (decode_32k/long_500k)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import InputShape, LayerSpec, ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(key, spec: LayerSpec, cfg: ModelConfig):
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    k1, k2, k3 = jax.random.split(key, 3)
    params["ln1"], specs["ln1"] = L.init_rms_norm(cfg.d_model)
    if spec.kind == "attn":
        if cfg.attn_type == "mla":
            params["attn"], specs["attn"] = attn_lib.init_mla(k1, cfg)
        else:
            params["attn"], specs["attn"] = attn_lib.init_gqa(k1, cfg)
    elif spec.kind == "cross":
        params["attn"], specs["attn"] = attn_lib.init_cross_attn(k1, cfg)
    elif spec.kind == "mamba":
        params["mamba"], specs["mamba"] = ssm_lib.init_mamba(k1, cfg)
    else:
        raise ValueError(spec.kind)
    if spec.kind != "mamba" or cfg.d_ff or spec.moe:
        if cfg.d_ff or spec.moe:
            params["ln2"], specs["ln2"] = L.init_rms_norm(cfg.d_model)
            if spec.moe:
                params["moe"], specs["moe"] = moe_lib.init_moe(k2, cfg)
            else:
                params["mlp"], specs["mlp"] = L.init_mlp(k3, cfg.d_model, cfg.d_ff)
    return params, specs


def init_params(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Returns (params, logical-axis specs). Per-period-position layer params
    are stacked over periods on a leading axis (scanned)."""
    keys = jax.random.split(key, len(cfg.period) + 3)
    blocks = {}
    block_specs = {}
    for pos, spec in enumerate(cfg.period):
        pkeys = jax.random.split(keys[pos], cfg.n_periods)
        stacked = jax.vmap(lambda k: _init_layer(k, spec, cfg)[0])(pkeys)
        _, sspec = _init_layer(keys[pos], spec, cfg)
        # leading stacking axis is never sharded: prepend None
        blocks[str(pos)] = stacked
        block_specs[str(pos)] = jax.tree_util.tree_map(
            lambda s: (None, *s), sspec, is_leaf=lambda s: isinstance(s, tuple)
        )
    params: dict[str, Any] = {"blocks": blocks}
    specs: dict[str, Any] = {"blocks": block_specs}
    ke, kh = keys[-2], keys[-1]
    if cfg.n_codebooks:  # audio: one table per codebook
        sub = jax.random.split(ke, cfg.n_codebooks)
        params["embed"] = jax.vmap(
            lambda k: L.init_embedding(k, cfg.vocab_size, cfg.d_model)[0]
        )(sub)
        specs["embed"] = (None, "vocab", "embed_nodiv")
        params["lm_head"] = jax.vmap(
            lambda k: L.init_lm_head(k, cfg.d_model, cfg.vocab_size)[0]
        )(jax.random.split(kh, cfg.n_codebooks))
        specs["lm_head"] = (None, "embed_nodiv", "vocab")
    else:
        params["embed"], specs["embed"] = L.init_embedding(ke, cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            params["lm_head"], specs["lm_head"] = L.init_lm_head(kh, cfg.d_model, cfg.vocab_size)
    if cfg.n_image_tokens:  # vlm projector stub: identity-sized projection
        params["media_proj"] = jax.random.normal(keys[-3], (cfg.d_model, cfg.d_model)) * 0.02
        specs["media_proj"] = ("embed", "embed_nodiv")
    params["final_norm"], specs["final_norm"] = L.init_rms_norm(cfg.d_model)
    return params, specs


def abstract_params(cfg: ModelConfig) -> tuple[dict, dict]:
    """(ShapeDtypeStruct tree, logical spec tree) without allocating."""
    cell = {}

    def f(k):
        p, s = init_params(cfg, k)
        cell["specs"] = s
        return p

    params_shape = jax.eval_shape(f, jax.random.PRNGKey(0))
    return params_shape, cell["specs"]


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _embed(params, tokens, cfg: ModelConfig, dtype):
    if cfg.n_codebooks:
        # tokens (B, S, n_q): sum codebook embeddings
        embs = [
            params["embed"][q][tokens[..., q]] for q in range(cfg.n_codebooks)
        ]
        return sum(embs).astype(dtype)
    return params["embed"][tokens].astype(dtype)


def _apply_layer(
    lp, spec: LayerSpec, x, positions, media, cfg: ModelConfig, dtype, mesh,
    collect_cache: bool,
):
    cache_out = {}
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        if cfg.attn_type == "mla":
            o, (ckv, kr) = attn_lib.mla_attend_full(
                lp["attn"], h, positions, cfg, dtype, cfg.attn_chunk
            )
            if collect_cache:
                cache_out = {"ckv": ckv, "kr": kr}
        else:
            q, k, v = attn_lib.gqa_qkv(lp["attn"], h, positions, cfg, dtype)
            o = attn_lib.blockwise_attention(
                q, k, v, causal=True, kv_chunk=cfg.attn_chunk,
                q_chunk=min(cfg.attn_chunk, 1024),
                triangular=cfg.triangular_attention,
                window=spec.sliding_window,
            )
            o = attn_lib.gqa_out(lp["attn"], o, dtype)
            if collect_cache:
                cache_out = {"k": k, "v": v}
    elif spec.kind == "cross":
        o = attn_lib.cross_attend(lp["attn"], h, media, cfg, dtype)
        if collect_cache:
            mk = jnp.einsum("bmd,dhk->bmhk", media, lp["attn"]["wk"].astype(dtype))
            mv = jnp.einsum("bmd,dhk->bmhk", media, lp["attn"]["wv"].astype(dtype))
            cache_out = {"mk": mk, "mv": mv}
    else:  # mamba
        o, ssm_cache = ssm_lib.mamba_forward(lp["mamba"], h, cfg, dtype)
        if collect_cache:
            cache_out = {"conv": ssm_cache.conv, "state": ssm_cache.state}
    x = x + o
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in lp or "moe" in lp:
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if spec.moe:
            if mesh is not None and mesh.shape.get("model", 1) > 1:
                y, aux = moe_lib.moe_ffn_sharded(lp["moe"], h, cfg, dtype, mesh)
            else:
                y, aux = moe_lib.moe_ffn_local(lp["moe"], h, cfg, dtype)
        else:
            y = L.mlp(lp["mlp"], h, dtype)
        x = x + y
    return x, aux, cache_out


def forward(
    params,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    *,
    media: jnp.ndarray | None = None,
    mesh=None,
    return_cache: bool = False,
):
    """Full-sequence forward. Returns (hidden (B,S,D), aux, cache|None)."""
    dtype = _dtype(cfg)
    x = _embed(params, tokens, cfg, dtype)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    if media is not None and "media_proj" in params:
        media = jnp.einsum("bmd,de->bme", media.astype(dtype), params["media_proj"].astype(dtype))

    def period_body(carry, block_params):
        x, aux = carry
        caches = {}
        for pos, spec in enumerate(cfg.period):
            x, a, c = _apply_layer(
                block_params[str(pos)], spec, x, positions, media, cfg, dtype,
                mesh, return_cache,
            )
            aux = aux + a
            if return_cache:
                caches[str(pos)] = c
        return (x, aux), caches if return_cache else None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux / cfg.n_layers, caches


def _lm_head(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def loss_fn(params, batch: dict, cfg: ModelConfig, mesh=None) -> jnp.ndarray:
    """Mean next-token CE (+ router aux)."""
    h, aux, _ = forward(
        params, batch["tokens"], cfg, media=batch.get("media"), mesh=mesh
    )
    if cfg.n_codebooks:
        ce = 0.0
        for q in range(cfg.n_codebooks):
            ce += L.chunked_cross_entropy(
                h, params["lm_head"][q].astype(h.dtype), batch["labels"][..., q],
                cfg.loss_chunk,
            )
        ce = ce / cfg.n_codebooks
    else:
        ce = L.chunked_cross_entropy(
            h, _lm_head(params, cfg).astype(h.dtype), batch["labels"], cfg.loss_chunk
        )
    return ce + cfg.router_aux_weight * aux


def prefill(params, tokens, cfg: ModelConfig, *, media=None, mesh=None):
    """Forward with caches; returns (last-position logits, cache)."""
    h, _, cache = forward(
        params, tokens, cfg, media=media, mesh=mesh, return_cache=True
    )
    hl = h[:, -1:]
    head = _lm_head(params, cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,qdv->bsqv", hl, head.astype(h.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", hl, head.astype(h.dtype))
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Empty fixed-size decode cache (leaves stacked over periods)."""
    dtype = dtype or _dtype(cfg)
    np_, cache = cfg.n_periods, {}
    for pos, spec in enumerate(cfg.period):
        if spec.kind == "attn":
            S_eff = min(seq_len, spec.sliding_window) if spec.sliding_window else seq_len
            if cfg.attn_type == "mla":
                c = {
                    "ckv": jnp.zeros((np_, batch, S_eff, cfg.kv_lora_rank), dtype),
                    "kr": jnp.zeros((np_, batch, S_eff, cfg.rope_head_dim), dtype),
                }
            else:
                c = {
                    "k": jnp.zeros((np_, batch, S_eff, cfg.n_kv_heads, cfg.head_dim), dtype),
                    "v": jnp.zeros((np_, batch, S_eff, cfg.n_kv_heads, cfg.v_head_dim), dtype),
                }
        elif spec.kind == "cross":
            c = {
                "mk": jnp.zeros((np_, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.head_dim), dtype),
                "mv": jnp.zeros((np_, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.v_head_dim), dtype),
            }
        else:
            c = {
                "conv": jnp.zeros((np_, batch, cfg.ssm_conv_width - 1, ssm_lib.conv_dim(cfg)), dtype),
                "state": jnp.zeros(
                    (np_, batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state),
                    jnp.float32,
                ),
            }
        cache[str(pos)] = c
    return cache


def decode_step(params, cache: dict, tokens: jnp.ndarray, pos: jnp.ndarray,
                cfg: ModelConfig, mesh=None):
    """One decode step: tokens (B, 1) (or (B,1,n_q)); pos () int32 — the
    absolute position being written. Attends over pos+1 cache entries.
    Returns (logits, updated cache)."""
    dtype = _dtype(cfg)
    x = _embed(params, tokens, cfg, dtype)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    length = pos + 1

    def period_body(x, xs):
        block_params, pc = xs
        new_pc = {}
        for lpos, spec in enumerate(cfg.period):
            lp = block_params[str(lpos)]
            c = pc[str(lpos)]
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            if spec.kind == "attn":
                if cfg.attn_type == "mla":
                    ckv_new, kr_new = attn_lib.mla_compress(lp["attn"], h, positions, cfg, dtype)
                    ckv = jax.lax.dynamic_update_slice(
                        c["ckv"], ckv_new.astype(c["ckv"].dtype), (0, pos, 0))
                    kr = jax.lax.dynamic_update_slice(
                        c["kr"], kr_new.astype(c["kr"].dtype), (0, pos, 0))
                    o = attn_lib.mla_decode(lp["attn"], h, ckv, kr, length, positions, cfg, dtype)
                    new_pc[str(lpos)] = {"ckv": ckv, "kr": kr}
                else:
                    q, k, v = attn_lib.gqa_qkv(lp["attn"], h, positions, cfg, dtype)
                    buf = c["k"].shape[1]
                    if spec.sliding_window and spec.sliding_window <= buf:
                        # ring buffer: slot = pos mod window; all slots valid
                        # once wrapped (every entry is within the window)
                        slot = pos % jnp.asarray(buf, pos.dtype)
                        eff_len = jnp.minimum(length, buf)
                    else:
                        slot, eff_len = pos, length
                    ck = jax.lax.dynamic_update_slice(
                        c["k"], k.astype(c["k"].dtype), (0, slot, 0, 0))
                    cv = jax.lax.dynamic_update_slice(
                        c["v"], v.astype(c["v"].dtype), (0, slot, 0, 0))
                    o = attn_lib.decode_attend(q[:, 0], ck, cv, eff_len)[:, None]
                    o = attn_lib.gqa_out(lp["attn"], o, dtype)
                    new_pc[str(lpos)] = {"k": ck, "v": cv}
            elif spec.kind == "cross":
                o = attn_lib.decode_attend(
                    jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"].astype(dtype))[:, 0],
                    c["mk"], c["mv"], jnp.asarray(c["mk"].shape[1]),
                )[:, None]
                o = attn_lib.gqa_out(lp["attn"], o, dtype)
                o = jnp.tanh(lp["attn"]["gate"]).astype(dtype) * o
                new_pc[str(lpos)] = c
            else:
                ssm_c = ssm_lib.SSMCache(conv=c["conv"], state=c["state"])
                o, ssm_c = ssm_lib.mamba_decode(lp["mamba"], h, ssm_c, cfg, dtype)
                new_pc[str(lpos)] = {"conv": ssm_c.conv, "state": ssm_c.state}
            x = x + o
            if "mlp" in lp or "moe" in lp:
                h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
                if spec.moe:
                    if mesh is not None and mesh.shape.get("model", 1) > 1:
                        y, _ = moe_lib.moe_ffn_sharded(
                            lp["moe"], h, cfg, dtype, mesh,
                            weight_stationary=cfg.serve_weight_stationary,
                        )
                    else:
                        y, _ = moe_lib.moe_ffn_local(lp["moe"], h, cfg, dtype)
                else:
                    y = L.mlp(lp["mlp"], h, dtype)
                x = x + y
        return x, new_pc

    x, new_cache = jax.lax.scan(period_body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = _lm_head(params, cfg)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,qdv->bsqv", x, head.astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dtype))
    return logits, new_cache
