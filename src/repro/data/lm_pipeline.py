"""Token data pipeline for the LM training examples.

No external corpora offline, so the pipeline generates a *structured*
synthetic language (Zipfian unigrams + Markov bigram structure + copy
motifs) — enough signal for a ~100M model's loss to drop well below the
unigram entropy, which is what the end-to-end example asserts.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 8192
    seq_len: int = 512
    batch_size: int = 8
    markov_order: float = 0.9    # prob of following the bigram chain
    n_states: int = 16           # latent chain states
    seed: int = 0


class SyntheticLM:
    """Deterministic per-seed stream of (tokens, labels) batches."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, S = cfg.vocab_size, cfg.n_states
        # Zipfian emission per latent state over a state-specific vocab slice
        self.state_next = rng.integers(0, S, size=(S, 4))      # sparse chain
        probs = 1.0 / np.arange(1, 65) ** 1.8
        self.emit_probs = probs / probs.sum()
        self.emit_vocab = rng.integers(0, V, size=(S, 64))

    def batch(self, step: int, n_codebooks: int = 0):
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, L = cfg.batch_size, cfg.seq_len + 1
        state = rng.integers(0, cfg.n_states, size=B)
        toks = np.empty((B, L), np.int32)
        for t in range(L):
            emit_idx = rng.choice(64, size=B, p=self.emit_probs)
            toks[:, t] = self.emit_vocab[state, emit_idx]
            follow = rng.random(B) < cfg.markov_order
            nxt = self.state_next[state, rng.integers(0, 4, size=B)]
            state = np.where(follow, nxt, rng.integers(0, cfg.n_states, size=B))
        tokens, labels = toks[:, :-1], toks[:, 1:]
        if n_codebooks:
            tokens = np.stack([(tokens + q) % cfg.vocab_size for q in range(n_codebooks)], -1)
            labels = np.stack([(labels + q) % cfg.vocab_size for q in range(n_codebooks)], -1)
        return {"tokens": tokens, "labels": labels}

    def unigram_entropy(self) -> float:
        """Upper bound a memorizing-unigram model should beat."""
        p = self.emit_probs
        return float(-(p * np.log(p)).sum())
