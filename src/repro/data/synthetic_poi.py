"""Synthetic POI check-in data with the paper's structural properties.

The real Foursquare / Alipay dumps are not available offline (repro gate),
so we *simulate the data gate*: a generator that reproduces the structure
the paper's method exploits —

* **location aggregation** (paper Fig. 2): users and POIs are clustered in
  cities; almost all of a user's check-ins happen in their home city;
* geographic proximity correlates with preference (nearby users share
  tastes — this is what makes nearby-user communication informative);
* power-law user activity and item popularity;
* implicit feedback: r_ij = 1 for observed check-ins (paper assumes
  r in [0,1]).

Sizes default to small (1-core CPU) but ``foursquare_like()`` /
``alipay_like()`` reproduce Table 1's statistics at full scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class POIDatasetConfig:
    n_users: int = 500
    n_items: int = 400
    n_ratings: int = 4500
    n_cities: int = 12
    idiosyncrasy: float = 0.9    # per-user taste noise — what the *personal*
                                 # factor q^i exists to capture (Eq. 5)
    latent_dim: int = 8          # ground-truth taste dimensionality
    cross_city_frac: float = 0.03   # paper: multi-city users are "neglectable"
    taste_spatial_scale: float = 0.35  # how fast taste varies with distance in-city
    distance_weight: float = 1.0    # POI-distance penalty in check-in logits:
                                    # people prefer *nearby* POIs — the locality
                                    # a single global MF factor cannot encode
                                    # but DMF's personal+neighborhood factors can
    popularity_scale: float = 0.8   # item log-popularity spread (power law)
    test_frac: float = 0.10
    seed: int = 0


@dataclasses.dataclass
class POIDataset:
    config: POIDatasetConfig
    train: np.ndarray        # (n_train, 2) int (user, item)
    test: np.ndarray         # (n_test, 2) int
    user_coords: np.ndarray  # (I, 2) float
    user_city: np.ndarray    # (I,) int
    item_city: np.ndarray    # (J,) int

    @property
    def n_users(self) -> int:
        return self.config.n_users

    @property
    def n_items(self) -> int:
        return self.config.n_items


def _zipf_sizes(n_bins: int, total: int, a: float, rng: np.random.Generator) -> np.ndarray:
    w = 1.0 / np.arange(1, n_bins + 1) ** a
    w = w / w.sum()
    sizes = rng.multinomial(total, w)
    sizes = np.maximum(sizes, 1)
    return sizes


def generate(cfg: POIDatasetConfig) -> POIDataset:
    rng = np.random.default_rng(cfg.seed)
    I, J, C = cfg.n_users, cfg.n_items, cfg.n_cities

    # --- geography: city centers on a plane, users/items gaussian around them
    centers = rng.uniform(0.0, 10.0 * np.sqrt(C), size=(C, 2))
    user_city = np.repeat(np.arange(C), _cum_assign(I, C, rng))[:I]
    item_city = np.repeat(np.arange(C), _cum_assign(J, C, rng))[:J]
    rng.shuffle(user_city)
    rng.shuffle(item_city)
    user_coords = centers[user_city] + rng.normal(0, 1.0, size=(I, 2))
    item_coords = centers[item_city] + rng.normal(0, 1.0, size=(J, 2))

    # --- ground-truth taste: city mean + spatially smooth local component
    K = cfg.latent_dim
    city_taste = rng.normal(0, 1.0, size=(C, K))
    # smooth in-city variation: project coordinates through random features
    proj = rng.normal(0, cfg.taste_spatial_scale, size=(2, K))
    u_true = (
        city_taste[user_city] + user_coords @ proj
        + cfg.idiosyncrasy * rng.normal(0, 1, (I, K))
    )
    v_true = city_taste[item_city] + item_coords @ proj + 0.3 * rng.normal(0, 1, (J, K))

    # --- activity / popularity power laws
    user_act = _zipf_sizes(I, cfg.n_ratings, 1.1, rng)
    log_pop = cfg.popularity_scale * (-np.log(np.arange(1, J + 1)))
    rng.shuffle(log_pop)

    # --- sample check-ins: mostly home-city POIs, softmax over
    #     taste-match + popularity - distance (locality!)
    pairs = set()
    records = []
    items_by_city = [np.flatnonzero(item_city == c) for c in range(C)]
    all_items = np.arange(J)
    for i in range(I):
        n_i = int(user_act[i])
        home = items_by_city[user_city[i]]
        for _ in range(n_i):
            pool = home if (rng.random() > cfg.cross_city_frac and len(home) > 0) else all_items
            dist = np.linalg.norm(item_coords[pool] - user_coords[i], axis=-1)
            logits = (
                0.5 * (v_true[pool] @ u_true[i])
                + log_pop[pool]
                - cfg.distance_weight * dist
            )
            logits = logits - logits.max()
            p = np.exp(logits)
            p /= p.sum()
            j = int(rng.choice(pool, p=p))
            if (i, j) not in pairs:
                pairs.add((i, j))
                records.append((i, j))
    records = np.array(records, dtype=np.int64)

    # --- 90/10 split (paper: random 90% train / 10% test)
    n = len(records)
    perm = rng.permutation(n)
    n_test = max(1, int(round(cfg.test_frac * n)))
    test = records[perm[:n_test]]
    train = records[perm[n_test:]]
    return POIDataset(cfg, train, test, user_coords.astype(np.float32), user_city, item_city)


def _cum_assign(n: int, c: int, rng: np.random.Generator) -> np.ndarray:
    return _zipf_sizes(c, n, 0.8, rng)


def foursquare_like(reduced: bool = True, seed: int = 0) -> POIDataset:
    """Table 1 Foursquare row: 6,524 users / 3,197 POIs / 26,186 ratings / 117 cities."""
    if reduced:
        cfg = POIDatasetConfig(n_users=500, n_items=320, n_ratings=4500, n_cities=12, seed=seed)
    else:
        cfg = POIDatasetConfig(n_users=6524, n_items=3197, n_ratings=26186, n_cities=117, seed=seed)
    return generate(cfg)


def alipay_like(reduced: bool = True, seed: int = 1) -> POIDataset:
    """Table 1 Alipay row: 5,996 users / 7,404 POIs / 18,978 ratings / 298 cities."""
    if reduced:
        cfg = POIDatasetConfig(n_users=450, n_items=560, n_ratings=3400, n_cities=24, seed=seed)
    else:
        cfg = POIDatasetConfig(n_users=5996, n_items=7404, n_ratings=18978, n_cities=298, seed=seed)
    return generate(cfg)
