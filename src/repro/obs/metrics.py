"""Process-wide metrics registry: counters, gauges and histograms with
labels, a JSONL sink, and the ONE latency-percentile definition.

Before this module, `serving/engine.py` and `scheduling/metrics.py`
each carried their own `latency_percentiles` (identical math, divergent
by accident waiting to happen) and every subsystem kept ad-hoc counter
fields. Both now delegate here; benches and the CLI export snapshots of
the same registry.

Design points:

* Metrics are cheap plain-Python accumulators — no locks on the read
  path, one registry-level lock on series creation. Hot loops that must
  stay instrumentation-free simply never call in (the serving/
  scheduling stats objects keep their local fields and `publish()` into
  the registry at report time).
* A series is (metric name, frozen label set). Labels are passed as
  kwargs and keyed order-insensitively: ``c.inc(shard=0, path="dense")``
  and ``c.inc(path="dense", shard=0)`` hit the same series.
* Re-registering a name with the same kind returns the same metric
  object (idempotent, so modules can register at call sites); a kind
  clash raises.
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np


def latency_percentiles(latencies_s, qs=(50, 95, 99)) -> dict[str, float]:
    """Seconds in, ``{"p50_ms": ..., "p95_ms": ..., "p99_ms": ...}`` out
    (NaN for an empty stream) — the single percentile definition shared
    by `serving.engine.EngineStats`, `scheduling.metrics` and the
    benches. Accepts any iterable (generators included)."""
    lat = np.asarray(list(latencies_s), np.float64)
    if lat.size == 0:
        return {f"p{q}_ms": float("nan") for q in qs}
    lat_ms = lat * 1e3
    return {f"p{q}_ms": float(np.percentile(lat_ms, q)) for q in qs}


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


class _Metric:
    kind = "abstract"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict[tuple, object] = {}

    def labels(self) -> list[tuple]:
        return sorted(self._series)


class Counter(_Metric):
    """Monotone accumulator. `inc` only — use a Gauge for set-to-value."""
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def _snapshot(self):
        return {_label_str(k): v for k, v in sorted(self._series.items())}


class Gauge(_Metric):
    """Point-in-time value; `set` overwrites."""
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), float("nan")))

    def _snapshot(self):
        return {_label_str(k): v for k, v in sorted(self._series.items())}


class Histogram(_Metric):
    """Raw-observation histogram (exact percentiles at snapshot time —
    fine at the stream sizes this repo sees; a bucketed variant can slot
    in behind the same API if streams ever outgrow memory)."""
    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        self._series.setdefault(_label_key(labels), []).append(float(value))

    def observe_many(self, values, **labels) -> None:
        self._series.setdefault(_label_key(labels), []).extend(
            float(v) for v in values)

    def reset(self, **labels) -> None:
        self._series[_label_key(labels)] = []

    def values(self, **labels) -> list[float]:
        return list(self._series.get(_label_key(labels), []))

    def percentiles(self, qs=(50, 95, 99), **labels) -> dict[str, float]:
        """Percentiles of the raw observations, in ms-suffixed keys —
        observations are expected in SECONDS (the repo-wide latency
        convention; see `latency_percentiles`)."""
        return latency_percentiles(self.values(**labels), qs)

    def _snapshot(self):
        out = {}
        for key, vals in sorted(self._series.items()):
            arr = np.asarray(vals, np.float64)
            s = {"count": int(arr.size)}
            if arr.size:
                s.update(sum=float(arr.sum()), min=float(arr.min()),
                         max=float(arr.max()), mean=float(arr.mean()),
                         p50=float(np.percentile(arr, 50)),
                         p95=float(np.percentile(arr, 95)),
                         p99=float(np.percentile(arr, 99)))
            out[_label_str(key)] = s
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _register(self, kind: str, name: str, help: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}, "
                        f"requested {kind}")
                return m
            m = _KINDS[kind](name, help)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register("counter", name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register("gauge", name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._register("histogram", name, help)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        """``{name: {"kind", "help", "values": {label-string: value}}}``
        — counters/gauges report numbers, histograms report summary
        stats (count/sum/min/max/mean/p50/p95/p99)."""
        out = {}
        for name in self.names():
            m = self._metrics[name]
            out[name] = {"kind": m.kind, "help": m.help,
                         "values": m._snapshot()}
        return out

    def write_jsonl(self, path, event: str = "snapshot") -> dict:
        """Append one ``{"event", "unix_time", "metrics"}`` line; returns
        the snapshot it wrote."""
        snap = self.snapshot()
        line = {"event": event, "unix_time": time.time(), "metrics": snap}
        with open(path, "a") as f:
            f.write(json.dumps(line) + "\n")
        return snap


_GLOBAL = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _GLOBAL


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _GLOBAL
    _GLOBAL = registry
    return registry
