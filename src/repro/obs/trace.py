"""Nestable span tracing with Chrome-trace/Perfetto export.

A `Tracer` records wall-clock spans (monotonic `perf_counter_ns`,
thread-safe, nesting tracked per thread) and exports them as the
Chrome trace-event JSON that Perfetto / `chrome://tracing` load
directly. The module-level tracer is DISABLED by default: `span()`
then returns a shared null context manager — no allocation, no clock
read — so instrumented hot paths cost nothing until someone calls
`configure_tracing(True)` (the `--trace-out` CLI flag does).

For the GPU pass (ROADMAP item 5) two bridges ride along:
`Tracer.jax_profiler` wraps `jax.profiler.trace` (XLA-level timeline
alongside these host-side spans), and `device_memory_snapshot()` grabs
per-device `memory_stats()` where the backend exposes them.
"""
from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time


class _NullContext:
    """Shared do-nothing context manager for the disabled-tracer path."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullContext()


class _Span:
    __slots__ = ("name", "t0_ns", "args", "depth", "parent")

    def __init__(self, name, t0_ns, args, depth, parent):
        self.name = name
        self.t0_ns = t0_ns
        self.args = args
        self.depth = depth
        self.parent = parent


class Tracer:
    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._events: list[dict] = []   # completed chrome "X" events
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._t0_ns = time.perf_counter_ns()   # trace-relative origin

    # -- recording ---------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Time a block. Nesting is tracked per thread: the exported
        event carries its depth and parent span name in ``args``."""
        if not self.enabled:
            yield None
            return
        stack = self._stack()
        parent = stack[-1].name if stack else None
        sp = _Span(name, time.perf_counter_ns(), args, len(stack), parent)
        stack.append(sp)
        try:
            yield sp
        finally:
            stack.pop()
            t1 = time.perf_counter_ns()
            ev_args = {"depth": sp.depth}
            if sp.parent is not None:
                ev_args["parent"] = sp.parent
            ev_args.update(sp.args)
            ev = {
                "name": name,
                "ph": "X",
                "ts": (sp.t0_ns - self._t0_ns) / 1e3,    # µs
                "dur": (t1 - sp.t0_ns) / 1e3,            # µs
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": ev_args,
            }
            with self._lock:
                self._events.append(ev)

    def traced(self, name: str | None = None):
        """Decorator form of `span` (span name defaults to the function's
        qualified name)."""
        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(label):
                    return fn(*a, **kw)
            return wrapper
        return deco

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event (chrome ``ph: "i"``)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "p",
              "ts": (time.perf_counter_ns() - self._t0_ns) / 1e3,
              "pid": os.getpid(), "tid": threading.get_ident(),
              "args": dict(args)}
        with self._lock:
            self._events.append(ev)

    # -- export ------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def chrome_trace(self) -> dict:
        """The Chrome trace-event document Perfetto loads as-is."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path) -> dict:
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc

    def span_stats(self) -> dict[str, dict]:
        """Per-span-name aggregates over the recorded complete events:
        ``{name: {count, total_s, mean_s, max_s}}`` — what the roofline
        measured-timing path consumes."""
        agg: dict[str, list[float]] = {}
        for ev in self.events():
            if ev.get("ph") == "X":
                agg.setdefault(ev["name"], []).append(ev["dur"] / 1e6)
        return {
            name: {"count": len(d), "total_s": sum(d),
                   "mean_s": sum(d) / len(d), "max_s": max(d)}
            for name, d in sorted(agg.items())
        }

    # -- accelerator bridges ----------------------------------------------
    @contextlib.contextmanager
    def jax_profiler(self, logdir):
        """Wrap a block in `jax.profiler.trace(logdir)` when the tracer
        is enabled (no-op otherwise) — the XLA-level timeline for the GPU
        pass, complementary to these host-side spans."""
        if not self.enabled:
            yield
            return
        import jax
        with jax.profiler.trace(str(logdir)):
            yield


def device_memory_snapshot() -> list[dict]:
    """Per-device `memory_stats()` where the backend exposes them (GPU/
    TPU runtimes do; CPU returns an empty stats dict per device). Never
    raises — observability must not take the job down."""
    try:
        import jax
        out = []
        for d in jax.local_devices():
            stats = {}
            try:
                stats = d.memory_stats() or {}
            except Exception:
                pass
            out.append({"device": str(d), "platform": d.platform,
                        "memory_stats": {k: int(v) for k, v in stats.items()
                                         if isinstance(v, (int, float))}})
        return out
    except Exception:
        return []


_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    global _GLOBAL
    _GLOBAL = tracer
    return tracer


def configure_tracing(enabled: bool = True) -> Tracer:
    """Flip the global tracer; returns it (fresh event buffer NOT
    implied — call `clear()` for that)."""
    _GLOBAL.enabled = enabled
    return _GLOBAL


def span(name: str, **args):
    """Span on the global tracer — returns a shared null context (no
    allocation) while tracing is disabled, so call sites in hot loops
    stay free."""
    if not _GLOBAL.enabled:
        return _NULL
    return _GLOBAL.span(name, **args)
