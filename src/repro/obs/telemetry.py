"""Per-epoch training telemetry: fixed-shape device reductions +
host-side assembly into `FitResult.telemetry` / a JSONL event stream.

The device half lives inside the epoch programs (core/dmf.py,
sharding/dmf.py): when their static ``tele`` flag is True, every
minibatch step emits one ``TELE_W``-wide vector of read-only reductions
over intermediates the step already computes — squared U/Q update
norms, squared released-message mass, squared scattered-propagation
mass, delivered-message counts, and Byzantine screening accept/reject
counts. The scan sums them, so telemetry keeps the one-dispatch-per-
epoch property and (critically) draws NO rng and writes NO factor —
factor trajectories are bit-identical with telemetry off, at every
shard count, DP/churn/byzantine included (tested).

The host half (`EpochCollector`) merges those reductions with what only
the host knows — the accountant's ε trajectory, the churn plan's online
count, the delay ring's occupancy, wall-clock seconds — into one event
dict per epoch, optionally streamed as JSONL and mirrored into the
global metrics registry.
"""
from __future__ import annotations

import json

import numpy as np

# Slot layout of the per-step device reduction vector. Order is part of
# the device<->host contract; append, never reorder.
TELE_KEYS = (
    "u_update_sq",     # Σ du²  over the batch (lr-scaled U delta)
    "q_update_sq",     # Σ dq²  over the batch (lr-scaled Q delta)
    "msg_sq",          # Σ gp²  over released (post-DP/post-attack) messages
    "scatter_sq",      # Σ (θ·w·gp)² over every applied propagation slot
    "n_messages",      # delivered neighbor-slot count (post fault gates)
    "screen_accept",   # deliveries surviving the screen (byz path only)
    "screen_reject",   # deliveries zeroed by the screen (byz path only)
)
TELE_W = len(TELE_KEYS)


def device_stats_to_dict(tele) -> dict:
    """(n_shards, TELE_W) — or (TELE_W,) single-device — reduction block
    to named host floats. Norms are sqrt of the summed squares; counts
    sum across shards but are also kept per shard (the "messages routed
    per shard" view)."""
    a = np.asarray(tele, np.float64)
    if a.ndim == 1:
        a = a[None, :]
    assert a.shape[-1] == TELE_W, a.shape
    tot = a.sum(axis=0)
    return {
        "u_update_norm": float(np.sqrt(tot[0])),
        "q_update_norm": float(np.sqrt(tot[1])),
        "p_msg_norm": float(np.sqrt(tot[2])),
        "p_scatter_norm": float(np.sqrt(tot[3])),
        "n_messages": int(tot[4]),
        "messages_per_shard": [int(x) for x in a[:, 4]],
        "screen_accept": int(tot[5]),
        "screen_reject": int(tot[6]),
    }


class EpochCollector:
    """Accumulates one event dict per training epoch.

    ``jsonl_path`` streams each event as one JSON line as it lands (the
    file is line-buffered so a crashed run keeps its prefix). Events are
    also mirrored into the global `obs.metrics` registry (a handful of
    dict ops per epoch — only paid when telemetry is on)."""

    def __init__(self, jsonl_path=None, n_shards: int = 1,
                 publish_metrics: bool = True):
        self.events: list[dict] = []
        self.n_shards = n_shards
        self._file = open(jsonl_path, "a", buffering=1) if jsonl_path else None
        self._publish = publish_metrics

    def record(self, epoch: int, *, train_loss: float, device_stats=None,
               test_loss=None, accountant=None, plan=None, ring=None,
               byz=None, wall_s: float | None = None) -> dict:
        ev: dict = {"epoch": int(epoch), "train_loss": float(train_loss)}
        if test_loss is not None:
            ev["test_loss"] = float(test_loss)
        if wall_s is not None:
            ev["wall_s"] = float(wall_s)
        if device_stats is not None:
            d = (device_stats if isinstance(device_stats, dict)
                 else device_stats_to_dict(device_stats))
            screening = byz is not None and getattr(byz, "screen", False)
            if not screening:
                # the zeros the non-byz trace emits are "not measured",
                # not "nothing rejected" — don't report them as counts
                d = {k: v for k, v in d.items()
                     if k not in ("screen_accept", "screen_reject")}
            ev.update(d)
        if accountant is not None and accountant.eps_trajectory:
            ev["dp_eps"] = float(accountant.eps_trajectory[-1])
        if plan is not None:
            ev["n_online"] = int(np.asarray(plan.online[epoch]).sum())
        if ring is not None:
            # messages still buffered for a later epoch after this one's
            # deliveries and writes
            ev["ring_occupancy"] = int((np.asarray(ring.due) > epoch).sum())
        self.events.append(ev)
        if self._file is not None:
            self._file.write(json.dumps(ev) + "\n")
        if self._publish:
            self._publish_event(ev)
        return ev

    def _publish_event(self, ev: dict) -> None:
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.get_registry()
        reg.counter("train_epochs_total").inc()
        reg.gauge("train_loss").set(ev["train_loss"])
        if "dp_eps" in ev:
            reg.gauge("train_dp_eps").set(ev["dp_eps"])
        if "n_online" in ev:
            reg.gauge("train_online_learners").set(ev["n_online"])
        if "ring_occupancy" in ev:
            reg.gauge("train_ring_occupancy").set(ev["ring_occupancy"])
        if "n_messages" in ev:
            reg.counter("train_messages_total").inc(ev["n_messages"])
            for s, c in enumerate(ev.get("messages_per_shard", ())):
                reg.counter("train_messages_per_shard_total").inc(c, shard=s)
        if "screen_accept" in ev:
            reg.counter("train_screen_accept_total").inc(ev["screen_accept"])
            reg.counter("train_screen_reject_total").inc(ev["screen_reject"])
        if "wall_s" in ev:
            reg.histogram("train_epoch_seconds").observe(ev["wall_s"])

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None
