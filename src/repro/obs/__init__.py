"""Unified observability layer (DESIGN.md §14).

Three pillars, all off by default and structurally zero-cost when off:

* `obs.metrics`   — process-wide registry of counters / gauges /
  histograms with labels, plus THE single `latency_percentiles`
  definition shared by serving, scheduling and the benches.
* `obs.trace`     — nestable span tracing (context manager + decorator,
  monotonic clock, thread-safe) exporting Chrome-trace/Perfetto JSON,
  with an optional `jax.profiler.trace` bridge and device-memory
  snapshots for the GPU pass.
* `obs.telemetry` — per-epoch training telemetry (loss, update norms,
  DP ε trajectory, churn online counts, DelayRing occupancy, Byzantine
  screening counts, messages per shard) assembled host-side from
  fixed-shape device reductions threaded through the epoch scan.

The hard contract mirrors the byzantine layer's: instrumentation off is
the statically-dead-code default (bit-exact with the uninstrumented
stack at every shard count), and telemetry on leaves factor
trajectories bit-identical — reductions only, no extra rng draws.
"""
from repro.obs.metrics import (MetricsRegistry, get_registry,   # noqa: F401
                               latency_percentiles, set_registry)
from repro.obs.trace import (Tracer, configure_tracing,          # noqa: F401
                             get_tracer, set_tracer, span)
from repro.obs.telemetry import (EpochCollector, TELE_KEYS,      # noqa: F401
                                 TELE_W, device_stats_to_dict)
