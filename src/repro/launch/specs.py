"""ShapeDtypeStruct input stand-ins + shardings for every (arch × shape).

``input_specs`` builds the exact abstract inputs each step function lowers
against — weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models import ssm as ssm_lib
from repro.models.config import InputShape, ModelConfig


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _maybe(ax, size, mesh):
    """Mesh axis (or tuple of axes) if divisible, else None (replicate)."""
    axs = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axs:
        n *= mesh.shape[a]
    return ax if size % n == 0 else None


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh, batch_over=None):
    """Training/prefill batch: tokens + labels (+ media for VLM).
    ``batch_over`` overrides the batch axes (§Perf dp layout: whole mesh)."""
    ba = batch_over or batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    bax = _maybe(ba if len(ba) > 1 else ba[0], B, mesh)
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tok_spec = P(bax, *([None] * (len(tok_shape) - 1)))
    out = {
        "tokens": _sds(tok_shape, jnp.int32, mesh, tok_spec),
        "labels": _sds(tok_shape, jnp.int32, mesh, tok_spec),
    }
    if cfg.n_image_tokens:
        out["media"] = _sds(
            (B, cfg.n_image_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype),
            mesh,
            P(bax, None, None),
        )
    return out


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh):
    """Decode cache stand-ins with the long-context sharding policy:

    * batch dim -> batch axes (when divisible; batch=1 replicates);
    * KV sequence dim -> the *model* axis when batch occupies data
      (decode_32k), or (data, model) when batch=1 (long_500k) — the
      sequence-sharded KV design from DESIGN.md §5/§6;
    * SSM state: heads -> model (O(1) memory, nothing seq-indexed).
    """
    ba = batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    bax = _maybe(ba if len(ba) > 1 else ba[0], B, mesh)
    if bax is None:
        seq_ax = _maybe(tuple([*ba, "model"]), S, mesh)
    else:
        seq_ax = _maybe("model", S, mesh)
    np_ = cfg.n_periods
    dt = jnp.dtype(cfg.compute_dtype)
    cache, specs = {}, {}
    for pos, spec in enumerate(cfg.period):
        if spec.kind == "attn":
            Se = min(S, spec.sliding_window) if spec.sliding_window else S
            seq_ax_e = seq_ax if Se == S else _maybe(
                tuple([*ba, "model"]) if bax is None else "model", Se, mesh)
            if cfg.attn_type == "mla":
                shapes = {
                    "ckv": ((np_, B, Se, cfg.kv_lora_rank), P(None, bax, seq_ax_e, None)),
                    "kr": ((np_, B, Se, cfg.rope_head_dim), P(None, bax, seq_ax_e, None)),
                }
            else:
                kvax = _maybe("model", cfg.n_kv_heads, mesh) if seq_ax_e in (None,) else None
                shapes = {
                    "k": ((np_, B, Se, cfg.n_kv_heads, cfg.head_dim),
                          P(None, bax, seq_ax_e, kvax, None)),
                    "v": ((np_, B, Se, cfg.n_kv_heads, cfg.v_head_dim),
                          P(None, bax, seq_ax_e, kvax, None)),
                }
        elif spec.kind == "cross":
            kvax = _maybe("model", cfg.n_kv_heads, mesh)
            shapes = {
                "mk": ((np_, B, cfg.n_image_tokens, cfg.n_kv_heads, cfg.head_dim),
                       P(None, bax, None, kvax, None)),
                "mv": ((np_, B, cfg.n_image_tokens, cfg.n_kv_heads, cfg.v_head_dim),
                       P(None, bax, None, kvax, None)),
            }
        else:
            cdim = ssm_lib.conv_dim(cfg)
            shapes = {
                "conv": ((np_, B, cfg.ssm_conv_width - 1, cdim),
                         P(None, bax, None, _maybe("model", cdim, mesh))),
                "state": ((np_, B, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state),
                          P(None, bax, _maybe("model", cfg.ssm_n_heads, mesh), None, None)),
            }
        cache[str(pos)] = {
            k: _sds(sh, jnp.float32 if k == "state" else dt, mesh, sp)
            for k, (sh, sp) in shapes.items()
        }
        specs[str(pos)] = {k: sp for k, (sh, sp) in shapes.items()}
    return cache, specs


def decode_specs(cfg: ModelConfig, shape: InputShape, mesh):
    """serve_step inputs: (cache, tokens (B,1), pos ())."""
    ba = batch_axes(mesh)
    B = shape.global_batch
    bax = _maybe(ba if len(ba) > 1 else ba[0], B, mesh)
    cache, cache_pspecs = cache_specs(cfg, shape, mesh)
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks else (B, 1)
    tokens = _sds(tok_shape, jnp.int32, mesh, P(bax, *([None] * (len(tok_shape) - 1))))
    pos = _sds((), jnp.int32, mesh, P())
    return cache, cache_pspecs, tokens, pos
