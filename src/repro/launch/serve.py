"""Serving launcher: prefill and decode steps with the long-context cache
sharding policy (launch/specs.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.sharding import rules


def serve_param_shardings(cfg: ModelConfig, mesh, *, fsdp: bool = True,
                          weight_stationary: bool = False):
    """weight_stationary (§Perf): weights resident — no FSDP dim on the
    embed axis; MoE expert hidden dim sharded over data instead (matches
    moe_ffn_sharded's ws path). Use when the resident footprint fits HBM."""
    params_shape, specs = transformer.abstract_params(cfg)
    overrides = dict(rules.SERVE_WS_OVERRIDES) if weight_stationary else None
    pspecs = rules.params_pspecs(specs, params_shape, mesh, fsdp=fsdp,
                                 overrides=overrides)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )


def make_prefill_step(cfg: ModelConfig, mesh):
    def prefill_step(params, batch):
        return transformer.prefill(
            params, batch["tokens"], cfg, media=batch.get("media"), mesh=mesh
        )

    return jax.jit(prefill_step)


def make_decode_step(cfg: ModelConfig, mesh, cache_pspecs):
    cache_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cache_pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )

    def serve_step(params, cache, tokens, pos):
        return transformer.decode_step(params, cache, tokens, pos, cfg, mesh=mesh)

    return jax.jit(
        serve_step,
        donate_argnums=(1,),
        out_shardings=(None, cache_shardings),
    )
