import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: the dry-run (and only the dry-run)
#   builds the 256/512-chip production meshes out of host placeholder devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) this lowers + compiles the
appropriate step function with ShapeDtypeStruct inputs (no allocation),
prints memory/cost analysis, extracts collective bytes from the compiled
HLO, and writes one JSON record per combination to
``benchmarks/results/dryrun/``. Roofline terms (deliverable g) are derived
from these records by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--sync gossip]
"""
import argparse
import functools
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.launch.train import make_train_step
from repro.launch.serve import make_decode_step, make_prefill_step, serve_param_shardings
from repro.models import transformer
from repro.models.config import INPUT_SHAPES, ModelConfig
from repro.optim import adamw
from repro.sharding import rules

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

def _dtype_bytes(dt: str) -> int:
    return {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
        "f8e4m3fn": 1, "f8e5m2": 1,
    }.get(dt, 4)


_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|f8e4m3fn|f8e5m2)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in (compiled) HLO."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op_m = re.search(
            r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start|-done)?\(", rhs)
        if not op_m:
            continue
        if rhs.startswith("tuple(") or op_m.group(0).endswith("-done("):
            continue  # -done carries no new bytes; counted at -start
        op = op_m.group(1)
        # output shapes precede the op name on the lhs type annotation
        type_part = rhs[: op_m.start()]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(type_part):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _dtype_bytes(dt)
        out[op] = out.get(op, 0) + nbytes
    return out


def hlo_flops_bytes(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


OPT_VARIANTS = {
    # §Perf hillclimb variants (EXPERIMENTS.md §Perf):
    #   tri      — triangular causal schedule (halves attention FLOPs)
    #   serve_ws — weight-stationary decode (resident weights, 2D experts)
    #   dp       — pure data-parallel layout over the whole mesh (dense only)
    "tri": {},
    "serve_ws": {},
    "dp": {},
}


def lower_combo(arch: str, shape_name: str, mesh, *, sync: str = "allreduce",
                calibrate: bool = True, opt: str | None = None):
    """Lower+compile the right step for (arch, shape); XLA counts a scan
    (while-loop) body once, so two extra cheap compiles at 1 and 2 periods
    calibrate the per-period cost and the totals are extrapolated:
        total = q(full) + (q(2p) - q(1p)) * (n_periods - 1).
    Returns the result dict with raw + corrected quantities."""
    cfg = registry.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context_decode:
        return {"skipped": "full-attention arch: long_500k requires "
                "sub-quadratic attention (DESIGN.md §5)"}
    import dataclasses as _dc
    if opt == "tri":
        cfg = _dc.replace(cfg, triangular_attention=True)
    elif opt == "serve_ws":
        cfg = _dc.replace(cfg, serve_weight_stationary=True)
    res = _lower_one(cfg, shape, mesh, sync=sync, opt=opt)
    res["opt"] = opt
    if "skipped" in res or not calibrate:
        return res
    p = len(cfg.period)
    r1 = _lower_one(_dc.replace(cfg, n_layers=p), shape, mesh, sync=sync, opt=opt)
    r2 = _lower_one(_dc.replace(cfg, n_layers=2 * p), shape, mesh, sync=sync, opt=opt)
    n_periods = cfg.n_periods
    body_flops = max(0.0, r2["hlo_flops_per_device"] - r1["hlo_flops_per_device"])
    body_bytes = max(0.0, r2["hlo_bytes_per_device"] - r1["hlo_bytes_per_device"])
    res["corrected_flops_per_device"] = (
        res["hlo_flops_per_device"] + body_flops * (n_periods - 1)
    )
    res["corrected_bytes_per_device"] = (
        res["hlo_bytes_per_device"] + body_bytes * (n_periods - 1)
    )
    coll = dict(res["collective_bytes_per_device"])
    for op in set(r1["collective_bytes_per_device"]) | set(r2["collective_bytes_per_device"]) | set(coll):
        body = max(
            0,
            r2["collective_bytes_per_device"].get(op, 0)
            - r1["collective_bytes_per_device"].get(op, 0),
        )
        coll[op] = coll.get(op, 0) + body * (n_periods - 1)
    res["corrected_collective_bytes_per_device"] = coll
    res["calib"] = {
        "p1_flops": r1["hlo_flops_per_device"],
        "p2_flops": r2["hlo_flops_per_device"],
        "p1_coll": r1["collective_bytes_per_device"],
        "p2_coll": r2["collective_bytes_per_device"],
    }
    return res


def _lower_one(cfg: ModelConfig, shape, mesh, *, sync: str = "allreduce",
               opt: str | None = None):
    shape_name = shape.name
    arch = cfg.name
    t0 = time.time()
    if shape.kind == "train":
        overrides = rules.DP_OVERRIDES if opt == "dp" else None
        batch_over = ("data", "model") if opt == "dp" else None
        gossip_cfg = None
        if opt == "gossip_d1":
            from repro.core.gossip import GossipConfig
            sync, gossip_cfg = "gossip", GossipConfig(walk_length=1)
        elif opt == "gossip_pod":
            from repro.core.gossip import GossipConfig
            sync = "gossip"
            gossip_cfg = GossipConfig(learner_axis="pod", walk_length=1)
        step, init_fn, pshard = make_train_step(
            cfg, mesh, adamw(3e-4), sync=sync, rules_overrides=overrides,
            gossip=gossip_cfg,
        )
        batch = specs_lib.batch_specs(cfg, shape, mesh, batch_over=batch_over)
        params_shape, specs = transformer.abstract_params(cfg)
        if sync == "gossip":
            L = mesh.shape[(gossip_cfg.learner_axis if gossip_cfg else "data")]
            params_shape = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct((L, *x.shape), x.dtype), params_shape
            )
            opt_shape = jax.eval_shape(
                lambda p: jax.vmap(adamw(3e-4).init)(p), params_shape
            )
        else:
            opt_shape = jax.eval_shape(adamw(3e-4).init, params_shape)
        from repro.launch.train import TrainState
        state_shape = TrainState(params_shape, opt_shape)
        # bind shardings onto abstract state
        state_sds = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            state_shape,
            _state_shardings(state_shape, pshard),
        )
        lowered = step.lower(state_sds, batch)
    elif shape.kind == "prefill":
        pshard = serve_param_shardings(cfg, mesh)
        params_shape, _ = transformer.abstract_params(cfg)
        params_sds = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            params_shape, pshard,
        )
        batch = specs_lib.batch_specs(cfg, shape, mesh)
        step = make_prefill_step(cfg, mesh)
        lowered = step.lower(params_sds, batch)
    else:  # decode
        if shape_name == "long_500k" and not cfg.supports_long_context_decode:
            return {"skipped": "full-attention arch: long_500k requires "
                    "sub-quadratic attention (DESIGN.md §5)"}
        ws = bool(cfg.serve_weight_stationary)
        pshard = serve_param_shardings(cfg, mesh, fsdp=not ws, weight_stationary=ws)
        params_shape, _ = transformer.abstract_params(cfg)
        params_sds = jax.tree_util.tree_map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            params_shape, pshard,
        )
        cache, cache_pspecs, tokens, pos = specs_lib.decode_specs(cfg, shape, mesh)
        step = make_decode_step(cfg, mesh, cache_pspecs)
        lowered = step.lower(params_sds, cache, tokens, pos)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    flops, bytes_acc = hlo_flops_bytes(compiled)
    coll = collective_bytes(compiled.as_text())
    n_dev = mesh.size
    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "sync": sync,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        "n_devices": n_dev,
    }
    return res


def _state_shardings(state_shape, pshard):
    """TrainState shardings: params use pshard; opt state mirrors by shape."""
    from repro.launch.train import _opt_shardings
    from repro.optim import adamw as _a
    mesh = jax.tree_util.tree_leaves(pshard)[0].mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    by_shape = {}
    for p, s in zip(
        jax.tree_util.tree_leaves(state_shape.params), jax.tree_util.tree_leaves(pshard)
    ):
        by_shape.setdefault(p.shape, s)
    opt_sh = jax.tree_util.tree_map(
        lambda l: by_shape.get(l.shape, repl), state_shape.opt_state
    )
    from repro.launch.train import TrainState
    return TrainState(pshard, opt_sh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sync", default="allreduce", choices=["allreduce", "gossip"])
    ap.add_argument("--opt", default=None,
                    choices=[None, "tri", "serve_ws", "dp", "gossip_d1",
                             "gossip_pod"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    RESULTS.mkdir(parents=True, exist_ok=True)
    combos = []
    archs = registry.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    n_ok = n_skip = n_fail = 0
    for arch, shape in combos:
        tag = f"{arch}__{shape}__{'multipod' if args.multi_pod else 'pod'}__{args.sync}"
        if args.opt:
            tag += f"__{args.opt}"
        out_path = RESULTS / f"{tag}.json"
        if out_path.exists() and not args.force:
            print(f"[cached] {tag}")
            n_ok += 1
            continue
        print(f"[lower ] {tag} ...", flush=True)
        try:
            res = lower_combo(arch, shape, mesh, sync=args.sync, opt=args.opt)
        except Exception as e:
            res = {"error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            n_fail += 1
            print(f"[FAIL  ] {tag}: {res['error']}")
        else:
            if "skipped" in res:
                n_skip += 1
                print(f"[skip  ] {tag}: {res['skipped']}")
            else:
                n_ok += 1
                print(
                    f"[ok    ] {tag}: compile={res['compile_s']}s "
                    f"flops/dev={res['hlo_flops_per_device']:.3e} "
                    f"coll={ {k: f'{v:.2e}' for k, v in res['collective_bytes_per_device'].items()} }"
                )
        out_path.write_text(json.dumps(res, indent=1))
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
