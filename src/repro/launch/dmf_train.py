"""CLI launcher for the paper's DMF training (Alg. 1).

    PYTHONPATH=src python -m repro.launch.dmf_train \
        --dataset foursquare --dim 10 --epochs 80 --walk-length 3

Learner-sharded SPMD training (one dispatch per epoch across an N-device
``learners`` mesh; on a CPU host the devices are provisioned automatically):

    PYTHONPATH=src python -m repro.launch.dmf_train --n-shards 8 --epochs 20

Differentially-private gradient exchange (src/repro/privacy/): clip+noise
every outgoing P-gradient message, with Rényi-DP ε(δ) accounting — either
set the mechanism directly or give a target ε and let the launcher solve
for the noise multiplier σ:

    PYTHONPATH=src python -m repro.launch.dmf_train --dp-sigma 1.0 --dp-clip 0.5
    PYTHONPATH=src python -m repro.launch.dmf_train --dp-epsilon 2.0 --epochs 40
"""
from __future__ import annotations

import argparse
import json


def _ensure_host_devices(n: int) -> None:
    """Provision n host-platform devices BEFORE jax initializes its backend
    (imports are fine — only the first device query binds XLA_FLAGS)."""
    if n <= 1:
        return
    from repro.launch.mesh import ensure_host_platform_devices

    ensure_host_platform_devices(n)
    import jax

    if len(jax.devices()) < n:
        raise SystemExit(
            f"--n-shards {n} needs {n} devices but jax initialized with "
            f"{len(jax.devices())} (backend was up before the flag could "
            f"apply); re-run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="foursquare", choices=["foursquare", "alipay"])
    ap.add_argument("--full", action="store_true", help="Table-1-scale data")
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=80)
    ap.add_argument("--mode", default="dmf", choices=["dmf", "gdmf", "ldmf"])
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--neg-samples", type=int, default=3)
    ap.add_argument("--n-neighbors", type=int, default=2)
    ap.add_argument("--walk-length", type=int, default=3)
    ap.add_argument("--paper-literal", action="store_true",
                    help="keep Alg.1's literal |N^d(i)| neighbor weighting")
    ap.add_argument("--use-pallas", action="store_true",
                    help="fused Pallas step kernel inside the scan epoch")
    ap.add_argument("--dense-reference", action="store_true",
                    help="seed dense per-batch path (equivalence oracle)")
    ap.add_argument("--n-shards", type=int, default=1,
                    help="learner-mesh width: >1 trains/evaluates SPMD over "
                         "row-sharded U/P/Q (host devices auto-provisioned)")
    ap.add_argument("--dp-clip", type=float, default=float("inf"),
                    help="C: L2 clip per outgoing gradient message "
                         "(inf = off; --dp-sigma/--dp-epsilon need it finite)")
    ap.add_argument("--dp-sigma", type=float, default=0.0,
                    help="σ: Gaussian noise multiplier relative to the clip "
                         "(0 = off; the DP-off path is bit-exact un-noised)")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="target ε(δ): solve for the σ meeting it over this "
                         "run's epochs/batching (overrides --dp-sigma; "
                         "defaults --dp-clip to 1.0 if unset)")
    ap.add_argument("--dp-delta", type=float, default=1e-5)
    ap.add_argument("--dp-seed", type=int, default=0,
                    help="DP mechanism base seed (per-epoch noise streams "
                         "are folded from it)")
    ap.add_argument("--churn-dropout", type=float, default=0.0,
                    help="per-epoch i.i.d. learner offline probability "
                         "(robustness/faults.py; offline learners are "
                         "bit-frozen, their messages lost)")
    ap.add_argument("--churn-session-alpha", type=float, default=0.0,
                    help="Pareto tail index of power-law online sessions "
                         "(0 = no session process)")
    ap.add_argument("--churn-delay", type=int, default=0,
                    help="max staleness k: learners draw a delay class in "
                         "0..k and their gradient messages land that many "
                         "epochs late through the fixed-shape delay ring")
    ap.add_argument("--churn-late-frac", type=float, default=0.0,
                    help="fraction of learners that join mid-run "
                         "(stateless before their join epoch)")
    ap.add_argument("--churn-seed", type=int, default=0,
                    help="churn schedule seed (independent of training rng)")
    ap.add_argument("--byz-family", default="none",
                    help="inject byzantine senders (robustness/byzantine.py): "
                         "none|nan|inf|norm_inflate|sign_flip|shill")
    ap.add_argument("--byz-frac", type=float, default=0.0,
                    help="fraction of learners compromised (seeded draw)")
    ap.add_argument("--byz-scale", type=float, default=10.0,
                    help="attack magnitude: norm-inflation factor λ, or the "
                         "shill direction's norm")
    ap.add_argument("--byz-target-item", type=int, default=0,
                    help="POI the shill family pushes every message toward")
    ap.add_argument("--byz-no-collude", action="store_true",
                    help="independent per-attacker shill directions instead "
                         "of one shared (colluding) direction")
    ap.add_argument("--byz-start-epoch", type=int, default=0,
                    help="sleeper agents: attack only from this epoch on")
    ap.add_argument("--byz-seed", type=int, default=0,
                    help="attack plan seed (independent of training rng)")
    ap.add_argument("--screen", action="store_true",
                    help="receiver-side message screening: drop non-finite "
                         "incoming messages, and over-norm ones if a cap "
                         "is set (--norm-cap)")
    ap.add_argument("--norm-cap", type=float, default=float("inf"),
                    help="screening L2 cap τ; 0 = auto-calibrate from the DP "
                         "mechanism so honest noised messages pass "
                         "(privacy.screening_threshold; needs finite "
                         "--dp-clip)")
    ap.add_argument("--aggregation", default="sum",
                    choices=["sum", "trim", "median"],
                    help="per-(receiver,item) combine of incoming messages: "
                         "plain summation, or count-scaled coordinate-wise "
                         "trimmed mean / median (byzantine-robust)")
    ap.add_argument("--trim-frac", type=float, default=0.2,
                    help="fraction trimmed from EACH tail (aggregation=trim)")
    ap.add_argument("--on-nonfinite", default="warn",
                    choices=["warn", "raise", "halt"],
                    help="divergence sentinel: warn and continue, raise "
                         "DivergenceError, or halt returning the last "
                         "finite state")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot the full loop state (factors, rng, delay "
                         "ring, eps ledger) under this directory")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot every N completed epochs (0 = off)")
    ap.add_argument("--resume-from", default=None,
                    help="a step_<t> dir or checkpoint root: restore and "
                         "continue, bit-identical to the uninterrupted run")
    ap.add_argument("--telemetry", action="store_true",
                    help="per-epoch training telemetry (obs/telemetry.py): "
                         "loss, update/message norms, DP ε, online counts, "
                         "ring occupancy, screening accepts — surfaced on "
                         "FitResult.telemetry; factor trajectories stay "
                         "bit-identical to a telemetry-off run")
    ap.add_argument("--telemetry-out", default=None,
                    help="stream each epoch's telemetry event as one JSON "
                         "line to this file (implies --telemetry)")
    ap.add_argument("--trace-out", default=None,
                    help="enable span tracing and write a Chrome-trace/"
                         "Perfetto JSON here when the run finishes")
    ap.add_argument("--metrics-out", default=None,
                    help="append a final metrics-registry snapshot (JSONL) "
                         "here when the run finishes")
    ap.add_argument("--log-every", type=int, default=0,
                    help="log train/test loss (and ε so far) every N epochs "
                         "via the `repro.dmf` stdlib logger (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _ensure_host_devices(args.n_shards)
    if args.log_every > 0:
        import logging
        logging.basicConfig(level=logging.INFO,
                            format="%(asctime)s %(name)s %(message)s")
    if args.trace_out:
        from repro.obs import trace as trace_lib
        trace_lib.configure_tracing(True)
    # import after the device flag is set: jax binds XLA_FLAGS at backend
    # init, which these imports may trigger (e.g. kernel warm paths)
    from repro.core import dmf, graph
    from repro.data import synthetic_poi

    maker = (synthetic_poi.foursquare_like if args.dataset == "foursquare"
             else synthetic_poi.alipay_like)
    ds = maker(reduced=not args.full, seed=args.seed)
    gcfg = graph.GraphConfig(
        n_neighbors=args.n_neighbors, walk_length=args.walk_length,
        paper_literal=args.paper_literal,
    )
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    if args.dense_reference:
        prop = graph.walk_propagation_matrix(W, gcfg)
    else:
        prop = graph.walk_neighbor_table(W, gcfg)

    import dataclasses as _dc

    import numpy as np
    dp_clip, dp_sigma = args.dp_clip, args.dp_sigma
    if args.dp_epsilon > 0:
        # ε-target mode: solve for the noise multiplier meeting ε(δ) over
        # this run's realized batching, at the busiest learner's rate and
        # its expected rows-per-participating-batch (accountant semantics)
        from repro.privacy import sigma_for_epsilon
        if not np.isfinite(dp_clip):
            dp_clip = 1.0
        m1 = 1 + args.neg_samples
        B = next(f.default for f in _dc.fields(dmf.DMFConfig)
                 if f.name == "batch_size")
        nb = max(len(ds.train) * m1 // B, 1)
        rows = np.bincount(ds.train[:, 0], minlength=ds.n_users) * m1
        q_max = float(1.0 - (1.0 - 1.0 / nb) ** rows.max())
        kbar = max(1.0, float(rows.max()) / max(nb * q_max, 1e-9))
        dp_sigma = sigma_for_epsilon(
            args.dp_epsilon, q=q_max, steps=args.epochs * nb,
            delta=args.dp_delta, rows_per_step=kbar)
        print(f"dp target eps={args.dp_epsilon} delta={args.dp_delta}: "
              f"solved sigma={dp_sigma:.4f} (clip={dp_clip}, q_max={q_max:.4f}, "
              f"steps={args.epochs * nb}, rows_per_step={kbar:.2f})")

    cfg = dmf.DMFConfig(
        n_users=ds.n_users, n_items=ds.n_items, dim=args.dim, mode=args.mode,
        alpha=args.alpha, beta=args.beta, gamma=args.gamma, lr=args.lr,
        neg_samples=args.neg_samples, seed=args.seed,
        use_pallas=args.use_pallas, n_shards=args.n_shards,
        dp_clip=dp_clip, dp_sigma=dp_sigma, dp_seed=args.dp_seed,
    )
    churn = None
    if (args.churn_dropout > 0 or args.churn_session_alpha > 0
            or args.churn_delay > 0 or args.churn_late_frac > 0):
        from repro.robustness import ChurnConfig
        churn = ChurnConfig(
            dropout=args.churn_dropout,
            session_alpha=args.churn_session_alpha,
            delay_classes=tuple(range(args.churn_delay + 1)),
            late_frac=args.churn_late_frac,
            seed=args.churn_seed,
        )
        plan = churn.compile(ds.n_users, args.epochs)
        print(f"churn dropout={args.churn_dropout} "
              f"delay<= {args.churn_delay} late_frac={args.churn_late_frac} "
              f"participation={plan.participation_rate:.3f}")

    attack = defense = None
    if args.byz_family != "none" and args.byz_frac > 0:
        from repro.robustness.byzantine import AttackConfig
        attack = AttackConfig(
            family=args.byz_family, frac=args.byz_frac, scale=args.byz_scale,
            target_item=args.byz_target_item, collude=not args.byz_no_collude,
            start_epoch=args.byz_start_epoch, seed=args.byz_seed)
        print(f"byzantine family={args.byz_family} frac={args.byz_frac} "
              f"scale={args.byz_scale} seed={args.byz_seed}")
    if args.screen or args.aggregation != "sum":
        from repro.privacy import screening_threshold
        from repro.robustness.byzantine import DefenseConfig
        norm_cap = args.norm_cap
        if args.screen and norm_cap == 0.0:
            norm_cap = screening_threshold(cfg, cfg.dim)
            print(f"screening norm cap auto-calibrated: tau={norm_cap:.4f}")
        defense = DefenseConfig(
            screen=args.screen, norm_cap=norm_cap,
            aggregation=args.aggregation, trim_frac=args.trim_frac)

    comm = graph.communication_bytes(
        W, D=args.walk_length, K=args.dim, n_ratings=len(ds.train))
    fanout = ("dense" if args.dense_reference
              else f"S={int(prop.idx.shape[1])}")
    print(f"dataset={args.dataset} users={ds.n_users} items={ds.n_items} "
          f"train={len(ds.train)} comm/epoch={comm/1e6:.2f} MB "
          f"propagation={fanout} shards={args.n_shards}")

    def cb(t, state, loss):
        if t % 10 == 0:
            print(f"epoch {t:4d} train_loss {loss:.5f}")

    res = dmf.fit(cfg, ds.train, prop, epochs=args.epochs, test=ds.test,
                  callback=cb, dense_reference=args.dense_reference,
                  dp_delta=args.dp_delta, churn=churn,
                  checkpoint_dir=args.checkpoint_dir,
                  checkpoint_every=args.checkpoint_every,
                  resume_from=args.resume_from,
                  attack=attack, defense=defense,
                  on_nonfinite=args.on_nonfinite,
                  telemetry=args.telemetry, telemetry_out=args.telemetry_out,
                  log_every=args.log_every)
    if res.diverged_at is not None:
        print(f"training halted: diverged at epoch {res.diverged_at}")
    ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items,
                      n_shards=args.n_shards)
    if res.privacy is not None:
        pv = dict(res.privacy)
        pv.pop("eps_trajectory", None)
        print("privacy " + json.dumps(pv))
    if res.telemetry:
        last = res.telemetry[-1]
        print("telemetry " + json.dumps(
            {k: last[k] for k in ("epoch", "train_loss", "n_messages")
             if k in last}))
    if args.trace_out:
        from repro.obs import trace as trace_lib
        trace_lib.get_tracer().export_chrome_trace(args.trace_out)
        print(f"trace written to {args.trace_out} "
              f"({len(trace_lib.get_tracer().events())} events)")
    if args.metrics_out:
        from repro.obs import metrics as obs_metrics
        obs_metrics.get_registry().write_jsonl(args.metrics_out,
                                               event="dmf_train_final")
        print(f"metrics snapshot appended to {args.metrics_out}")
    print(json.dumps({k: round(v, 4) for k, v in ev.items()}))


if __name__ == "__main__":
    main()
