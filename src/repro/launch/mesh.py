"""Production meshes (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; everything else
sees the real single CPU device).
"""
from __future__ import annotations

import os

import jax


def ensure_host_platform_devices(n: int) -> None:
    """Ask XLA for ``n`` virtual host (CPU) devices by appending
    ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS`` — unless
    some count is already forced, which is respected. The single definition
    for every caller that self-provisions a mesh (tests/conftest.py,
    benchmarks/run.py --devices, the dmf_train CLI --n-shards).

    Must run before the first jax *device query*: importing jax (as this
    module does) is safe — only backend init binds the flags."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Version-compat `shard_map`: jax >= 0.5 exposes ``jax.shard_map`` (with
    ``check_vma``); 0.4.x only has ``jax.experimental.shard_map.shard_map``
    (where the same knob is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single pod; (2,16,16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
