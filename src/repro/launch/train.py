"""Training launcher: builds pjit-ed train steps for any --arch.

Two synchronization modes (the paper's axis of comparison):
  * ``allreduce`` — centralized baseline: FSDP+TP sharded params; XLA's
    implicit gradient reduction over the batch axes is the all-reduce the
    paper's DMF removes.
  * ``gossip``    — DMF-adapted: per-learner replicas along
    ``gossip.learner_axis``, local updates, D rounds of ring mixing of the
    *global* partition via collective-permute (core/gossip.py).

Usage (see examples/ and launch/dryrun.py):
    step, state, shardings = make_trainer(cfg, mesh, opt, sync="allreduce")
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import gossip as gossip_lib
from repro.launch.mesh import batch_axes
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import Optimizer, apply_updates
from repro.sharding import rules


class TrainState(NamedTuple):
    params: Any
    opt_state: Any


def _loss(params, batch, cfg: ModelConfig, mesh):
    return transformer.loss_fn(params, batch, cfg, mesh=mesh)


def make_train_step(cfg: ModelConfig, mesh, opt: Optimizer, *, sync: str = "allreduce",
                    gossip: gossip_lib.GossipConfig | None = None,
                    rules_overrides: dict | None = None):
    """Returns (step_fn, init_fn, param_shardings).

    step_fn(state, batch) -> (state, metrics); already jit-ed with
    in/out shardings bound. init_fn(key) -> sharded TrainState.
    ``rules_overrides`` remaps logical axes (e.g. rules.DP_OVERRIDES for the
    pure-data-parallel §Perf layout).
    """
    if sync == "gossip":
        return _make_gossip_step(cfg, mesh, opt, gossip or gossip_lib.GossipConfig())
    return _make_allreduce_step(cfg, mesh, opt, rules_overrides)


def _make_allreduce_step(cfg: ModelConfig, mesh, opt: Optimizer,
                         rules_overrides: dict | None = None):
    params_shape, specs = transformer.abstract_params(cfg)
    pspecs = rules.params_pspecs(specs, params_shape, mesh, overrides=rules_overrides)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                    is_leaf=lambda x: isinstance(x, P))

    def init_fn(key):
        params, _ = transformer.init_params(cfg, key)
        return TrainState(params, opt.init(params))

    init_jit = jax.jit(
        init_fn,
        out_shardings=TrainState(
            pshard,
            _opt_shardings(opt, params_shape, pshard),
        ),
    )

    def step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(_loss)(state.params, batch, cfg, mesh)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        return TrainState(params, opt_state), {"loss": loss}

    step_jit = jax.jit(step, donate_argnums=(0,))
    return step_jit, init_jit, pshard


def _opt_shardings(opt: Optimizer, params_shape, pshard):
    """Optimizer-state shardings: moment leaves mirror their parameter's
    sharding (matched by shape); scalars replicate."""
    mesh = jax.tree_util.tree_leaves(pshard)[0].mesh
    repl = NamedSharding(mesh, P())
    opt_shape = jax.eval_shape(opt.init, params_shape)
    by_shape = {}
    for p, s in zip(
        jax.tree_util.tree_leaves(params_shape), jax.tree_util.tree_leaves(pshard)
    ):
        by_shape.setdefault(p.shape, s)
    return jax.tree_util.tree_map(lambda l: by_shape.get(l.shape, repl), opt_shape)


def _make_gossip_step(cfg: ModelConfig, mesh, opt: Optimizer, gcfg: gossip_lib.GossipConfig):
    """Per-learner replicas + ring mixing (DMF protocol)."""
    L = mesh.shape[gcfg.learner_axis]
    params_shape, specs = transformer.abstract_params(cfg)
    # learner axis prepended; FSDP (embed->data) disabled when data is the
    # learner axis (each learner holds a full model-sharded replica)
    st_specs = gossip_lib.stacked_specs(specs, gcfg.learner_axis)
    stacked_shape = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((L, *x.shape), x.dtype), params_shape
    )
    fsdp = gcfg.learner_axis != "data"
    pspecs = rules.params_pspecs(st_specs, stacked_shape, mesh, fsdp=fsdp)
    pshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                    is_leaf=lambda x: isinstance(x, P))

    def init_fn(key):
        params, _ = transformer.init_params(cfg, key)
        stacked = gossip_lib.stack_params(params, L)
        return TrainState(stacked, jax.vmap(opt.init)(stacked))

    init_jit = jax.jit(
        init_fn,
        out_shardings=TrainState(pshard, _opt_shardings(opt, stacked_shape, pshard)),
    )

    def reshape_batch(batch):
        # (B, ...) -> (L, B/L, ...) learner-major
        return jax.tree_util.tree_map(
            lambda x: x.reshape(L, x.shape[0] // L, *x.shape[1:]), batch
        )

    def step(state: TrainState, batch):
        lb = reshape_batch(batch)

        def per_learner(params, b, ostate):
            # NOTE mesh=None: inside vmap the MoE uses the local path; expert
            # sharding still applies through the parameter shardings.
            loss, grads = jax.value_and_grad(_loss)(params, b, cfg, None)
            upd, ostate = opt.update(grads, ostate, params)
            return apply_updates(params, upd), ostate, loss

        params, opt_state, losses = jax.vmap(per_learner)(
            state.params, lb, state.opt_state
        )
        # DMF step: mix the global partition with Ŵ^D (collective-permute)
        params = gossip_lib.mix_global(params, gcfg)
        return TrainState(params, opt_state), {
            "loss": jnp.mean(losses),
            "consensus_err": gossip_lib.consensus_error(params, gcfg),
        }

    step_jit = jax.jit(step, donate_argnums=(0,))
    return step_jit, init_jit, pshard
