"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def save_json(name: str, obj) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(obj, indent=1, default=float))
    return p


def load_json(name: str):
    p = RESULTS / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.s * 1e6


def fmt_table(headers, rows) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join(["---"] * len(headers)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)
