"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import pathlib
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
ROOT = RESULTS.parent.parent


def save_json(name: str, obj) -> pathlib.Path:
    """Write benchmarks/results/<name>.json. Headline artifacts (BENCH_*
    names, e.g. BENCH_dmf_train, BENCH_serving) are mirrored to the repo
    root — the convention the perf trajectory is tracked by."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = json.dumps(obj, indent=1, default=float)
    p = RESULTS / f"{name}.json"
    p.write_text(payload)
    if name.startswith("BENCH_"):
        (ROOT / f"{name}.json").write_text(payload)
    return p


def load_json(name: str):
    p = RESULTS / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.s * 1e6


def fmt_table(headers, rows) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join(["---"] * len(headers)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)
