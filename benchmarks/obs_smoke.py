"""Observability smoke: the acceptance gate of the obs layer, runnable
standalone and in CI.

    PYTHONPATH=src python -m benchmarks.obs_smoke [--shards N]

Runs a small DP + churn + byzantine training twice — telemetry/tracing
off, then fully on — and asserts the hard contract:

1. **bit-identical factors**: U/P/Q and the loss trajectory match the
   off-run exactly (telemetry is reductions only — no rng, no writes);
2. the per-epoch **telemetry JSONL** exists and every line carries loss,
   ε-so-far, online count, ring occupancy and screening accepts;
3. the exported **Chrome trace** is valid JSON with `traceEvents`
   containing the `fit.epoch` spans;
4. the **metrics registry** snapshot has the train_* series.

Artifacts land in ``benchmarks/results/obs/`` (telemetry.jsonl,
trace.json, metrics.jsonl, summary.json) — uploaded by CI, and
``trace.json`` is the default measured-timing input for
`benchmarks.roofline.measured_rows`.
"""
from __future__ import annotations

import argparse
import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parent / "results" / "obs"

REQUIRED_EVENT_KEYS = ("epoch", "train_loss", "dp_eps", "n_online",
                       "ring_occupancy", "screen_accept", "n_messages")


def main(shards: int = 1, epochs: int = 4) -> dict:
    import numpy as np

    from repro.core import dmf, graph
    from repro.data import synthetic_poi
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as trace_lib
    from repro.robustness import ChurnConfig
    from repro.robustness.byzantine import AttackConfig, DefenseConfig

    OUT.mkdir(parents=True, exist_ok=True)
    tele_path = OUT / "telemetry.jsonl"
    trace_path = OUT / "trace.json"
    metrics_path = OUT / "metrics.jsonl"
    for p in (tele_path, metrics_path):
        p.unlink(missing_ok=True)

    ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
        n_users=80, n_items=50, n_ratings=600, n_cities=4, seed=0))
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(
        n_users=ds.n_users, n_items=ds.n_items, dim=6, batch_size=64,
        beta=0.1, gamma=0.01, n_shards=shards,
        dp_sigma=0.3, dp_clip=1.0, dp_seed=3)
    kw = dict(
        epochs=epochs, test=ds.test,
        churn=ChurnConfig(dropout=0.2, delay_classes=(0, 1), seed=4),
        attack=AttackConfig(family="sign_flip", frac=0.2, seed=5),
        defense=DefenseConfig(screen=True, norm_cap=2.0))

    off = dmf.fit(cfg, ds.train, nbr, **kw)

    trace_lib.configure_tracing(True)
    trace_lib.get_tracer().clear()
    on = dmf.fit(cfg, ds.train, nbr, telemetry=True,
                 telemetry_out=tele_path, **kw)
    trace_lib.get_tracer().export_chrome_trace(trace_path)
    trace_lib.configure_tracing(False)
    obs_metrics.get_registry().write_jsonl(metrics_path, event="obs_smoke")

    # 1 — bit-identical trajectories
    for nm in ("U", "P", "Q"):
        a = np.asarray(getattr(off.state, nm))
        b = np.asarray(getattr(on.state, nm))
        assert (a == b).all(), f"{nm} diverged with telemetry on"
    assert off.train_losses == on.train_losses, "loss trajectory diverged"

    # 2 — JSONL telemetry stream
    lines = [json.loads(l) for l in tele_path.read_text().splitlines()]
    assert len(lines) == epochs, (len(lines), epochs)
    for ev in lines:
        missing = [k for k in REQUIRED_EVENT_KEYS if k not in ev]
        assert not missing, f"epoch {ev.get('epoch')}: missing {missing}"
        assert len(ev["messages_per_shard"]) == shards, ev
    eps = [ev["dp_eps"] for ev in lines]
    assert eps == sorted(eps), "dp_eps must be nondecreasing"

    # 3 — valid Chrome trace with the fit spans
    doc = json.loads(trace_path.read_text())
    names = {e["name"] for e in doc["traceEvents"]}
    assert "fit.epoch" in names, names
    assert sum(e["name"] == "fit.epoch" and e["ph"] == "X"
               for e in doc["traceEvents"]) == epochs

    # 4 — registry picked the training series up
    snap = json.loads(metrics_path.read_text().splitlines()[-1])["metrics"]
    for name in ("train_epochs_total", "train_loss", "train_dp_eps",
                 "train_messages_total", "train_epoch_seconds"):
        assert name in snap, name

    summary = {
        "shards": shards,
        "epochs": epochs,
        "bit_identical": True,
        "n_trace_events": len(doc["traceEvents"]),
        "final_event": lines[-1],
    }
    (OUT / "summary.json").write_text(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=1,
                    help=">1 needs that many devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--epochs", type=int, default=4)
    args = ap.parse_args()
    if args.shards > 1:
        from repro.launch.mesh import ensure_host_platform_devices
        ensure_host_platform_devices(args.shards)
    s = main(shards=args.shards, epochs=args.epochs)
    print("obs_smoke OK " + json.dumps(
        {k: s[k] for k in ("shards", "epochs", "bit_identical",
                           "n_trace_events")}))
