"""DMF training-path benchmark: seed dense per-batch loop vs the
sparse-neighborhood scan epoch vs sparse-scan + fused Pallas step, plus the
learner-sharded SPMD epoch by shard count.

Measures epochs/sec at a Foursquare-scale synthetic config (default
I=2048, J=1024, K=10, N=2, D=3 — the perf-trajectory anchor) and checks
the train/test loss trajectories of the fast paths against the dense
reference (must agree within 1e-4). The ``sharded`` section runs the SPMD
path at I=4096 for shard counts 1/2/4/8 — it needs the host devices
provisioned before jax starts:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.dmf_train_bench
    # or: PYTHONPATH=src python -m benchmarks.run --only dmf_train --devices 8

(shard counts above the provisioned device count are recorded as skipped;
 on a CPU host the virtual devices share the physical cores, so epochs/sec
 there measures dispatch/SPMD overhead, not real-parallel speedup).
Writes ``BENCH_dmf_train.json`` to benchmarks/results/ and the repo root.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi


def _time_epochs(epoch_fn, state, n_timed: int, cfg, train, prop):
    """Warm up one epoch (jit/compile), then time n_timed epochs."""
    rng = np.random.default_rng(123)
    state, _ = epoch_fn(state, prop, train, cfg, rng)
    jax.block_until_ready(state.U)
    t0 = time.perf_counter()
    for _ in range(n_timed):
        state, _ = epoch_fn(state, prop, train, cfg, rng)
    jax.block_until_ready(state.U)
    dt = time.perf_counter() - t0
    return n_timed / dt


def sharded_section(full: bool, tiny: bool, n_timed: int, n_check: int,
                    shard_counts=(1, 2, 4, 8)) -> dict:
    """Learner-sharded SPMD epochs by shard count (tentpole perf contract:
    sharded == single-device sparse path, measured at I=4096+)."""
    if tiny:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=256, n_items=128, n_ratings=1500, n_cities=4)
    elif full:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=8192, n_items=2048, n_ratings=48000, n_cities=32)
    else:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=4096, n_items=1024, n_ratings=24000, n_cities=16)
    ds = synthetic_poi.generate(dcfg)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    n_devices = len(jax.devices())
    out = {
        "config": {"n_users": ds.n_users, "n_items": ds.n_items,
                   "n_train": int(len(ds.train)), "n_devices": n_devices,
                   "neighbor_table_width_S": int(nbr.idx.shape[1])},
        "epochs_per_sec": {},
        "train_loss_max_diff_vs_sparse": {},
    }
    base_cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                             beta=0.1, gamma=0.01)
    ref = dmf.fit(base_cfg, ds.train, nbr, epochs=n_check)
    for n_shards in shard_counts:
        key = f"shards_{n_shards}"
        if n_shards > n_devices:
            out["epochs_per_sec"][key] = None
            out["train_loss_max_diff_vs_sparse"][key] = (
                f"skipped: {n_devices} devices")
            continue
        cfg = dataclasses.replace(base_cfg, n_shards=n_shards)
        from repro.sharding import dmf as sharded_dmf
        plan = sharded_dmf.make_shard_plan(nbr, cfg) if n_shards > 1 else nbr
        out["epochs_per_sec"][key] = _time_epochs(
            dmf.train_epoch, dmf.init_state(cfg), n_timed, cfg, ds.train, plan)
        rs = dmf.fit(cfg, ds.train, nbr, epochs=n_check)
        out["train_loss_max_diff_vs_sparse"][key] = float(
            np.abs(np.asarray(ref.train_losses)
                   - np.asarray(rs.train_losses)).max())
    return out


def main(full: bool = False, n_timed: int = 3, n_check: int = 4,
         tiny: bool = False) -> dict:
    if tiny:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=256, n_items=128, n_ratings=1500, n_cities=4)
    elif full:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=6524, n_items=3197, n_ratings=26186, n_cities=117)
    else:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=2048, n_items=1024, n_ratings=12000, n_cities=16)
    ds = synthetic_poi.generate(dcfg)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    cfg_pl = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                           beta=0.1, gamma=0.01, use_pallas=True)
    Mj = jnp.asarray(M)

    eps = {}
    eps["dense_per_batch"] = _time_epochs(
        dmf.train_epoch_dense, dmf.init_state(cfg), n_timed, cfg, ds.train, Mj)
    eps["sparse_scan"] = _time_epochs(
        dmf.train_epoch, dmf.init_state(cfg), n_timed, cfg, ds.train, nbr)
    eps["sparse_scan_pallas"] = _time_epochs(
        dmf.train_epoch, dmf.init_state(cfg_pl), n_timed, cfg_pl, ds.train, nbr)

    # loss-trajectory equivalence: fast paths vs the dense reference
    rd = dmf.fit(cfg, ds.train, M, epochs=n_check, test=ds.test,
                 dense_reference=True)
    rs = dmf.fit(cfg, ds.train, nbr, epochs=n_check, test=ds.test)
    rp = dmf.fit(cfg_pl, ds.train, nbr, epochs=n_check, test=ds.test)

    def _maxdiff(a, b):
        return float(np.abs(np.asarray(a) - np.asarray(b)).max())

    res = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": cfg.dim,
            "n_neighbors": gcfg.n_neighbors, "walk_length": gcfg.walk_length,
            "n_train": int(len(ds.train)), "batch_size": cfg.batch_size,
            "neighbor_table_width_S": int(nbr.idx.shape[1]),
        },
        "epochs_per_sec": eps,
        "speedup_sparse_vs_dense": eps["sparse_scan"] / eps["dense_per_batch"],
        "speedup_pallas_vs_dense": eps["sparse_scan_pallas"] / eps["dense_per_batch"],
        "train_loss_max_diff_sparse": _maxdiff(rd.train_losses, rs.train_losses),
        "test_loss_max_diff_sparse": _maxdiff(rd.test_losses, rs.test_losses),
        "train_loss_max_diff_pallas": _maxdiff(rd.train_losses, rp.train_losses),
        "test_loss_max_diff_pallas": _maxdiff(rd.test_losses, rp.test_losses),
        "train_losses_dense": rd.train_losses,
        "train_losses_sparse": rs.train_losses,
    }
    res["sharded"] = sharded_section(
        full, tiny, n_timed=max(1, n_timed - 1), n_check=min(n_check, 3))
    common.save_json("BENCH_dmf_train", res)   # mirrors to repo root
    return res


if __name__ == "__main__":
    r = main()
    print(json.dumps({k: v for k, v in r.items()
                      if not k.startswith("train_losses")}, indent=1))
