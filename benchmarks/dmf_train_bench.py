"""DMF training-path benchmark: seed dense per-batch loop vs the
sparse-neighborhood scan epoch vs sparse-scan + fused Pallas step.

Measures epochs/sec at a Foursquare-scale synthetic config (default
I=2048, J=1024, K=10, N=2, D=3 — the perf-trajectory anchor) and checks
the train/test loss trajectories of the fast paths against the dense
reference (must agree within 1e-4). Writes ``BENCH_dmf_train.json`` to
benchmarks/results/ and the repo root.

    PYTHONPATH=src python -m benchmarks.dmf_train_bench
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi


def _time_epochs(epoch_fn, state, n_timed: int, cfg, train, prop):
    """Warm up one epoch (jit/compile), then time n_timed epochs."""
    rng = np.random.default_rng(123)
    state, _ = epoch_fn(state, prop, train, cfg, rng)
    jax.block_until_ready(state.U)
    t0 = time.perf_counter()
    for _ in range(n_timed):
        state, _ = epoch_fn(state, prop, train, cfg, rng)
    jax.block_until_ready(state.U)
    dt = time.perf_counter() - t0
    return n_timed / dt


def main(full: bool = False, n_timed: int = 3, n_check: int = 4) -> dict:
    if full:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=6524, n_items=3197, n_ratings=26186, n_cities=117)
    else:
        dcfg = synthetic_poi.POIDatasetConfig(
            n_users=2048, n_items=1024, n_ratings=12000, n_cities=16)
    ds = synthetic_poi.generate(dcfg)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    cfg_pl = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                           beta=0.1, gamma=0.01, use_pallas=True)
    Mj = jnp.asarray(M)

    eps = {}
    eps["dense_per_batch"] = _time_epochs(
        dmf.train_epoch_dense, dmf.init_state(cfg), n_timed, cfg, ds.train, Mj)
    eps["sparse_scan"] = _time_epochs(
        dmf.train_epoch, dmf.init_state(cfg), n_timed, cfg, ds.train, nbr)
    eps["sparse_scan_pallas"] = _time_epochs(
        dmf.train_epoch, dmf.init_state(cfg_pl), n_timed, cfg_pl, ds.train, nbr)

    # loss-trajectory equivalence: fast paths vs the dense reference
    rd = dmf.fit(cfg, ds.train, M, epochs=n_check, test=ds.test,
                 dense_reference=True)
    rs = dmf.fit(cfg, ds.train, nbr, epochs=n_check, test=ds.test)
    rp = dmf.fit(cfg_pl, ds.train, nbr, epochs=n_check, test=ds.test)

    def _maxdiff(a, b):
        return float(np.abs(np.asarray(a) - np.asarray(b)).max())

    res = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": cfg.dim,
            "n_neighbors": gcfg.n_neighbors, "walk_length": gcfg.walk_length,
            "n_train": int(len(ds.train)), "batch_size": cfg.batch_size,
            "neighbor_table_width_S": int(nbr.idx.shape[1]),
        },
        "epochs_per_sec": eps,
        "speedup_sparse_vs_dense": eps["sparse_scan"] / eps["dense_per_batch"],
        "speedup_pallas_vs_dense": eps["sparse_scan_pallas"] / eps["dense_per_batch"],
        "train_loss_max_diff_sparse": _maxdiff(rd.train_losses, rs.train_losses),
        "test_loss_max_diff_sparse": _maxdiff(rd.test_losses, rs.test_losses),
        "train_loss_max_diff_pallas": _maxdiff(rd.train_losses, rp.train_losses),
        "test_loss_max_diff_pallas": _maxdiff(rd.test_losses, rp.test_losses),
        "train_losses_dense": rd.train_losses,
        "train_losses_sparse": rs.train_losses,
    }
    common.save_json("BENCH_dmf_train", res)   # mirrors to repo root
    return res


if __name__ == "__main__":
    r = main()
    print(json.dumps({k: v for k, v in r.items()
                      if not k.startswith("train_losses")}, indent=1))
