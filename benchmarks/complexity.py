"""§Complexity: measured communication bytes and per-epoch update cost vs
|O| — the paper's claim is both are linear in the training-set size (for
fixed small N, D, K)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import dmf, graph
from repro.data import synthetic_poi


def main(full: bool = False):
    sizes = [1500, 3000, 4500] if not full else [6000, 12000, 24000]
    rows = []
    for n_r in sizes:
        cfg_d = synthetic_poi.POIDatasetConfig(
            n_users=400, n_items=300, n_ratings=n_r, n_cities=10, seed=0
        )
        ds = synthetic_poi.generate(cfg_d)
        gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
        W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
        nbr = graph.walk_neighbor_table(W, gcfg)   # convert once, not per epoch
        K = 10
        comm = graph.communication_bytes(W, D=3, K=K, n_ratings=len(ds.train))
        cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=K,
                            beta=0.1, gamma=0.01)
        rng = np.random.default_rng(0)
        state = dmf.init_state(cfg, rng)
        state, _ = dmf.train_epoch(state, nbr, ds.train, cfg, rng)  # warmup/jit
        t0 = time.perf_counter()
        state, _ = dmf.train_epoch(state, nbr, ds.train, cfg, rng)
        dt = time.perf_counter() - t0
        rows.append({
            "n_train": int(len(ds.train)),
            "comm_bytes_per_epoch": int(comm),
            "epoch_seconds": round(dt, 3),
        })
    # linearity check: bytes/|O| and sec/|O| roughly constant
    ratios_b = [r["comm_bytes_per_epoch"] / r["n_train"] for r in rows]
    ratios_t = [r["epoch_seconds"] / r["n_train"] for r in rows]
    return {
        "rows": rows,
        "comm_linear": bool(max(ratios_b) < 2.5 * min(ratios_b)),
        "compute_linear": bool(max(ratios_t) < 2.5 * min(ratios_t)),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(main(), indent=1))
