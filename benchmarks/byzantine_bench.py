"""Byzantine-robustness benchmark: attack injection vs receiver-side
defenses on the gradient-exchange channel (ISSUE 9 tentpole).

Three questions, answered on the synthetic Foursquare config:

1. **Attack × defense grid** — final train loss for every attack family
   (NaN bomb, norm inflation, sign flip, targeted shilling) under no
   defense, screening only, and screening + trimmed-mean aggregation, at
   20% malicious learners. Non-finite collapses are recorded as
   ``final_train_loss: null`` + ``nonfinite: true`` (the sentinel halts
   them), never as NaN in the JSON.
2. **Headline contract** — undefended norm-inflation must collapse the
   run (loss ratio ≥ 5× fault-free, or outright non-finite) while the
   screened + trimmed run at the same 20% malicious stays within 1.5× of
   fault-free; screening itself must cost ≤ 15% epoch throughput.
3. **DP interaction** — with the mechanism on, the screening cap τ is
   calibrated from (dp_clip, dp_sigma) via `privacy.screening_threshold`
   so HONEST noised messages pass (pass rate replayed over an observed
   message log), and the defended-under-attack loss stays bounded.

Writes ``BENCH_byzantine.json`` (repo root + benchmarks/results mirror):

    PYTHONPATH=src python -m benchmarks.run --only byzantine

CI runs the assertion-only fast path (no JSON written):

    PYTHONPATH=src python -m benchmarks.byzantine_bench --byzantine-smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.privacy import audit, screening_threshold
from repro.robustness.byzantine import AttackConfig, DefenseConfig

FRAC = 0.2           # malicious fraction the headline contract is stated at
INFLATE = 100.0      # norm-inflation factor λ for the collapse demonstration
FAMILIES = ("nan", "norm_inflate", "sign_flip", "shill")


def _defenses(tau: float) -> dict:
    return {
        "undefended": None,
        "screen": DefenseConfig(screen=True, norm_cap=tau),
        "screen_trim": DefenseConfig(screen=True, norm_cap=tau,
                                     aggregation="trim", trim_frac=0.25),
    }


def _attack(family: str, seed: int = 11) -> AttackConfig:
    scale = INFLATE if family == "norm_inflate" else 5.0
    return AttackConfig(family=family, frac=FRAC, scale=scale,
                        target_item=0, seed=seed)


def _fit_row(cfg, train, nbr, epochs, anchor_loss, attack, defense):
    """One grid point, divergence-safe: the sentinel halts a collapsed run
    and the row reports null loss + the halt epoch instead of NaN."""
    res = dmf.fit(cfg, train, nbr, epochs=epochs, attack=attack,
                  defense=defense, on_nonfinite="halt")
    loss = float(res.train_losses[-1])
    nonfinite = not np.isfinite(loss) or res.diverged_at is not None
    return {
        "final_train_loss": None if nonfinite else loss,
        "loss_ratio_vs_faultfree": None if nonfinite else loss / anchor_loss,
        "nonfinite": bool(nonfinite),
        "halted_at": res.diverged_at,
    }


def _time_epochs(cfg, train, nbr, n_timed, variants, repeats=3):
    """Best-of-``repeats`` epochs/sec per variant through full `fit` runs,
    so the byz host precompute (attack realization, bucket assignment) is
    inside the measured path — that IS the defense's overhead story.
    Variants are interleaved round-robin inside each repeat: container CPU
    shares drift on a minutes scale, and timing each variant as its own
    back-to-back block skewed the overhead ratio by up to ~30% run-to-run;
    inside one round-robin cycle every variant sees the same conditions."""
    best = {name: float("inf") for name in variants}
    for defense in variants.values():                                # warm
        res = dmf.fit(cfg, train, nbr, epochs=1, defense=defense)
        jax.block_until_ready(res.state.U)
    for _ in range(repeats):
        for name, defense in variants.items():
            t0 = time.perf_counter()
            res = dmf.fit(cfg, train, nbr, epochs=n_timed, defense=defense)
            jax.block_until_ready(res.state.U)
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: n_timed / b for name, b in best.items()}


def main(full: bool = False, tiny: bool = False, n_timed: int = 4,
         epochs: int | None = None) -> dict:
    if tiny:
        ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
            n_users=192, n_items=96, n_ratings=1200, n_cities=4))
        epochs = epochs or 6
    else:
        ds = synthetic_poi.foursquare_like(reduced=not full)
        epochs = epochs or (60 if full else 30)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)

    # fault-free anchor; byz-kwargs-off must reproduce it bit-exactly (the
    # live wiring check mirroring churn_bench's trivial-plan anchor)
    plain = dmf.fit(cfg, ds.train, nbr, epochs=epochs)
    anchor_loss = float(plain.train_losses[-1])
    off = dmf.fit(cfg, ds.train, nbr, epochs=epochs, attack=None,
                  defense=None)
    anchor_gap = float(off.train_losses[-1] - anchor_loss)

    # without DP there is no mechanism to calibrate against: the grid uses
    # an empirical cap from the honest message stream (p99.9 honest norm —
    # an operator-chosen cap, exactly what a deployment without DP has)
    log = audit.observe_messages(cfg, ds.train, nbr, epochs=1, seed=0)
    tau = float(np.quantile(np.linalg.norm(log.gp, axis=1), 0.999) * 1.5)

    grid = []
    for family in FAMILIES:
        for dname, dfn in _defenses(tau).items():
            row = {"family": family, "defense": dname, "frac": FRAC,
                   **_fit_row(cfg, ds.train, nbr, epochs, anchor_loss,
                              _attack(family), dfn)}
            grid.append(row)

    def _cell(family, defense):
        return next(r for r in grid
                    if r["family"] == family and r["defense"] == defense)

    und = _cell("norm_inflate", "undefended")
    dfd = _cell("norm_inflate", "screen_trim")
    undefended_collapsed = bool(
        und["nonfinite"] or und["loss_ratio_vs_faultfree"] >= 5.0)
    defended_ok = bool(
        not dfd["nonfinite"] and dfd["loss_ratio_vs_faultfree"] <= 1.5)

    # screening overhead: defense on (no attack), against the plain scan
    eps = _time_epochs(cfg, ds.train, nbr, n_timed, {
        "sparse_scan": None,
        "screen": DefenseConfig(screen=True, norm_cap=tau),
        "screen_trim": DefenseConfig(screen=True, norm_cap=tau,
                                     aggregation="trim", trim_frac=0.25),
    })
    eps_plain, eps_screen, eps_trim = (
        eps["sparse_scan"], eps["screen"], eps["screen_trim"])
    screening_overhead = eps_plain / eps_screen - 1.0

    # DP interaction: calibrated τ keeps honest noised traffic flowing
    # while the defended attacked run stays bounded
    dp_cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                           beta=0.1, gamma=0.01, dp_sigma=0.5, dp_clip=1.0,
                           dp_seed=3)
    dp_tau = screening_threshold(dp_cfg, dp_cfg.dim, reject_prob=1e-6)
    dp_log = audit.observe_messages(dp_cfg, ds.train, nbr, epochs=1, seed=0)
    dp_screen = audit.screening_report(dp_log, dp_tau, reject_prob=1e-6)
    dp_anchor = dmf.fit(dp_cfg, ds.train, nbr, epochs=epochs)
    dp_defended = _fit_row(
        dp_cfg, ds.train, nbr, epochs, float(dp_anchor.train_losses[-1]),
        _attack("norm_inflate"),
        DefenseConfig(screen=True, norm_cap=dp_tau,
                      aggregation="trim", trim_frac=0.25))

    res = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": 10,
            "n_train": int(len(ds.train)), "epochs": epochs,
            "malicious_frac": FRAC, "inflate_scale": INFLATE,
            "families": list(FAMILIES), "norm_cap": tau,
        },
        "anchor": {
            "train_loss_final": anchor_loss,
            "byz_off_gap": anchor_gap,     # must be exactly 0.0
        },
        "grid": grid,
        "headline": {
            "undefended_collapse_ratio": und["loss_ratio_vs_faultfree"],
            "undefended_nonfinite": und["nonfinite"],
            "undefended_collapsed": undefended_collapsed,
            "defended_ratio": dfd["loss_ratio_vs_faultfree"],
            "defended_within_1p5x": defended_ok,
        },
        "epochs_per_sec": {
            "sparse_scan": eps_plain,
            "screen": eps_screen,
            "screen_trim": eps_trim,
        },
        "screening_overhead_vs_base": screening_overhead,
        "robust_agg_overhead_vs_base": eps_plain / eps_trim - 1.0,
        "dp_interaction": {
            "dp_sigma": dp_cfg.dp_sigma, "dp_clip": dp_cfg.dp_clip,
            "tau_calibrated": dp_tau,
            "honest_pass_rate": dp_screen["pass_rate"],
            "calibrated_reject_prob": dp_screen["calibrated_reject_prob"],
            "defended_ratio": dp_defended["loss_ratio_vs_faultfree"],
            "defended_nonfinite": dp_defended["nonfinite"],
        },
    }
    common.save_json("BENCH_byzantine", res)   # mirrors to repo root
    return res


def byzantine_smoke() -> dict:
    """The CI fast path: toy sizes, assertions live, nothing written."""
    res = main(tiny=True, n_timed=1, epochs=5)
    assert res["anchor"]["byz_off_gap"] == 0.0, (
        "byz-kwargs-off drifted from the plain run")
    assert res["headline"]["undefended_collapsed"], (
        "undefended norm inflation failed to collapse training")
    assert res["headline"]["defended_within_1p5x"], (
        "screen+trim defense failed its 1.5x envelope")
    nan_def = next(r for r in res["grid"] if r["family"] == "nan"
                   and r["defense"] == "screen")
    assert not nan_def["nonfinite"], "screening let a NaN bomb through"
    assert res["dp_interaction"]["honest_pass_rate"] >= 0.999, (
        "calibrated tau rejects honest DP traffic")
    return {
        "headline": res["headline"],
        "screening_overhead_vs_base": res["screening_overhead_vs_base"],
        "dp_interaction": res["dp_interaction"],
        "ok": True,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="full-scale dataset + more epochs")
    ap.add_argument("--tiny", action="store_true",
                    help="toy sizes (bench smoke scale)")
    ap.add_argument("--byzantine-smoke", action="store_true",
                    help="toy-scale run with the headline assertions live; "
                         "JSON artifact restored afterwards (CI)")
    cli = ap.parse_args()
    if cli.byzantine_smoke:
        import unittest.mock as _mock
        # keep the committed BENCH_byzantine.json untouched during smoke
        with _mock.patch.object(common, "save_json", lambda *a, **k: None):
            print(json.dumps(byzantine_smoke(), indent=1))
    else:
        print(json.dumps(main(full=cli.full, tiny=cli.tiny), indent=1))
