"""Robustness benchmark: training under learner churn + stale gradient
exchange, and the cost of crash-consistency (ISSUE 6 tentpole).

Three questions, answered on the synthetic Foursquare config:

1. **Degradation surface** — final train/test loss and ranking metrics
   over a dropout × staleness grid (plus a late-joiner point), each
   against the fault-free anchor: how much accuracy does realistic fleet
   availability cost? The no-churn grid point doubles as a wiring check —
   it must reproduce the fault-free run exactly (loss_gap == 0).
2. **Churn-path overhead** — epochs/sec of the fault-injected epoch
   (gates + delay-ring delivery) vs the plain sparse scan, and the cost
   of checkpointing every epoch on top.
3. **Resume exactness** — run with periodic snapshots, "crash", resume:
   the continued run must be bit-identical (DP on), reported as a bool.

Writes ``BENCH_churn.json`` (repo root + benchmarks/results mirror):

    PYTHONPATH=src python -m benchmarks.run --only robustness
"""
from __future__ import annotations

import json
import tempfile
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.robustness import ChurnConfig

# dropout × max-staleness grid; (0, 0) is the fault-free anchor the gaps
# are measured against. 0.3/2 is the envelope the test suite pins.
DROPOUTS = (0.0, 0.1, 0.3)
STALENESS = (0, 1, 2)


def _grid_point(cfg, train, nbr, ds, epochs, dropout, k_max, base=None,
                **churn_kw):
    # every grid point runs the churn path — the (0, 0) anchor with the
    # TRIVIAL plan, so its gap-vs-plain is a live bit-exactness check
    churn = ChurnConfig(dropout=dropout,
                        delay_classes=tuple(range(k_max + 1)),
                        seed=17, **churn_kw)
    res = dmf.fit(cfg, train, nbr, epochs=epochs, test=ds.test, churn=churn)
    ev = dmf.evaluate(res.state, train, ds.test, ds.n_users, ds.n_items)
    plan = churn.compile(cfg.n_users, epochs)
    row = {
        "dropout": dropout,
        "k_max": k_max,
        "participation_rate": plan.participation_rate,
        "train_loss_final": float(res.train_losses[-1]),
        "test_loss_final": float(res.test_losses[-1]),
        **{k: float(v) for k, v in ev.items()},
    }
    if base is not None:
        row["loss_gap_vs_faultfree"] = float(
            res.train_losses[-1] - base["train_loss_final"])
    return row, res


def _time_epochs(cfg, train, nbr, n_timed, repeats=3, churn=None,
                 checkpoint_every=0):
    """Best-of-``repeats`` epochs/sec (erratic container CPU shares — see
    privacy_bench), full `fit` runs so churn compilation, ring carry and
    checkpoint I/O are all inside the measured path."""
    best = float("inf")
    with tempfile.TemporaryDirectory() as td:
        kw = {}
        if checkpoint_every:
            kw = {"checkpoint_dir": td, "checkpoint_every": checkpoint_every}
        res = dmf.fit(cfg, train, nbr, epochs=1, churn=churn, **kw)  # warm
        jax.block_until_ready(res.state.U)
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = dmf.fit(cfg, train, nbr, epochs=n_timed, churn=churn, **kw)
            jax.block_until_ready(res.state.U)
            best = min(best, time.perf_counter() - t0)
    return n_timed / best


def main(full: bool = False, tiny: bool = False, n_timed: int = 4,
         epochs: int | None = None) -> dict:
    if tiny:
        ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
            n_users=192, n_items=96, n_ratings=1200, n_cities=4))
        epochs = epochs or 6
    else:
        ds = synthetic_poi.foursquare_like(reduced=not full)
        epochs = epochs or (60 if full else 30)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)

    grid = []
    base = None
    for dropout in DROPOUTS:
        for k_max in STALENESS:
            row, _ = _grid_point(cfg, ds.train, nbr, ds, epochs, dropout,
                                 k_max, base=base)
            if base is None:                 # (0, 0): the fault-free anchor
                base = row
                # wiring check: the trivial plan must BE the plain run —
                # a nonzero gap here means the churn path drifted
                plain = dmf.fit(cfg, ds.train, nbr, epochs=epochs,
                                test=ds.test)
                row["loss_gap_vs_faultfree"] = float(
                    row["train_loss_final"] - plain.train_losses[-1])
            grid.append(row)
    late, _ = _grid_point(cfg, ds.train, nbr, ds, epochs, 0.1, 1, base=base,
                          late_frac=0.25, late_by=0.5)
    late["late_frac"] = 0.25

    # resume exactness with DP on: full run vs crash-at-midpoint + resume
    dp_cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                           beta=0.1, gamma=0.01, dp_sigma=0.5, dp_clip=0.25)
    cc = ChurnConfig(dropout=0.2, delay_classes=(0, 1, 2), seed=17)
    r_epochs = max(4, epochs // 4)
    mid = r_epochs // 2
    with tempfile.TemporaryDirectory() as td:
        whole = dmf.fit(dp_cfg, ds.train, nbr, epochs=r_epochs, churn=cc,
                        checkpoint_dir=td, checkpoint_every=mid)
        resumed = dmf.fit(dp_cfg, ds.train, nbr, epochs=r_epochs, churn=cc,
                          resume_from=f"{td}/step_{mid}")
    bit_identical = bool(
        whole.train_losses == resumed.train_losses
        and (np.asarray(whole.state.U) == np.asarray(resumed.state.U)).all()
        and (np.asarray(whole.state.P) == np.asarray(resumed.state.P)).all()
        and whole.privacy == resumed.privacy)

    # overheads: churn gates + ring vs plain scan; checkpoint-every-epoch
    eps_plain = _time_epochs(cfg, ds.train, nbr, n_timed)
    eps_churn = _time_epochs(cfg, ds.train, nbr, n_timed,
                             churn=ChurnConfig(dropout=0.2,
                                               delay_classes=(0, 1, 2),
                                               seed=17))
    eps_ckpt = _time_epochs(cfg, ds.train, nbr, n_timed, checkpoint_every=1)

    res = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": 10,
            "n_train": int(len(ds.train)), "epochs": epochs,
            "dropout_grid": list(DROPOUTS), "staleness_grid": list(STALENESS),
            "resume_epochs": r_epochs, "resume_crash_at": mid,
        },
        "grid": grid,
        "late_join": late,
        "resume": {
            "bit_identical_with_dp": bit_identical,
            "dp_sigma": dp_cfg.dp_sigma,
        },
        "epochs_per_sec": {
            "sparse_scan": eps_plain,
            "churn_path": eps_churn,
            "checkpoint_every_epoch": eps_ckpt,
        },
        "churn_overhead_vs_base": eps_plain / eps_churn - 1.0,
        "checkpoint_overhead_vs_base": eps_plain / eps_ckpt - 1.0,
    }
    common.save_json("BENCH_churn", res)   # mirrors to repo root
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
