"""Roofline analysis (deliverable g) — per (arch × shape), single-pod mesh.

    compute term    = step FLOPs / chip / 197e12 (bf16 peak)
    memory term     = HBM bytes / chip / 819e9
    collective term = collective bytes / chip / 50e9 (ICI per link)

Sources — and a measurement caveat that is itself a §Perf finding:
``compiled.cost_analysis()`` on the CPU backend counts each while-loop
(scan) body ONCE. The outer layer scan is calibrated away by the dry-run's
1-/2-period compiles, but the *inner* scans (blockwise-attention q/kv
loops, the per-expert MoE loop, the chunked-CE loop) make HLO FLOPs/bytes
undercount by up to ~40x (validated experimentally, see EXPERIMENTS.md
§Perf/Finding-0). The compute and memory terms are therefore ANALYTIC —
first-principles per-arch formulas below (the same napkin math the
hillclimb loop uses) — while the collective term IS taken from the
compiled HLO (corrected): no collective ops live inside the inner scans,
so the outer-scan calibration fully covers them. Raw HLO numbers are kept
in the JSON as diagnostics.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import registry
from repro.models.config import INPUT_SHAPES

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link
CHIPS = 256               # single-pod roofline
N_DATA, N_MODEL = 16, 16


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------
def param_count(cfg) -> tuple[float, float]:
    """(total params, active params per token)."""
    d = cfg.d_model
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.n_codebooks:
        emb = cfg.n_codebooks * cfg.vocab_size * d * 2
    total = act = emb
    n = cfg.n_periods
    for spec in cfg.period:
        if spec.kind in ("attn", "cross"):
            if cfg.attn_type == "mla":
                qin = cfg.q_lora_rank or d
                a = (
                    (d * cfg.q_lora_rank if cfg.q_lora_rank else 0)
                    + qin * cfg.n_heads * (cfg.head_dim + cfg.rope_head_dim)
                    + d * (cfg.kv_lora_rank + cfg.rope_head_dim)
                    + cfg.kv_lora_rank * cfg.n_heads * (cfg.head_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d
                )
            else:
                a = d * cfg.n_heads * cfg.head_dim * 2 + d * cfg.n_kv_heads * cfg.head_dim * 2
            total += n * a
            act += n * a
        else:  # mamba
            di = cfg.ssm_d_inner
            cdim = di + 2 * cfg.ssm_n_groups * cfg.ssm_d_state
            a = d * (2 * di + 2 * cfg.ssm_n_groups * cfg.ssm_d_state + cfg.ssm_n_heads)
            a += cfg.ssm_conv_width * cdim + di * d
            total += n * a
            act += n * a
        if spec.moe:
            e = 3 * d * cfg.moe_d_ff
            total += n * cfg.n_routed_experts * e
            act += n * (cfg.moe_top_k + cfg.n_shared_experts) * e
        elif cfg.d_ff:
            total += n * 3 * d * cfg.d_ff
            act += n * 3 * d * cfg.d_ff
    return float(total), float(act)


# ---------------------------------------------------------------------------
# analytic step FLOPs (global, whole step)
# ---------------------------------------------------------------------------
def _attn_core_flops_fwd(cfg, B, S, causal_eff=1.0) -> float:
    """QK^T + PV flops per full forward (all layers). causal_eff=1.0 models
    the baseline blockwise schedule (masked upper triangle still computed);
    0.5 is the triangular-schedule optimum."""
    fl = 0.0
    for spec in cfg.period:
        if spec.kind == "attn":
            if cfg.attn_type == "mla":
                hd = cfg.head_dim + cfg.rope_head_dim
                vd = cfg.v_head_dim
            else:
                hd = cfg.head_dim
                vd = cfg.v_head_dim
            fl += cfg.n_periods * 2 * B * S * S * cfg.n_heads * (hd + vd) * causal_eff
        elif spec.kind == "cross":
            M = cfg.n_image_tokens
            fl += cfg.n_periods * 2 * B * S * M * cfg.n_heads * (cfg.head_dim + cfg.v_head_dim)
        else:  # SSD: intra-chunk (quadratic in chunk) + state path
            H, P, N, Q = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state, cfg.ssm_chunk
            # y_diag ~ 2*B*S*Q*H*P + CB ~ 2*B*S*Q*N*H ; states/off ~ 6*B*S*H*P*N
            fl += cfg.n_periods * B * S * H * (2 * Q * (P + N) + 6 * P * N)
    return fl


def _attn_decode_flops(cfg, B, S) -> float:
    """Per-token attention/SSM flops against an S-long context."""
    fl = 0.0
    for spec in cfg.period:
        if spec.kind == "attn":
            Se = min(S, spec.sliding_window) if spec.sliding_window else S
            if cfg.attn_type == "mla":  # absorbed: scores in latent space
                r = cfg.kv_lora_rank + cfg.rope_head_dim
                fl += cfg.n_periods * B * cfg.n_heads * Se * (2 * r + 2 * cfg.kv_lora_rank)
            else:
                fl += cfg.n_periods * 2 * B * cfg.n_heads * Se * (cfg.head_dim + cfg.v_head_dim)
        elif spec.kind == "cross":
            M = cfg.n_image_tokens
            fl += cfg.n_periods * 2 * B * cfg.n_heads * M * (cfg.head_dim + cfg.v_head_dim)
        else:
            H, P, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_d_state
            fl += cfg.n_periods * 6 * B * H * P * N
    return fl


def analytic_flops(cfg, shape) -> dict:
    _, active = param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    ce = 0.5 if getattr(cfg, "triangular_attention", False) else 1.0
    if shape.kind == "train":
        T = B * S
        # fwd 2NT + remat-fwd 2NT + bwd 4NT = 8NT matmul + attn (fwd+remat+2*bwd)
        mat = 8.0 * active * T
        attn = _attn_core_flops_fwd(cfg, B, S, ce) * 4.0
        useful = 6.0 * active * T + _attn_core_flops_fwd(cfg, B, S, 0.5) * 3.0
    elif shape.kind == "prefill":
        T = B * S
        mat = 2.0 * active * T
        attn = _attn_core_flops_fwd(cfg, B, S, ce)
        useful = 2.0 * active * T + _attn_core_flops_fwd(cfg, B, S, 0.5)
    else:
        mat = 2.0 * active * B
        attn = _attn_decode_flops(cfg, B, S)
        useful = mat + attn
    return {"total": mat + attn, "useful": useful, "matmul": mat, "attn": attn}


# ---------------------------------------------------------------------------
# analytic HBM bytes per device
# ---------------------------------------------------------------------------
def kv_cache_bytes(cfg, B, S) -> float:
    """Global decode-cache bytes (bf16)."""
    by = 0.0
    for spec in cfg.period:
        if spec.kind == "attn":
            Se = min(S, spec.sliding_window) if spec.sliding_window else S
            if cfg.attn_type == "mla":
                by += cfg.n_periods * B * Se * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
            else:
                by += cfg.n_periods * B * Se * cfg.n_kv_heads * (cfg.head_dim + cfg.v_head_dim) * 2
        elif spec.kind == "cross":
            by += cfg.n_periods * B * cfg.n_image_tokens * cfg.n_kv_heads * 2 * cfg.head_dim * 2
        else:
            by += cfg.n_periods * B * (
                cfg.ssm_n_heads * cfg.ssm_head_dim * cfg.ssm_d_state * 4  # f32 state
                + (cfg.ssm_conv_width - 1) * (cfg.ssm_d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_d_state) * 2
            )
    return by


def analytic_bytes_per_device(cfg, shape, chips=CHIPS, n_data=N_DATA,
                              n_model=N_MODEL) -> dict:
    total_p, _ = param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    if shape.kind == "train":
        T_loc = B * S / (chips / n_model)  # tokens per batch shard
        p_loc = total_p / chips           # fsdp+tp sharded
        # params: bf16 read (fwd+remat+bwd: 3x after per-layer all-gather),
        # grads f32 w+r, master f32 r+w, adam m,v r+w
        param_traffic = p_loc * (2 * 3 + 4 * 2 + 4 * 2 + 4 * 4)
        # activations: residual stream saves w+r (remat boundary) + per-layer
        # working set ~10 d-sized tensors streamed through HBM, /model shard
        act_traffic = (T_loc * d * 2) * L / n_model * (2 + 10)
        return {"total": param_traffic + act_traffic,
                "params": param_traffic, "act": act_traffic}
    if shape.kind == "prefill":
        T_loc = B * S / (chips / n_model)
        p_loc = total_p / chips
        param_traffic = p_loc * 2
        act_traffic = (T_loc * d * 2) * L / n_model * 8
        cache = kv_cache_bytes(cfg, B, S) / chips
        return {"total": param_traffic + act_traffic + cache,
                "params": param_traffic, "act": act_traffic, "cache": cache}
    # decode: weights read every token + full cache read
    p_loc = total_p * 2 / chips           # bf16 weights, fsdp+tp resident
    cache_loc = kv_cache_bytes(cfg, B, S) / chips
    act = B / max(chips / n_model, 1) * d * L * 2 * 10
    return {"total": p_loc + cache_loc + act,
            "params": p_loc, "cache": cache_loc, "act": act}


# ---------------------------------------------------------------------------
# assembling the report
# ---------------------------------------------------------------------------
def analyze(rec: dict) -> dict:
    cfg = registry.get_config(rec["arch"])
    if rec.get("opt") == "tri":
        import dataclasses
        cfg = dataclasses.replace(cfg, triangular_attention=True)
    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["n_devices"]
    n_model = 16
    fl = analytic_flops(cfg, shape)
    by = analytic_bytes_per_device(cfg, shape, chips=n, n_data=n // n_model,
                                   n_model=n_model)
    coll = rec.get(
        "corrected_collective_bytes_per_device", rec["collective_bytes_per_device"]
    )
    coll_total = sum(coll.values())
    t_compute = fl["total"] / n / PEAK_FLOPS
    t_memory = by["total"] / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total_p, active_p = param_count(cfg)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "sync": rec.get("sync", "allreduce"),
        "params_total": total_p,
        "params_active": active_p,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "flops_total": fl["total"],
        "flops_useful": fl["useful"],
        "useful_ratio": fl["useful"] / max(fl["total"], 1.0),
        "bytes_per_device": by,
        "step_lower_bound_s": bound,
        "mfu_upper_bound": fl["useful"] / (n * PEAK_FLOPS) / max(bound, 1e-12),
        "collective_by_op": coll,
        "hlo_diag": {
            "flops_per_device_raw": rec.get("hlo_flops_per_device"),
            "flops_per_device_scan_corrected": rec.get("corrected_flops_per_device"),
            "bytes_per_device_raw": rec.get("hlo_bytes_per_device"),
        },
    }


def analytic_collective_bytes(cfg, shape, chips=CHIPS, n_data=N_DATA,
                              n_model=N_MODEL) -> dict:
    """First-principles collective traffic per device (bf16), used when no
    dry-run HLO artifacts exist: ring grad all-reduce over the data axis +
    per-layer param all-gathers over the model axis for training shapes,
    2-per-layer activation all-reduces under tensor parallelism for
    prefill/decode. Same napkin math as the compute/memory terms."""
    total_p, _ = param_count(cfg)
    if shape.kind == "train":
        ar = 2 * (n_data - 1) / n_data * (total_p / chips) * 2
        ag = (n_model - 1) / n_model * (total_p / chips) * 2 * 3  # fwd+remat+bwd
        return {"all-reduce": ar, "all-gather": ag}
    B, S = shape.global_batch, shape.seq_len
    T_loc = (B if shape.kind == "decode" else B * S) / max(chips / n_model, 1)
    ar = (2 * cfg.n_layers * T_loc * cfg.d_model * 2
          * 2 * (n_model - 1) / n_model)
    return {"all-reduce": ar}


def analytic_rows(chips=CHIPS) -> list[dict]:
    """Roofline over every registry arch × input shape with ALL terms
    analytic — the no-artifacts fallback that keeps `run.py --only
    roofline` a live entry point on a fresh checkout. Rows are tagged
    ``collective_source: analytic`` so they can't be mistaken for
    HLO-measured collectives."""
    rows = []
    for arch in registry.ARCH_IDS:
        for shape_name in INPUT_SHAPES:
            cfg = registry.get_config(arch)
            shape = INPUT_SHAPES[shape_name]
            rec = {
                "arch": arch,
                "shape": shape_name,
                "n_devices": chips,
                "sync": "allreduce",
                "collective_bytes_per_device": analytic_collective_bytes(
                    cfg, shape, chips=chips),
            }
            row = analyze(rec)
            row["collective_source"] = "analytic"
            rows.append(row)
    return rows


MEASURED_TRACE = pathlib.Path(__file__).resolve().parent / "results" / \
    "obs" / "trace.json"


def measured_rows(trace_path=MEASURED_TRACE) -> list[dict]:
    """Rows built from MEASURED host-side span timings (a Chrome-trace
    JSON written by `repro.obs.trace` — e.g. `benchmarks/obs_smoke.py` or
    `dmf_train --trace-out`). Each span name becomes one row whose compute
    term is the measured mean wall time; memory/collective terms are zero
    (a host-side span can't split them) and the row is tagged
    ``collective_source: measured_trace`` / ``timing_source: measured`` so
    it can never be mistaken for the analytic napkin math. Missing or
    unreadable trace → empty list (the analytic fallback stands alone)."""
    p = pathlib.Path(trace_path)
    if not p.exists():
        return []
    try:
        doc = json.loads(p.read_text())
        events = doc.get("traceEvents", [])
    except (json.JSONDecodeError, AttributeError):
        return []
    agg: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            agg.setdefault(ev["name"], []).append(float(ev["dur"]) / 1e6)
    rows = []
    for name, durs in sorted(agg.items()):
        mean_s = sum(durs) / len(durs)
        rows.append({
            "arch": "measured",
            "shape": name,
            "sync": "n/a",
            "t_compute_s": mean_s,
            "t_memory_s": 0.0,
            "t_collective_s": 0.0,
            "dominant": "measured",
            "useful_ratio": 1.0,
            "mfu_upper_bound": 0.0,
            "step_lower_bound_s": mean_s,
            "span_count": len(durs),
            "span_total_s": sum(durs),
            "span_max_s": max(durs),
            "collective_source": "measured_trace",
            "timing_source": "measured",
        })
    return rows


def main(mesh_tag: str = "pod", sync: str = "allreduce",
         trace_path=MEASURED_TRACE):
    rows = []
    for p in sorted(DRYRUN.glob(f"*__{mesh_tag}__{sync}.json")):
        rec = json.loads(p.read_text())
        if "error" in rec or "skipped" in rec:
            continue
        row = analyze(rec)
        row["collective_source"] = "dryrun_hlo"
        rows.append(row)
    if not rows:
        rows = analytic_rows()
    rows += measured_rows(trace_path)
    return rows


def render(rows) -> str:
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | {r['mfu_upper_bound']:.2f} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    tag = sys.argv[1] if len(sys.argv) > 1 else "pod"
    sync = sys.argv[2] if len(sys.argv) > 2 else "allreduce"
    rows = main(tag, sync)
    print(render(rows))
