"""Tables 2 & 3: P@k / R@k comparison of MF, BPR, GDMF, LDMF, DMF over
K ∈ {5, 10, 15} on Foursquare-like and Alipay-like synthetic data.

Qualitative claims validated (EXPERIMENTS.md §Paper):
  C1  DMF outperforms MF (and generally BPR);
  C2  GDMF is comparable to MF;
  C3  LDMF is by far the worst (no collaboration);
  C4  performance improves with K.
"""
from __future__ import annotations

import numpy as np

from repro.core import baselines, dmf, graph
from repro.data import synthetic_poi

# tuned per-model hypers (paper: "tune parameters of each model to achieve
# their best performance")
DMF_HP = dict(beta=0.1, gamma=0.01)
GDMF_HP = dict(beta=0.1, gamma=0.0)
LDMF_HP = dict(beta=0.0, gamma=0.01)


def run_dataset(ds, dims=(5, 10, 15), epochs=80, seeds=(0,), D=3, N=2):
    gcfg = graph.GraphConfig(n_neighbors=N, walk_length=D)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    out = {}
    for K in dims:
        rows = {}
        for seed in seeds:
            runs = {}
            for name, mode, hp in [
                ("DMF", "dmf", DMF_HP), ("GDMF", "gdmf", GDMF_HP),
                ("LDMF", "ldmf", LDMF_HP),
            ]:
                cfg = dmf.DMFConfig(
                    n_users=ds.n_users, n_items=ds.n_items, dim=K, mode=mode,
                    seed=seed, **hp,
                )
                res = dmf.fit(cfg, ds.train, M, epochs=epochs)
                runs[name] = dmf.evaluate(
                    res.state, ds.train, ds.test, ds.n_users, ds.n_items
                )
            mfc = baselines.MFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=K, seed=seed)
            st, _ = baselines.fit_mf(mfc, ds.train, epochs=epochs)
            runs["MF"] = baselines.evaluate_mf(st, ds.train, ds.test, ds.n_users, ds.n_items)
            bc = baselines.BPRConfig(n_users=ds.n_users, n_items=ds.n_items, dim=K, seed=seed)
            st2, _ = baselines.fit_bpr(bc, ds.train, epochs=epochs)
            runs["BPR"] = baselines.evaluate_mf(st2, ds.train, ds.test, ds.n_users, ds.n_items)
            for name, ev in runs.items():
                rows.setdefault(name, []).append(ev)
        out[K] = {
            name: {k: float(np.mean([e[k] for e in evs])) for k in evs[0]}
            for name, evs in rows.items()
        }
    return out


def check_claims(table) -> dict[str, bool]:
    """The paper's qualitative orderings, averaged over K."""
    def avg(model, metric):
        return np.mean([table[K][model][metric] for K in table])

    return {
        "C1_dmf_beats_mf": all(
            avg("DMF", m) > avg("MF", m) for m in ["P@5", "R@5", "P@10", "R@10"]
        ),
        "C2_gdmf_comparable_mf": all(
            avg("GDMF", m) > 0.6 * avg("MF", m) for m in ["P@5", "R@5"]
        ),
        "C3_ldmf_worst": all(
            avg("LDMF", m) < min(avg(x, m) for x in ["MF", "BPR", "GDMF", "DMF"])
            for m in ["P@5", "R@5"]
        ),
        "C4_quality_up_with_k": (
            table[max(table)]["DMF"]["R@10"] >= table[min(table)]["DMF"]["R@10"] * 0.9
        ),
    }


def main(full: bool = False, epochs: int | None = None, seeds=(0, 1)):
    results = {}
    for dsname, maker in [
        ("foursquare", synthetic_poi.foursquare_like),
        ("alipay", synthetic_poi.alipay_like),
    ]:
        ds = maker(reduced=not full)
        table = run_dataset(
            ds, epochs=epochs or (120 if full else 80), seeds=seeds
        )
        results[dsname] = {
            "table": {str(k): v for k, v in table.items()},
            "claims": check_claims(table),
            "n_users": ds.n_users, "n_items": ds.n_items,
            "n_train": len(ds.train), "n_test": len(ds.test),
        }
    return results


if __name__ == "__main__":
    import json
    print(json.dumps(main(), indent=1))
