"""Beyond-paper ablation: gossip (DMF protocol) vs centralized all-reduce on
a small LM — loss parity and consensus, quantified (EXPERIMENTS.md §Perf-B
semantics note). Runs in a subprocess with 8 host devices so the harness
itself keeps seeing the single real CPU device.

Writes ``BENCH_gossip_ablation.json`` (repo root + benchmarks/results
mirror, the `common.save_json` BENCH_* convention). The subprocess hands
its result back through a temp FILE, not stdout — the snippet previously
ended in a stray module-scope json print, making the whole bench depend
on stdout's last line staying clean (any library chatter broke the
parse)."""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import tempfile

from benchmarks import common

REPO = pathlib.Path(__file__).resolve().parents[1]

CODE = """
import json, sys
import jax, jax.numpy as jnp
from repro.configs import registry
from repro.core import gossip as gossip_lib
from repro.data.lm_pipeline import LMDataConfig, SyntheticLM
from repro.launch.train import make_train_step
from repro.models import config as mc
from repro.optim import adamw

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = mc.reduced(registry.get_config("qwen1.5-4b"), n_kv_heads=2, vocab_size=256,
                 d_model=128, d_ff=256, n_heads=4, head_dim=32)
data = SyntheticLM(LMDataConfig(vocab_size=256, seq_len=64, batch_size=16, seed=0))
out = {}
for name, sync, D in [("allreduce", "allreduce", 0), ("gossip_d1", "gossip", 1),
                      ("gossip_d2", "gossip", 2)]:
    g = gossip_lib.GossipConfig(learner_axis="data", walk_length=max(D, 1))
    step, init_fn, _ = make_train_step(cfg, mesh, adamw(6e-3), sync=sync, gossip=g)
    state = init_fn(jax.random.PRNGKey(0))
    losses = []
    cons = None
    for i in range(50):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = step(state, b)
        losses.append(round(float(m["loss"]), 4))
        if "consensus_err" in m:
            cons = round(float(m["consensus_err"]), 4)
    out[name] = {"first": losses[0], "last": losses[-1],
                 "curve10": losses[::5], "consensus_err": cons}
with open(sys.argv[1], "w") as f:
    json.dump(out, f)
"""


def main(steps: int = 50):
    import os
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(REPO / "src")}
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        out_path = pathlib.Path(tmp.name)
    try:
        res = subprocess.run(
            [sys.executable, "-c", CODE, str(out_path)], capture_output=True,
            text=True, timeout=2400, env=env)
        if res.returncode != 0:
            return {"error": res.stderr[-1500:]}
        data = json.loads(out_path.read_text())
    finally:
        out_path.unlink(missing_ok=True)
    gap = data["gossip_d1"]["last"] - data["allreduce"]["last"]
    data["gossip_minus_allreduce_final_loss"] = round(gap, 4)
    common.save_json("BENCH_gossip_ablation", data)  # mirrors to repo root
    return data


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
