"""Benchmark harness entry point — one section per paper table/figure plus
the roofline report. Prints ``name,us_per_call,derived`` CSV lines and
writes JSON artifacts to benchmarks/results/.

  PYTHONPATH=src python -m benchmarks.run            # quick (CI) sizes
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
  PYTHONPATH=src python -m benchmarks.run --only paper_tables,roofline
  PYTHONPATH=src python -m benchmarks.run --only dmf_train,serving --devices 8
                                # ^ learner-sharded sections need host devices
"""
from __future__ import annotations

import argparse
import sys
import time

# every section this harness dispatches — `--only` takes a comma-separated
# subset (whitespace tolerated) and rejects unknown names instead of
# silently running nothing
SECTIONS = (
    "paper_tables", "convergence", "reg_sweep", "walk_sweep", "dmf_train",
    "serving", "scheduler", "privacy", "robustness", "byzantine",
    "complexity",
    "gossip_ablation", "perf_report", "kernels", "roofline",
)


def _section(name):
    # marker event in the span trace (no-op while tracing is disabled);
    # repro.obs.trace imports no jax, so this is safe pre-device-flag
    from repro.obs import trace as trace_lib
    trace_lib.get_tracer().instant("bench.section", section=name)
    print(f"# --- {name} " + "-" * max(0, 60 - len(name)), flush=True)


def parse_only(spec: str) -> set | None:
    """``--only a, b`` -> {'a', 'b'}; empty/None -> run everything."""
    if not spec:
        return None
    only = {s.strip() for s in spec.split(",") if s.strip()}
    unknown = only - set(SECTIONS)
    if unknown:
        raise SystemExit(
            f"--only: unknown section(s) {sorted(unknown)}; "
            f"choose from {', '.join(SECTIONS)}")
    return only


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated section list "
                         f"({', '.join(SECTIONS)}); default: all")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host-platform devices (the dmf_train/"
                         "serving `sharded` sections need 8; 0 = leave the "
                         "jax default — sharded entries are then recorded "
                         "as skipped)")
    ap.add_argument("--trace-out", default=None,
                    help="enable span tracing (repro.obs.trace) for the "
                         "whole run and write the Chrome-trace/Perfetto "
                         "JSON here at the end")
    ap.add_argument("--metrics-out", default=None,
                    help="append a final metrics-registry snapshot "
                         "(JSONL) here at the end")
    args = ap.parse_args()
    if args.devices > 0:
        # must happen before ANY jax backend init — the bench modules are
        # imported lazily below for exactly this reason (importing
        # repro.launch.mesh itself is safe: imports don't bind XLA_FLAGS)
        from repro.launch.mesh import ensure_host_platform_devices

        ensure_host_platform_devices(args.devices)
    only = parse_only(args.only)

    if args.trace_out:
        from repro.obs import trace as trace_lib
        trace_lib.configure_tracing(True)

    from benchmarks import common

    def want(name):
        return only is None or name in only

    if want("paper_tables"):
        from benchmarks import paper_tables
        _section("paper_tables (Tables 2 & 3)")
        t0 = time.perf_counter()
        res = paper_tables.main(full=args.full, seeds=(0, 1) if not args.full else (0, 1, 2))
        us = (time.perf_counter() - t0) * 1e6
        common.save_json("paper_tables", res)
        for ds, r in res.items():
            claims = " ".join(f"{k}={v}" for k, v in r["claims"].items())
            print(f"paper_tables_{ds},{us:.0f},{claims}")
            for K, models in r["table"].items():
                for m, ev in models.items():
                    print(
                        f"paper_tables_{ds}_K{K}_{m},0,"
                        f"P@5={ev['P@5']:.4f};R@5={ev['R@5']:.4f};"
                        f"P@10={ev['P@10']:.4f};R@10={ev['R@10']:.4f}"
                    )

    if want("convergence"):
        from benchmarks import convergence
        _section("convergence (Fig. 4)")
        t0 = time.perf_counter()
        res = convergence.main(full=args.full)   # saves BENCH_convergence itself
        us = (time.perf_counter() - t0) * 1e6
        for ds, r in res.items():
            print(
                f"convergence_{ds},{us:.0f},converged={r['converged']};"
                f"first={r['train_loss'][0]};last={r['train_loss'][-1]}"
            )

    if want("reg_sweep"):
        from benchmarks import reg_sweep
        _section("reg_sweep (Fig. 5)")
        t0 = time.perf_counter()
        res = reg_sweep.main(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        common.save_json("reg_sweep", res)
        print(
            f"reg_sweep,{us:.0f},best={res['best']};"
            f"sensitive={res['spread_validates_sensitivity']}"
        )

    if want("walk_sweep"):
        from benchmarks import walk_sweep
        _section("walk_sweep (Fig. 6)")
        t0 = time.perf_counter()
        res = walk_sweep.main(full=args.full)    # saves BENCH_walk_sweep itself
        us = (time.perf_counter() - t0) * 1e6
        for ds, r in res.items():
            print(
                f"walk_sweep_{ds},{us:.0f},"
                + ";".join(f"D{d}={v}" for d, v in r["R@10_by_D"].items())
                + f";stable_after_3={r['stable_after_3']}"
            )

    if want("dmf_train"):
        from benchmarks import dmf_train_bench
        _section("dmf_train (sparse-scan vs seed dense hot path)")
        t0 = time.perf_counter()
        res = dmf_train_bench.main(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        e = res["epochs_per_sec"]
        print(
            f"dmf_train,{us:.0f},"
            f"dense={e['dense_per_batch']:.3f}eps;sparse={e['sparse_scan']:.3f}eps;"
            f"pallas={e['sparse_scan_pallas']:.3f}eps;"
            f"speedup={res['speedup_sparse_vs_dense']:.1f}x;"
            f"loss_dev={res['train_loss_max_diff_sparse']:.2e}"
        )
        sh = res["sharded"]
        eps_sh = ";".join(
            f"{k}={v:.3f}eps" for k, v in sh["epochs_per_sec"].items()
            if v is not None)
        print(
            f"dmf_train_sharded,0,I={sh['config']['n_users']};"
            f"devices={sh['config']['n_devices']};{eps_sh or 'all_skipped'}"
        )

    if want("serving"):
        from benchmarks import serving_bench
        _section("serving (engine: loop vs batched vs geo-pruned)")
        t0 = time.perf_counter()
        res = serving_bench.main(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        r = res["requests_per_sec"]
        print(
            f"serving,{us:.0f},"
            f"loop={r['loop_per_request']:.1f}rps;"
            f"dense={r['batched_dense']:.1f}rps;"
            f"pruned={r['batched_pruned']:.1f}rps;"
            f"speedup_vs_loop={res['speedup_pruned_vs_loop']:.1f}x;"
            f"agree_in_bucket="
            f"{res['pruned_dense_topk_agreement_where_in_bucket']:.3f};"
            f"agree_raw={res['pruned_dense_topk_agreement']:.3f}"
        )
        sh = res["sharded"]
        rps_sh = ";".join(
            f"{k}={v:.1f}rps" for k, v in sh["requests_per_sec"].items()
            if v is not None)
        print(
            f"serving_sharded,0,devices={sh['config']['n_devices']};"
            f"{rps_sh or 'all_skipped'}"
        )
        mil = res["million"]
        mr = mil["requests_per_sec"]
        print(
            f"serving_million,0,"
            f"I={mil['config']['n_users']};J={mil['config']['n_items']};"
            f"cells={mil['index']['n_cells']};cap={mil['index']['cap']};"
            f"slab_gb={mil['resident_gb']['slab_fp32']:.2f};"
            f"fp32={mr['fp32']:.0f}rps;int8={mr['int8']:.0f}rps;"
            f"bf16={mr['bf16']:.0f}rps;"
            f"fp32_bitwise={mil['exact']['fp32_bitwise_vs_dense_engine']};"
            f"int8_delta={mil['exact']['int8']['max_abs_score_delta']:.2e}"
        )

    if want("scheduler"):
        from benchmarks import scheduler_bench
        _section("scheduler (continuous batching + SLO admission)")
        t0 = time.perf_counter()
        res = scheduler_bench.main(full=args.full)   # saves BENCH_scheduler
        us = (time.perf_counter() - t0) * 1e6
        for key, entry in res["grid"].items():
            if "skipped" in entry:
                print(f"scheduler_{key},0,skipped={entry['skipped']}")
                continue
            pts = ";".join(
                f"x{row['offered_frac_of_capacity']}:"
                f"goodput={row['scheduler']['goodput_rps']:.0f}rps:"
                f"slo={row['scheduler']['slo_attainment']:.3f}:"
                f"p50={row['scheduler']['latency_ms']['p50_ms']:.1f}ms"
                for row in entry["loads"])
            print(f"scheduler_{key},0,{pts};"
                  f"bit_identical={entry['bit_identical_vs_direct']}")
        p50 = res["p50_ms_at_max_shards"]
        ing = res["ingest_interleave"]
        print(
            f"scheduler,{us:.0f},"
            f"capacity={res['single_shard_capacity_rps']:.0f}rps;"
            f"max_shards={res['max_shards_measured']};"
            f"p50_sched={p50['scheduler']:.1f}ms;"
            f"p50_lockstep={p50['lockstep']:.1f}ms;"
            f"beats_lockstep={res['scheduler_beats_lockstep_p50_at_max_shards']};"
            f"ingest_idle={ing['ingest_ran_in_idle_gap']};"
            f"ingest_snapshots_exact="
            f"{ing['pre_ingest_bit_identical_to_no_ingest'] and ing['post_ingest_bit_identical_to_ingested_snapshot']}"
        )

    if want("privacy"):
        from benchmarks import privacy_bench
        _section("privacy (DP exchange: eps-utility frontier + audit)")
        t0 = time.perf_counter()
        res = privacy_bench.main(full=args.full)   # saves BENCH_privacy itself
        us = (time.perf_counter() - t0) * 1e6
        fr = res["frontier"]
        pts = ";".join(
            f"eps={'inf' if r['eps'] is None else round(r['eps'], 2)}:"
            f"P@10={r['P@10']:.4f}:adv={r['rating_inversion_advantage']:.3f}"
            for r in fr)
        print(
            f"privacy,{us:.0f},{pts};"
            f"monotone={res['attack_advantage_monotone_nonincreasing']};"
            f"dp_overhead_fused="
            f"{res['dp_overhead_fused_vs_pallas_base']:.3f}"
        )

    if want("robustness"):
        from benchmarks import churn_bench
        _section("robustness (churn/staleness degradation + crash-resume)")
        t0 = time.perf_counter()
        res = churn_bench.main(full=args.full)   # saves BENCH_churn itself
        us = (time.perf_counter() - t0) * 1e6
        worst = max(res["grid"][1:],
                    key=lambda r: abs(r["loss_gap_vs_faultfree"]))
        print(
            f"robustness,{us:.0f},"
            f"anchor_gap={res['grid'][0]['loss_gap_vs_faultfree']:.2e};"
            f"worst_gap=p{worst['dropout']}k{worst['k_max']}:"
            f"{worst['loss_gap_vs_faultfree']:.4f};"
            f"resume_bit_identical={res['resume']['bit_identical_with_dp']};"
            f"churn_overhead={res['churn_overhead_vs_base']:.3f};"
            f"ckpt_overhead={res['checkpoint_overhead_vs_base']:.3f}"
        )

    if want("byzantine"):
        from benchmarks import byzantine_bench
        _section("byzantine (attack injection vs screening/robust agg)")
        t0 = time.perf_counter()
        res = byzantine_bench.main(full=args.full)  # saves BENCH_byzantine
        us = (time.perf_counter() - t0) * 1e6
        h = res["headline"]
        ratio = h["undefended_collapse_ratio"]
        print(
            f"byzantine,{us:.0f},"
            f"anchor_gap={res['anchor']['byz_off_gap']:.2e};"
            f"undefended="
            f"{'nonfinite' if h['undefended_nonfinite'] else f'{ratio:.1f}x'};"
            f"collapsed={h['undefended_collapsed']};"
            f"defended={h['defended_ratio']:.3f}x;"
            f"within_1p5x={h['defended_within_1p5x']};"
            f"screen_overhead={res['screening_overhead_vs_base']:.3f};"
            f"trim_overhead={res['robust_agg_overhead_vs_base']:.3f};"
            f"dp_pass_rate={res['dp_interaction']['honest_pass_rate']:.4f}"
        )

    if want("complexity"):
        from benchmarks import complexity
        _section("complexity (paper §Complexity)")
        t0 = time.perf_counter()
        res = complexity.main(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        common.save_json("complexity", res)
        print(
            f"complexity,{us:.0f},comm_linear={res['comm_linear']};"
            f"compute_linear={res['compute_linear']}"
        )

    if want("gossip_ablation"):
        from benchmarks import gossip_ablation
        _section("gossip_ablation (beyond-paper: DMF sync at LM scale)")
        t0 = time.perf_counter()
        res = gossip_ablation.main()     # saves BENCH_gossip_ablation itself
        us = (time.perf_counter() - t0) * 1e6
        if "error" in res:
            print(f"gossip_ablation,{us:.0f},ERROR")
        else:
            print(
                f"gossip_ablation,{us:.0f},"
                f"allreduce={res['allreduce']['last']};"
                f"gossip_d1={res['gossip_d1']['last']};"
                f"gossip_d2={res['gossip_d2']['last']};"
                f"gap={res['gossip_minus_allreduce_final_loss']};"
                f"consensus_err={res['gossip_d1']['consensus_err']}"
            )

    if want("perf_report"):
        from benchmarks import perf_report
        _section("perf_report (§Perf before/after)")
        for line in perf_report.render(perf_report.main()).splitlines():
            print(line)

    if want("kernels"):
        from benchmarks import kernels_bench
        _section("kernels (Pallas vs ref)")
        for name, us, extra in kernels_bench.main():
            print(f"{name},{us:.0f},{extra}")

    if want("roofline"):
        from benchmarks import roofline
        _section("roofline (dry-run artifacts, analytic fallback)")
        rows = roofline.main()
        common.save_json("roofline", rows)
        for r in rows:
            print(
                f"roofline_{r['arch']}_{r['shape']},0,"
                f"compute={r['t_compute_s']:.3e};memory={r['t_memory_s']:.3e};"
                f"collective={r['t_collective_s']:.3e};dominant={r['dominant']};"
                f"useful={r['useful_ratio']:.2f};src={r['collective_source']}"
            )

    if args.trace_out:
        from repro.obs import trace as trace_lib
        trace_lib.get_tracer().export_chrome_trace(args.trace_out)
        print(f"# trace written to {args.trace_out} "
              f"({len(trace_lib.get_tracer().events())} events)", flush=True)
    if args.metrics_out:
        from repro.obs import metrics as obs_metrics
        obs_metrics.get_registry().write_jsonl(args.metrics_out,
                                               event="bench_run_final")
        print(f"# metrics snapshot appended to {args.metrics_out}",
              flush=True)


if __name__ == "__main__":
    main()
