"""Kernel micro-bench: Pallas (interpret on CPU; compiled on TPU) vs the
pure-jnp oracle — correctness deltas + call timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, reps=3):
    f(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    rng = np.random.default_rng(0)
    rows = []

    B, K = 2048, 16
    u, p, q = (jnp.asarray(rng.normal(size=(B, K)), jnp.float32) for _ in range(3))
    r = jnp.asarray(rng.random(B), jnp.float32)
    c = jnp.asarray(rng.random(B), jnp.float32)
    f_k = lambda: ops.dmf_grads(u, p, q, r, c, alpha=0.1, beta=0.01, gamma=0.01)
    f_r = lambda: ref.dmf_grads_ref(u, p, q, r, c, 0.1, 0.01, 0.01)
    err = max(
        float(jnp.abs(a - b).max()) for a, b in zip(f_k(), f_r())
    )
    rows.append(("dmf_grads_kernel", _time(f_k), f"max_err={err:.2e}"))
    rows.append(("dmf_grads_ref", _time(f_r), ""))

    f_k = lambda: ops.dmf_fused_step(u, p, q, r, c, theta=0.1, alpha=0.1,
                                     beta=0.01, gamma=0.01)
    f_r = lambda: ref.dmf_fused_step_ref(u, p, q, r, c, 0.1, 0.1, 0.01, 0.01)
    err = max(
        float(jnp.abs(a - b).max()) for a, b in zip(f_k(), f_r())
    )
    rows.append(("dmf_fused_step_kernel", _time(f_k), f"max_err={err:.2e}"))
    rows.append(("dmf_fused_step_ref", _time(f_r), ""))

    I, F = 512, 1024
    M = jnp.asarray(rng.normal(size=(I, I)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(I, F)), jnp.float32)
    f_k = lambda: ops.gossip_mix_op(M, X)
    f_r = lambda: ref.gossip_mix_ref(M, X)
    err = float(jnp.abs(f_k() - f_r()).max())
    rows.append(("gossip_mix_kernel", _time(f_k), f"max_err={err:.2e}"))
    rows.append(("gossip_mix_ref", _time(f_r), ""))

    I, J, K = 256, 1024, 16
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(J, K)), jnp.float32)
    mask = jnp.asarray(rng.random((I, J)) < 0.05)
    f_k = lambda: ops.recommend_topk(U, V, mask, 10)
    f_r = lambda: ref.topk_scores_ref(U, V, mask, 10)
    vk, ik = f_k()
    vr, ir = f_r()
    err = float(jnp.abs(vk - vr).max())
    rows.append(("topk_scores_kernel", _time(f_k), f"max_err={err:.2e}"))
    rows.append(("topk_scores_ref", _time(f_r), ""))

    I, J, K = 256, 512, 10
    U = jnp.asarray(rng.normal(size=(I, K)), jnp.float32)
    Vp = jnp.asarray(rng.normal(size=(I, J, K)), jnp.float32)
    mask = jnp.asarray(rng.random((I, J)) < 0.05)
    f_k = lambda: ops.recommend_topk_peruser(U, Vp, mask, 10)
    f_r = lambda: ref.topk_scores_peruser_ref(U, Vp, mask, 10)
    vk, _ = f_k()
    vr, _ = f_r()
    err = float(jnp.abs(vk - vr).max())
    rows.append(("topk_peruser_kernel", _time(f_k), f"max_err={err:.2e}"))
    rows.append(("topk_peruser_ref", _time(f_r), ""))
    return rows


if __name__ == "__main__":
    for name, us, extra in main():
        print(f"{name},{us:.0f},{extra}")
