"""Fig. 4: training/test loss vs. maximum iteration T — DMF converges
steadily (paper: ~100 epochs on Foursquare, ~200 on Alipay).

Writes ``BENCH_convergence.json`` (repo root + benchmarks/results mirror,
the `common.save_json` BENCH_* convention)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi


def main(full: bool = False, epochs: int = 120):
    out = {}
    for dsname, maker in [
        ("foursquare", synthetic_poi.foursquare_like),
        ("alipay", synthetic_poi.alipay_like),
    ]:
        ds = maker(reduced=not full)
        gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
        W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
        M = graph.walk_propagation_matrix(W, gcfg)
        cfg = dmf.DMFConfig(
            n_users=ds.n_users, n_items=ds.n_items, dim=10, beta=0.1, gamma=0.01
        )
        res = dmf.fit(cfg, ds.train, M, epochs=epochs, test=ds.test)
        tr, te = res.train_losses, res.test_losses
        out[dsname] = {
            "train_loss": [round(float(x), 5) for x in tr],
            "test_loss": [round(float(x), 5) for x in te],
            # convergence check: monotone-ish decrease, last-quarter flat
            "converged": bool(
                tr[-1] < 0.5 * tr[0]
                and abs(np.mean(tr[-10:]) - np.mean(tr[-20:-10]))
                < max(0.15 * np.mean(tr[-20:-10]), 1e-3)
            ),
        }
    common.save_json("BENCH_convergence", out)   # mirrors to repo root
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(main(), indent=1))
