"""Fig. 5: effect of the global (β) and local (γ) item regularizers.

Paper claim: good mid-range choices of (β, γ) beat both extremes — the
extremes degenerate toward GDMF (γ→∞) / LDMF (β→∞) behaviour.
"""
from __future__ import annotations

import numpy as np

from repro.core import dmf, graph
from repro.data import synthetic_poi

GRID = [1e-3, 1e-2, 1e-1, 1e0, 1e1]


def main(full: bool = False, epochs: int = 60):
    ds = synthetic_poi.foursquare_like(reduced=not full)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    heat = {}
    for beta in GRID:
        for gamma in GRID:
            cfg = dmf.DMFConfig(
                n_users=ds.n_users, n_items=ds.n_items, dim=5,
                beta=beta, gamma=gamma,
            )
            res = dmf.fit(cfg, ds.train, M, epochs=epochs)
            ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
            heat[f"b{beta:g}_g{gamma:g}"] = round(ev["R@10"], 4)
    vals = np.array(list(heat.values()))
    return {
        "grid_R@10": heat,
        "best": max(heat, key=heat.get),
        "spread_validates_sensitivity": bool(vals.max() > 1.15 * max(vals.min(), 1e-9)),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(main(), indent=1))
