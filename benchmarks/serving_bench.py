"""Serving-path benchmark: sustained throughput + latency percentiles for
the three request paths over one trained model —

  * ``loop_per_request`` — the seed behavior: one interpret-mode kernel
    dispatch per request (`ops.recommend_topk` with a single-user batch);
  * ``batched_dense``    — `ServingEngine(prune=False)`: microbatched,
    full-J streaming top-k per request;
  * ``batched_pruned``   — `ServingEngine(prune=True)`: microbatched +
    city-bucket candidate pruning through the fused serve kernel.

Writes ``BENCH_serving.json`` (repo root + benchmarks/results/, same
convention as BENCH_dmf_train). Required: batched_pruned ≥ 10x the
per-request loop in requests/sec at foursquare_like(reduced=True) scale.
Also reports how often the pruned top-k agrees with the dense full-J
top-k (Fig. 2 says almost always) and the per-microbatch latency
percentiles of both engine paths.

    PYTHONPATH=src python -m benchmarks.serving_bench
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.kernels import ops
from repro.serving import ServingConfig, ServingEngine, index_from_dataset


def _loop_per_request(state, seen, users, k, n_timed):
    """Seed path: per-request Python loop, one kernel call per request."""
    U = state.U
    V = state.P + state.Q
    seen = jnp.asarray(seen)
    u0 = int(users[0])
    ops.recommend_topk(U[u0][None], V[u0], seen[u0][None], k)  # warm/compile
    t0 = time.perf_counter()
    for u in users[:n_timed]:
        u = int(u)
        _, idx = ops.recommend_topk(U[u][None], V[u], seen[u][None], k)
        jax.block_until_ready(idx)
    dt = time.perf_counter() - t0
    return n_timed / dt


def _engine_path(state, index, train, users, k, microbatch, prune, interpret=True):
    eng = ServingEngine(
        state, index,
        ServingConfig(microbatch=microbatch, k=k, prune=prune,
                      interpret=interpret),
        train=train,
    )
    eng.recommend(users[:microbatch])      # warm/compile
    eng.stats.reset()
    _, idx = eng.recommend(users)
    return eng.requests_per_sec, eng.stats.latency_percentiles(), idx


def main(full: bool = False) -> dict:
    ds = synthetic_poi.foursquare_like(reduced=not full)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    res = dmf.fit(cfg, ds.train, nbr, epochs=20 if not full else 40)
    index = index_from_dataset(ds)

    from repro.core import metrics as metrics_lib
    seen = metrics_lib.masks_from_interactions(ds.n_users, ds.n_items, ds.train)

    k = 10
    microbatch = 64
    n_requests = 256 if not full else 1024
    n_loop = 32 if not full else 64        # the loop path is slow by design
    rng = np.random.default_rng(0)
    users = rng.integers(0, ds.n_users, n_requests)

    rps_loop = _loop_per_request(res.state, seen, users, k, n_loop)
    rps_dense, lat_dense, idx_dense = _engine_path(
        res.state, index, ds.train, users, k, microbatch, prune=False)
    rps_pruned, lat_pruned, idx_pruned = _engine_path(
        res.state, index, ds.train, users, k, microbatch, prune=True)

    # pruning fidelity. Two regimes: where the dense full-J top-k already
    # lies inside the user's city bucket, pruning must be EXACT (asserted
    # in tests/test_serving.py). Elsewhere the difference is score-tie
    # spillover: untouched items score exactly u·0 = 0, so users short of k
    # positively-scored city candidates fill dense slots with lowest-id
    # 0.0-ties from any city — the pruned path keeps those in-city instead.
    agree = np.fromiter(
        ((set(a[a >= 0]) == set(b[b >= 0]))
         for a, b in zip(idx_pruned, idx_dense)), bool, len(users))
    in_bucket = np.fromiter(
        (bool(np.isin(d[d >= 0],
                      index.bucket_items[index.user_bucket[u]]).all())
         for u, d in zip(users, idx_dense)), bool, len(users))

    res_json = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": cfg.dim,
            "k": k, "microbatch": microbatch, "n_requests": int(n_requests),
            "n_loop_requests": int(n_loop),
            "bucket_cap": index.cap, "n_buckets": index.n_buckets,
            "n_truncated_buckets": index.n_truncated_buckets,
        },
        "requests_per_sec": {
            "loop_per_request": rps_loop,
            "batched_dense": rps_dense,
            "batched_pruned": rps_pruned,
        },
        "latency_ms": {
            "batched_dense": lat_dense,
            "batched_pruned": lat_pruned,
        },
        "speedup_pruned_vs_loop": rps_pruned / rps_loop,
        "speedup_pruned_vs_dense": rps_pruned / rps_dense,
        "pruned_dense_topk_agreement": float(agree.mean()),
        "dense_topk_in_bucket_frac": float(in_bucket.mean()),
        "pruned_dense_topk_agreement_where_in_bucket": float(
            agree[in_bucket].mean() if in_bucket.any() else 1.0),
    }
    common.save_json("BENCH_serving", res_json)   # mirrors to repo root
    return res_json


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
