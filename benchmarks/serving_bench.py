"""Serving-path benchmark: sustained throughput + latency percentiles for
the three request paths over one trained model —

  * ``loop_per_request`` — the seed behavior: one interpret-mode kernel
    dispatch per request (`ops.recommend_topk` with a single-user batch);
  * ``batched_dense``    — `ServingEngine(prune=False)`: microbatched,
    full-J streaming top-k per request;
  * ``batched_pruned``   — `ServingEngine(prune=True)`: microbatched +
    city-bucket candidate pruning through the fused serve kernel.

Writes ``BENCH_serving.json`` (repo root + benchmarks/results/, same
convention as BENCH_dmf_train). Required: batched_pruned ≥ 10x the
per-request loop in requests/sec at foursquare_like(reduced=True) scale.
Also reports how often the pruned top-k agrees with the dense full-J
top-k (Fig. 2 says almost always) and the per-microbatch latency
percentiles of both engine paths.

The ``sharded`` section measures the learner-sharded SPMD engine
(`ServingConfig.n_shards`) by shard count — each dispatch serves
microbatch×n_shards requests, recommendations bit-identical to the
single-shard engine. Needs host devices provisioned before jax starts:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.serving_bench
    # or: PYTHONPATH=src python -m benchmarks.run --only serving --devices 8
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.kernels import ops
from repro.serving import ServingConfig, ServingEngine, index_from_dataset


def _loop_per_request(state, seen, users, k, n_timed):
    """Seed path: per-request Python loop, one kernel call per request."""
    U = state.U
    V = state.P + state.Q
    seen = jnp.asarray(seen)
    u0 = int(users[0])
    ops.recommend_topk(U[u0][None], V[u0], seen[u0][None], k)  # warm/compile
    t0 = time.perf_counter()
    for u in users[:n_timed]:
        u = int(u)
        _, idx = ops.recommend_topk(U[u][None], V[u], seen[u][None], k)
        jax.block_until_ready(idx)
    dt = time.perf_counter() - t0
    return n_timed / dt


def _engine_path(state, index, train, users, k, microbatch, prune,
                 interpret=True, n_shards=1):
    eng = ServingEngine(
        state, index,
        ServingConfig(microbatch=microbatch, k=k, prune=prune,
                      interpret=interpret, n_shards=n_shards),
        train=train,
    )
    eng.recommend(users[:microbatch])      # warm/compile
    eng.stats.reset()
    _, idx = eng.recommend(users)
    return eng.requests_per_sec, eng.stats.latency_percentiles(), idx


def sharded_section(state, index, train, users, k, microbatch,
                    shard_counts=(1, 2, 4, 8)) -> dict:
    """SPMD engine by shard count: requests/sec, per-dispatch latency, and
    exactness vs the single-shard pruned engine (must be 1.0 — same kernel,
    same rows, just gathered shard-locally). The shards_1 grid entry doubles
    as the exactness reference — deterministic engine, so no separate
    reference pass."""
    n_devices = len(jax.devices())
    assert shard_counts and shard_counts[0] == 1, (
        "shards_1 is the exactness reference and must lead the grid")
    idx_ref = None
    out = {"config": {"n_devices": n_devices, "n_requests": int(len(users)),
                      "microbatch": microbatch},
           "requests_per_sec": {}, "latency_ms": {},
           "exact_match_vs_single_shard": {}}
    for n_shards in shard_counts:
        key = f"shards_{n_shards}"
        if n_shards > n_devices:
            out["requests_per_sec"][key] = None
            out["exact_match_vs_single_shard"][key] = (
                f"skipped: {n_devices} devices")
            continue
        rps, lat, idx = _engine_path(state, index, train, users, k,
                                     microbatch, prune=True,
                                     n_shards=n_shards)
        if idx_ref is None:
            idx_ref = idx
        out["requests_per_sec"][key] = rps
        out["latency_ms"][key] = lat
        out["exact_match_vs_single_shard"][key] = float(
            (np.asarray(idx) == np.asarray(idx_ref)).all(axis=1).mean())
    return out


def main(full: bool = False, tiny: bool = False) -> dict:
    if tiny:
        ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
            n_users=128, n_items=96, n_ratings=900, n_cities=4))
    else:
        ds = synthetic_poi.foursquare_like(reduced=not full)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    res = dmf.fit(cfg, ds.train, nbr, epochs=40 if full else (6 if tiny else 20))
    index = index_from_dataset(ds)

    from repro.core import metrics as metrics_lib
    seen = metrics_lib.masks_from_interactions(ds.n_users, ds.n_items, ds.train)

    k = 10
    microbatch = 16 if tiny else 64
    n_requests = 64 if tiny else (256 if not full else 1024)
    n_loop = 8 if tiny else (32 if not full else 64)  # loop path slow by design
    rng = np.random.default_rng(0)
    users = rng.integers(0, ds.n_users, n_requests)

    rps_loop = _loop_per_request(res.state, seen, users, k, n_loop)
    rps_dense, lat_dense, idx_dense = _engine_path(
        res.state, index, ds.train, users, k, microbatch, prune=False)
    rps_pruned, lat_pruned, idx_pruned = _engine_path(
        res.state, index, ds.train, users, k, microbatch, prune=True)

    # pruning fidelity. Two regimes: where the dense full-J top-k already
    # lies inside the user's city bucket, pruning must be EXACT (asserted
    # in tests/test_serving.py). Elsewhere the difference is score-tie
    # spillover: untouched items score exactly u·0 = 0, so users short of k
    # positively-scored city candidates fill dense slots with lowest-id
    # 0.0-ties from any city — the pruned path keeps those in-city instead.
    agree = np.fromiter(
        ((set(a[a >= 0]) == set(b[b >= 0]))
         for a, b in zip(idx_pruned, idx_dense)), bool, len(users))
    in_bucket = np.fromiter(
        (bool(np.isin(d[d >= 0],
                      index.bucket_items[index.user_bucket[u]]).all())
         for u, d in zip(users, idx_dense)), bool, len(users))

    res_json = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": cfg.dim,
            "k": k, "microbatch": microbatch, "n_requests": int(n_requests),
            "n_loop_requests": int(n_loop),
            "bucket_cap": index.cap, "n_buckets": index.n_buckets,
            "n_truncated_buckets": index.n_truncated_buckets,
        },
        "requests_per_sec": {
            "loop_per_request": rps_loop,
            "batched_dense": rps_dense,
            "batched_pruned": rps_pruned,
        },
        "latency_ms": {
            "batched_dense": lat_dense,
            "batched_pruned": lat_pruned,
        },
        "speedup_pruned_vs_loop": rps_pruned / rps_loop,
        "speedup_pruned_vs_dense": rps_pruned / rps_dense,
        "pruned_dense_topk_agreement": float(agree.mean()),
        "dense_topk_in_bucket_frac": float(in_bucket.mean()),
        "pruned_dense_topk_agreement_where_in_bucket": float(
            agree[in_bucket].mean() if in_bucket.any() else 1.0),
    }
    # SPMD engine by shard count (more requests: each dispatch serves
    # microbatch×shards, so the single-shard request count undersamples)
    sh_users = rng.integers(0, ds.n_users, n_requests * 4)
    res_json["sharded"] = sharded_section(
        res.state, index, ds.train, sh_users, k, microbatch)
    common.save_json("BENCH_serving", res_json)   # mirrors to repo root
    return res_json


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
