"""Serving-path benchmark: sustained throughput + latency percentiles for
the three request paths over one trained model —

  * ``loop_per_request`` — the seed behavior: one interpret-mode kernel
    dispatch per request (`ops.recommend_topk` with a single-user batch);
  * ``batched_dense``    — `ServingEngine(prune=False)`: microbatched,
    full-J streaming top-k per request;
  * ``batched_pruned``   — `ServingEngine(prune=True)`: microbatched +
    city-bucket candidate pruning through the fused serve kernel.

Writes ``BENCH_serving.json`` (repo root + benchmarks/results/, same
convention as BENCH_dmf_train). Required: batched_pruned ≥ 10x the
per-request loop in requests/sec at foursquare_like(reduced=True) scale.
Also reports how often the pruned top-k agrees with the dense full-J
top-k (Fig. 2 says almost always) and the per-microbatch latency
percentiles of both engine paths.

The ``sharded`` section measures the learner-sharded SPMD engine
(`ServingConfig.n_shards`) by shard count — each dispatch serves
microbatch×n_shards requests, recommendations bit-identical to the
single-shard engine. Needs host devices provisioned before jax starts:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.serving_bench
    # or: PYTHONPATH=src python -m benchmarks.run --only serving --devices 8

The ``million`` section is the scale story: a synthetic 1M-user / 100k-POI
world served from the `TiledFactorStore` (HBM-resident per-user candidate
windows; the full (I, J, K) factor tensor would be 3.2 TB) through the
tiled window kernel, in fp32 / int8 / bf16. Exactness is cross-checked
against a dense sub-`ServingEngine` rebuilt bitwise-identically on sampled
users (fp32 must match exactly; quantized paths report measured top-k
overlap and max |score delta| vs the analytic bound). ``--tiled-smoke``
runs the same section at toy scale with the assertions live and no JSON
write — the fast-CI entry point.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.kernels import ops
from repro.serving import (ServingConfig, ServingEngine, SyntheticFactors,
                           TiledFactorStore, TiledServingEngine,
                           build_hierarchical_index, index_from_dataset,
                           synthetic_world)


def _loop_per_request(state, seen, users, k, n_timed):
    """Seed path: per-request Python loop, one kernel call per request."""
    U = state.U
    V = state.P + state.Q
    seen = jnp.asarray(seen)
    u0 = int(users[0])
    ops.recommend_topk(U[u0][None], V[u0], seen[u0][None], k)  # warm/compile
    t0 = time.perf_counter()
    for u in users[:n_timed]:
        u = int(u)
        _, idx = ops.recommend_topk(U[u][None], V[u], seen[u][None], k)
        jax.block_until_ready(idx)
    dt = time.perf_counter() - t0
    return n_timed / dt


def _engine_path(state, index, train, users, k, microbatch, prune,
                 interpret=True, n_shards=1):
    eng = ServingEngine(
        state, index,
        ServingConfig(microbatch=microbatch, k=k, prune=prune,
                      interpret=interpret, n_shards=n_shards),
        train=train,
    )
    eng.recommend(users[:microbatch])      # warm/compile
    eng.stats.reset()
    _, idx = eng.recommend(users)
    return eng.requests_per_sec, eng.stats.latency_percentiles(), idx


def sharded_section(state, index, train, users, k, microbatch,
                    shard_counts=(1, 2, 4, 8)) -> dict:
    """SPMD engine by shard count: requests/sec, per-dispatch latency, and
    exactness vs the single-shard pruned engine (must be 1.0 — same kernel,
    same rows, just gathered shard-locally). The shards_1 grid entry doubles
    as the exactness reference — deterministic engine, so no separate
    reference pass."""
    n_devices = len(jax.devices())
    assert shard_counts and shard_counts[0] == 1, (
        "shards_1 is the exactness reference and must lead the grid")
    idx_ref = None
    out = {"config": {"n_devices": n_devices, "n_requests": int(len(users)),
                      "microbatch": microbatch},
           "requests_per_sec": {}, "latency_ms": {},
           "exact_match_vs_single_shard": {}}
    for n_shards in shard_counts:
        key = f"shards_{n_shards}"
        if n_shards > n_devices:
            out["requests_per_sec"][key] = None
            out["exact_match_vs_single_shard"][key] = (
                f"skipped: {n_devices} devices")
            continue
        rps, lat, idx = _engine_path(state, index, train, users, k,
                                     microbatch, prune=True,
                                     n_shards=n_shards)
        if idx_ref is None:
            idx_ref = idx
        out["requests_per_sec"][key] = rps
        out["latency_ms"][key] = lat
        out["exact_match_vs_single_shard"][key] = float(
            (np.asarray(idx) == np.asarray(idx_ref)).all(axis=1).mean())
    return out


def _tiled_rps(eng, users, warm=64):
    eng.recommend(users[:warm])
    eng.stats.reset()
    vals, idx, flags = eng.recommend(users, return_flags=True)
    return eng.requests_per_sec, vals, idx, flags


def million_section(n_users=1_000_000, n_items=100_000, n_cities=1024,
                    dim=8, cell_cap=128, n_requests=2048, n_oracle=32,
                    microbatch=128, k=10, seed=0) -> dict:
    """Serve a synthetic ``n_users`` × ``n_items`` world from the tiled
    store. Reports build times, resident bytes per precision, requests/sec
    for fp32 / int8 / bf16, the flat-vs-hierarchical cap reduction that
    makes the slab fit at all, and the exactness block (fp32 bitwise vs a
    dense sub-engine on sampled users; quantized overlap + measured delta
    vs the analytic bound). The returned dict IS asserted on: callers rely
    on exact.fp32_bitwise_vs_dense_engine being True."""
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    uc, ic, ucoord, icoord = synthetic_world(n_users, n_items, n_cities,
                                             seed=seed)
    t_world = time.perf_counter() - t0
    t0 = time.perf_counter()
    hier = build_hierarchical_index(ic, uc, icoord, ucoord, cell_cap=cell_cap)
    t_index = time.perf_counter() - t0
    # what the flat city index would have needed (the hierarchy's raison
    # d'être: slab bytes scale linearly with cap)
    biggest_city = int(np.bincount(ic, minlength=n_cities).max())
    t0 = time.perf_counter()
    synth = SyntheticFactors.create(n_users, n_items, dim, seed=seed + 1)
    store = TiledFactorStore.synthetic(synth, hier.flat, seen_per_user=2,
                                       seed=seed + 2)
    t_store = time.perf_counter() - t0
    t0 = time.perf_counter()
    store.quantize_int8()
    store.quantize_bf16()
    t_quant = time.perf_counter() - t0

    users = rng.integers(0, n_users, n_requests)
    cfg = ServingConfig(microbatch=microbatch, k=k)
    rps = {}
    served = {}
    for mode in ("fp32", "int8", "bf16"):
        eng = TiledServingEngine(store, cfg, mode=mode)
        rps[mode], *served_m = _tiled_rps(eng, users)
        served[mode] = served_m
    vals_f, idx_f, flags = served["fp32"]

    # --- exactness: dense sub-engine on sampled users, rebuilt so its
    # pruned path runs the SAME kernel computation on the SAME floats
    # (P = dense generator rows, Q = 0; seen scattered from the store
    # windows). Sampled among non-fallback users so both sides serve the
    # factor path, not the popularity slate.
    pool = np.flatnonzero(~store.cold
                          & (hier.flat.bucket_size[hier.flat.user_bucket] > 0))
    sample = rng.choice(pool, size=min(n_oracle, len(pool)), replace=False)
    n = len(sample)
    dense = synth.dense_rows(sample)                       # (n, J, K)
    sub_state = dmf.DMFState(
        U=jnp.asarray(store.U[sample]),
        P=jnp.asarray(dense),
        Q=jnp.zeros_like(dense),
    )
    seen_sub = np.zeros((n, n_items), bool)
    cand_s = hier.flat.bucket_items[hier.flat.user_bucket[sample]]
    for r in range(n):
        m = (cand_s[r] >= 0) & (store.seen[sample[r]] != 0)
        seen_sub[r, cand_s[r][m]] = True
    sub_index = dataclasses.replace(
        hier.flat, user_bucket=hier.flat.user_bucket[sample])
    sub_eng = ServingEngine(sub_state, sub_index,
                            ServingConfig(microbatch=min(microbatch, n), k=k),
                            seen=seen_sub)
    v_ref, i_ref, f_ref = sub_eng.recommend(np.arange(n), return_flags=True)
    teng = TiledServingEngine(store, cfg)
    v_t, i_t, f_t = teng.recommend(sample, return_flags=True)
    assert not f_ref.any() and not f_t.any()
    fp32_bitwise = bool((np.asarray(i_ref) == i_t).all()
                        and (np.asarray(v_ref) == v_t).all())
    assert fp32_bitwise, "tiled fp32 diverged from the dense sub-engine"

    # quantized: measured top-k score delta vs the per-request analytic
    # bound, and slate overlap vs fp32, on the same sampled users
    exact = {"n_oracle_users": int(n),
             "fp32_bitwise_vs_dense_engine": fp32_bitwise}
    for mode, bound in [("int8", store.int8_score_bound(sample)),
                        ("bf16", store.bf16_score_bound(sample))]:
        qe = TiledServingEngine(store, cfg, mode=mode)
        vq, iq, fq = qe.recommend(sample, return_flags=True)
        overlap = np.fromiter(
            (len(set(a[a >= 0]) & set(b[b >= 0])) / max((a >= 0).sum(), 1)
             for a, b in zip(np.asarray(i_t), iq)), np.float64, n)
        worst = 0.0
        for r in range(n):
            sc = store.slab[sample[r]] @ store.U[sample[r]]
            for slot in range(k):
                j = iq[r, slot]
                if j < 0:
                    continue
                pos = int(np.flatnonzero(cand_s[r] == j)[0])
                worst = max(worst, abs(float(vq[r, slot]) - float(sc[pos])))
        assert worst <= float(bound.max()) + 1e-6, (mode, worst, bound.max())
        exact[mode] = {
            "topk_overlap_vs_fp32": float(overlap.mean()),
            "max_abs_score_delta": worst,
            "analytic_bound_max": float(bound.max()),
        }

    nb = store.nbytes()
    return {
        "config": {"n_users": n_users, "n_items": n_items,
                   "n_cities": n_cities, "dim": dim, "cell_cap": cell_cap,
                   "n_requests": int(n_requests), "microbatch": microbatch,
                   "k": k},
        "index": {"n_cells": hier.n_cells, "cap": hier.flat.cap,
                  "max_depth": hier.max_depth,
                  "flat_city_cap_would_be": biggest_city,
                  "cap_reduction_vs_flat":
                      biggest_city / max(hier.flat.cap, 1)},
        "build_seconds": {"world": t_world, "index": t_index,
                          "store": t_store, "quantize": t_quant},
        "resident_gb": {kk: v / 1e9 for kk, v in nb.items()},
        "requests_per_sec": rps,
        "fallback_frac": float(flags.mean()),
        "exact": exact,
    }


def tiled_smoke() -> dict:
    """Toy-scale million section for fast CI: every exactness assertion
    live (fp32 bitwise vs dense sub-engine, quantized delta within the
    analytic bound), no JSON written, seconds not minutes."""
    return million_section(n_users=4096, n_items=1024, n_cities=16,
                           dim=8, cell_cap=128, n_requests=256,
                           n_oracle=24, microbatch=64)


def main(full: bool = False, tiny: bool = False) -> dict:
    if tiny:
        ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
            n_users=128, n_items=96, n_ratings=900, n_cities=4))
    else:
        ds = synthetic_poi.foursquare_like(reduced=not full)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    res = dmf.fit(cfg, ds.train, nbr, epochs=40 if full else (6 if tiny else 20))
    index = index_from_dataset(ds)

    from repro.core import metrics as metrics_lib
    seen = metrics_lib.masks_from_interactions(ds.n_users, ds.n_items, ds.train)

    k = 10
    microbatch = 16 if tiny else 64
    n_requests = 64 if tiny else (256 if not full else 1024)
    n_loop = 8 if tiny else (32 if not full else 64)  # loop path slow by design
    rng = np.random.default_rng(0)
    users = rng.integers(0, ds.n_users, n_requests)

    rps_loop = _loop_per_request(res.state, seen, users, k, n_loop)
    rps_dense, lat_dense, idx_dense = _engine_path(
        res.state, index, ds.train, users, k, microbatch, prune=False)
    rps_pruned, lat_pruned, idx_pruned = _engine_path(
        res.state, index, ds.train, users, k, microbatch, prune=True)

    # pruning fidelity. Two regimes: where the dense full-J top-k already
    # lies inside the user's city bucket, pruning must be EXACT (asserted
    # in tests/test_serving.py). Elsewhere the difference is score-tie
    # spillover: untouched items score exactly u·0 = 0, so users short of k
    # positively-scored city candidates fill dense slots with lowest-id
    # 0.0-ties from any city — the pruned path keeps those in-city instead.
    agree = np.fromiter(
        ((set(a[a >= 0]) == set(b[b >= 0]))
         for a, b in zip(idx_pruned, idx_dense)), bool, len(users))
    in_bucket = np.fromiter(
        (bool(np.isin(d[d >= 0],
                      index.bucket_items[index.user_bucket[u]]).all())
         for u, d in zip(users, idx_dense)), bool, len(users))

    res_json = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": cfg.dim,
            "k": k, "microbatch": microbatch, "n_requests": int(n_requests),
            "n_loop_requests": int(n_loop),
            "bucket_cap": index.cap, "n_buckets": index.n_buckets,
            "n_truncated_buckets": index.n_truncated_buckets,
        },
        "requests_per_sec": {
            "loop_per_request": rps_loop,
            "batched_dense": rps_dense,
            "batched_pruned": rps_pruned,
        },
        "latency_ms": {
            "batched_dense": lat_dense,
            "batched_pruned": lat_pruned,
        },
        "speedup_pruned_vs_loop": rps_pruned / rps_loop,
        "speedup_pruned_vs_dense": rps_pruned / rps_dense,
        "pruned_dense_topk_agreement": float(agree.mean()),
        "dense_topk_in_bucket_frac": float(in_bucket.mean()),
        "pruned_dense_topk_agreement_where_in_bucket": float(
            agree[in_bucket].mean() if in_bucket.any() else 1.0),
    }
    # the serving tentpole contract, pinned in the artifact: the tiled
    # window kernel (per-request candidate windows only) is bit-identical
    # to the whole-slab kernel on the bench's own pruned requests
    V = np.asarray(res.state.P + res.state.Q)
    wu = users[:microbatch]
    cand_w = index.bucket_items[index.user_bucket[wu]]
    safe_w = np.maximum(cand_w, 0)
    vw = V[wu[:, None], safe_w]
    sw = np.where(cand_w >= 0, seen[wu[:, None], safe_w], False
                  ).astype(np.int8)
    tv, ti = ops.serve_topk_window(np.asarray(res.state.U)[wu], vw,
                                   cand_w, sw, k)
    sv, si = ops.serve_topk(jnp.asarray(res.state.U)[jnp.asarray(wu)],
                            jnp.asarray(V)[jnp.asarray(wu)],
                            jnp.asarray(cand_w),
                            jnp.asarray(seen)[jnp.asarray(wu)], k)
    res_json["tiled_kernel_bit_identical_vs_slab"] = bool(
        (np.asarray(ti) == np.asarray(si)).all()
        and (np.asarray(tv) == np.asarray(sv)).all())
    assert res_json["tiled_kernel_bit_identical_vs_slab"]

    # SPMD engine by shard count (more requests: each dispatch serves
    # microbatch×shards, so the single-shard request count undersamples)
    sh_users = rng.integers(0, ds.n_users, n_requests * 4)
    res_json["sharded"] = sharded_section(
        res.state, index, ds.train, sh_users, k, microbatch)
    # million-user tiled-store section (toy-sized under tiny so the bench
    # smoke stays fast; real 1M × 100k otherwise)
    if tiny:
        res_json["million"] = tiled_smoke()
    else:
        res_json["million"] = million_section(
            n_requests=4096 if full else 2048)
    common.save_json("BENCH_serving", res_json)   # mirrors to repo root
    return res_json


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="full-scale dataset + more requests")
    ap.add_argument("--tiny", action="store_true",
                    help="toy sizes (bench smoke scale)")
    ap.add_argument("--tiled-smoke", action="store_true",
                    help="run only the toy-scale tiled/million section with "
                         "its exactness assertions; no JSON written (CI)")
    cli = ap.parse_args()
    if cli.tiled_smoke:
        print(json.dumps(tiled_smoke(), indent=1))
    else:
        print(json.dumps(main(full=cli.full, tiny=cli.tiny), indent=1))
