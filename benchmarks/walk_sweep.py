"""Fig. 6: effect of the maximum random-walk distance D ∈ {1,2,3,4}.

Paper claim: quality rises with D and is roughly stable for D ≥ 3 (small D
already suffices -> low communication cost).

Writes ``BENCH_walk_sweep.json`` (repo root + benchmarks/results mirror,
the `common.save_json` BENCH_* convention).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi


def main(full: bool = False, epochs: int = 60, seeds=(0, 1, 2)):
    out = {}
    for dsname, maker in [
        ("foursquare", synthetic_poi.foursquare_like),
        ("alipay", synthetic_poi.alipay_like),
    ]:
        ds = maker(reduced=not full)
        gcfg0 = graph.GraphConfig(n_neighbors=2, walk_length=1)
        W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg0)
        curve = {}
        for D in [1, 2, 3, 4]:
            gcfg = graph.GraphConfig(n_neighbors=2, walk_length=D)
            M = graph.walk_propagation_matrix(W, gcfg)
            vals = []
            for seed in seeds:
                cfg = dmf.DMFConfig(
                    n_users=ds.n_users, n_items=ds.n_items, dim=5,
                    beta=0.1, gamma=0.01, seed=seed,
                )
                res = dmf.fit(cfg, ds.train, M, epochs=epochs)
                ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
                vals.append(ev["R@10"])
            curve[D] = round(float(np.mean(vals)), 4)
        out[dsname] = {
            "R@10_by_D": curve,
            "stable_after_3": bool(
                abs(curve[4] - curve[3]) <= 0.15 * max(curve[3], 1e-9)
            ),
        }
    common.save_json("BENCH_walk_sweep", out)    # mirrors to repo root
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(main(), indent=1))
