"""Bench-regression gate: fresh BENCH_*.json vs the committed baselines.

    PYTHONPATH=src python -m benchmarks.compare                 # all BENCH_*
    PYTHONPATH=src python -m benchmarks.compare --only BENCH_serving
    PYTHONPATH=src python -m benchmarks.compare --threshold 0.5

For every ``BENCH_<section>.json`` committed at the repo root (the
baseline the perf trajectory is tracked by — see `common.save_json`), the
matching fresh artifact in ``benchmarks/results/`` is walked leaf-by-leaf
and every TRACKED numeric leaf is compared:

* **higher-is-better** leaves (throughput: ``*_per_sec``, ``*_rps``,
  ``epochs_per_sec``, ``goodput``, ``slo_attainment``, accuracy ``P@``/
  ``R@``, ``speedup``) regress when ``fresh < base * (1 - threshold)``;
* **lower-is-better** leaves (latency ``p50/p95/p99_ms``, ``*_seconds``,
  ``*_ms``, ``*_overhead*``, ``*_bytes``/``*_gb``) regress when
  ``fresh > base * (1 + threshold)``.

Leaves matching neither family (counts, flags, config echoes, loss gaps)
are reported only with ``--all`` and never gate. The default threshold is
deliberately loose (25%): CI machines are noisy, and this gate exists to
catch step-function regressions (a kernel silently falling off its fast
path), not 3% jitter. Exit status: 0 = no tracked regression, 1 =
regression(s), 2 = nothing to compare. Imports no jax — safe anywhere.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from benchmarks.common import RESULTS, ROOT, fmt_table

# substring → direction; first match wins, order matters (e.g. "_rps" must
# not be shadowed by a lower-is-better family)
HIGHER_BETTER = ("epochs_per_sec", "requests_per_sec", "_per_sec", "_rps",
                 "goodput", "slo_attainment", "speedup", "pass_rate",
                 "participation", "agreement", "P@", "R@")
LOWER_BETTER = ("p50_ms", "p95_ms", "p99_ms", "_ms", "_seconds", "overhead",
                "_bytes", "_gb", "wall_s")


def direction(path: str) -> str | None:
    """'up' (higher better), 'down' (lower better) or None (untracked) for
    a $.dotted.leaf.path — matched on the path, so a p50_ms nested under
    latency_ms is caught wherever it lives."""
    for pat in HIGHER_BETTER:
        if pat in path:
            return "up"
    for pat in LOWER_BETTER:
        if pat in path:
            return "down"
    return None


def numeric_leaves(obj, path="$") -> dict[str, float]:
    """Flatten every finite numeric leaf to {dotted-path: value}. Bools are
    config echoes, not measurements — skipped."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(numeric_leaves(v, f"{path}.{k}"))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(numeric_leaves(v, f"{path}[{i}]"))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        v = float(obj)
        if v == v and abs(v) != float("inf"):
            out[path] = v
    return out


def compare_one(name: str, base: dict, fresh: dict,
                threshold: float) -> list[dict]:
    """Per-leaf comparison rows for one artifact pair. A row is a dict
    with bench/path/direction/base/fresh/delta_frac/regressed."""
    b, f = numeric_leaves(base), numeric_leaves(fresh)
    rows = []
    for path in sorted(set(b) & set(f)):
        d = direction(path)
        bv, fv = b[path], f[path]
        delta = (fv - bv) / abs(bv) if bv else (0.0 if fv == bv else
                                                float("inf"))
        # threshold on the move relative to |base| — a plain multiplicative
        # band misfires when the baseline is negative (e.g. an overhead
        # that measured slightly below zero) or exactly zero
        band = threshold * max(abs(bv), 1e-12)
        if d == "up":
            reg = fv < bv - band
        elif d == "down":
            reg = fv > bv + band
        else:
            reg = False
        rows.append({"bench": name, "path": path, "direction": d or "-",
                     "base": bv, "fresh": fv, "delta_frac": delta,
                     "regressed": bool(reg)})
    return rows


def run(baseline_dir=ROOT, fresh_dir=RESULTS, only=None,
        threshold: float = 0.25) -> tuple[list[dict], list[str]]:
    """Compare every baseline/fresh pair; returns (rows, missing-fresh
    names). Baselines with no fresh artifact are reported, not failed —
    a partial bench run shouldn't fake a regression."""
    rows, missing = [], []
    for p in sorted(pathlib.Path(baseline_dir).glob("BENCH_*.json")):
        name = p.stem
        if only and name not in only:
            continue
        fp = pathlib.Path(fresh_dir) / p.name
        if not fp.exists():
            missing.append(name)
            continue
        rows += compare_one(name, json.loads(p.read_text()),
                            json.loads(fp.read_text()), threshold)
    return rows, missing


def render(rows, show_all: bool = False) -> str:
    sel = [r for r in rows
           if show_all or r["regressed"] or r["direction"] != "-"]
    table = fmt_table(
        ["bench", "leaf", "dir", "base", "fresh", "Δ%", "status"],
        [[r["bench"], r["path"], r["direction"],
          f"{r['base']:.4g}", f"{r['fresh']:.4g}",
          f"{100 * r['delta_frac']:+.1f}",
          "REGRESSED" if r["regressed"] else "ok"] for r in sel])
    n_reg = sum(r["regressed"] for r in rows)
    tracked = sum(r["direction"] != "-" for r in rows)
    return (table + f"\n\n{len(rows)} leaves compared, {tracked} tracked, "
            f"{n_reg} regressed")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression tolerance on tracked leaves "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--only", default="",
                    help="comma-separated BENCH_* names (default: all "
                         "committed baselines)")
    ap.add_argument("--all", action="store_true",
                    help="show untracked leaves in the table too")
    ap.add_argument("--baseline-dir", default=str(ROOT))
    ap.add_argument("--fresh-dir", default=str(RESULTS))
    args = ap.parse_args(argv)
    only = {s.strip() for s in args.only.split(",") if s.strip()} or None
    rows, missing = run(args.baseline_dir, args.fresh_dir, only,
                        args.threshold)
    if missing:
        print("no fresh artifact for: " + ", ".join(missing)
              + " (run the matching `benchmarks.run --only` sections)")
    if not rows:
        print("nothing to compare")
        return 2
    print(render(rows, show_all=args.all))
    return 1 if any(r["regressed"] for r in rows) else 0


if __name__ == "__main__":
    sys.exit(main())
