"""Continuous-batching scheduler benchmark: goodput under a p99 SLO.

BENCH_serving measures *drain* throughput — hand the engine a request list,
clock the wall time — and its sharded section shows the cost of that
dispatch discipline: p50 latency balloons as shards go 1 → 8 because every
request waits for the widest global lockstep batch. This bench puts the
`scheduling.Scheduler` (per-shard independent dispatch + SLO admission) and
the lockstep discipline (`scheduling.simulate_lockstep` — today's
`ServingEngine` drain, measured per-request) on the SAME timestamped
Poisson workloads and reports what a traffic engineer actually provisions
by: **goodput under the SLO** (completed-within-deadline requests/sec),
SLO attainment, rejection/expiry rates, and per-request p50/p95/p99 — per
shard count {1, 2, 4, 8}, per offered load (fractions/multiples of the
measured single-shard capacity).

Also checks the scheduler's two correctness contracts on a live run:
every served slate is bit-identical to a direct `ServingEngine.recommend`
of the same user ids, and ingest interleaved into idle slots leaves slates
bit-identical to the matching no-ingest / post-ingest factor snapshots.

Writes ``BENCH_scheduler.json`` (repo root + benchmarks/results mirror).
Sharded entries need host devices provisioned before jax starts:

    PYTHONPATH=src python -m benchmarks.run --only scheduler --devices 8
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi
from repro.scheduling import (Scheduler, SchedulerConfig, WorkloadConfig,
                              generate, simulate_lockstep)
from repro.serving import ServingConfig, ServingEngine, index_from_dataset


def _build_engine(state, index, train, nbr, cfg, microbatch, n_shards):
    eng = ServingEngine(
        state, index,
        ServingConfig(microbatch=microbatch, n_shards=n_shards),
        train=train, nbr=nbr, dmf_cfg=cfg)
    eng.serve_microbatch(np.arange(microbatch, dtype=np.int64))   # warm jit
    if n_shards > 1:
        eng.serve_wave(np.zeros((n_shards, microbatch), np.int32))
    eng.stats.reset()
    return eng


def _measure_capacity(eng, n_users, reps: int = 5) -> float:
    """Single-queue capacity: requests/sec of back-to-back full microbatch
    dispatches — the scale the offered-load grid hangs off."""
    R = eng.cfg.microbatch
    rng = np.random.default_rng(7)
    dts = []
    for _ in range(reps):
        *_, dt = eng.serve_microbatch(rng.integers(0, n_users, R))
        dts.append(dt)
    return R / float(np.median(dts))


def _bit_identical(report, state, index, train, nbr, cfg, microbatch,
                   n_shards) -> bool:
    """Every served slate == a fresh engine's direct recommend of the same
    ids (fresh engine: the scheduler's engine accumulated no state, but this
    also proves no hidden dependence on scheduler-side dispatch order)."""
    served = report.served()
    if not served:
        return False
    eng = ServingEngine(
        state, index,
        ServingConfig(microbatch=microbatch, n_shards=n_shards),
        train=train, nbr=nbr, dmf_cfg=cfg)
    vals, idx, flags = eng.recommend([r.user for r in served],
                                     return_flags=True)
    return bool(all(
        (r.vals == vals[j]).all() and (r.idx == idx[j]).all()
        and r.fallback == bool(flags[j])
        for j, r in enumerate(served)))


def _ingest_interleave_section(state, index, ds, nbr, cfg, microbatch,
                               slo_ms) -> dict:
    """Two request bursts with an idle gap; one ingest window of held-out
    check-ins. The scheduler must run the refresh INSIDE the gap (never
    blocking a queued request) and stay snapshot-consistent: burst-1 slates
    == no-ingest engine, burst-2 slates == engine after the same ingest."""
    from repro.scheduling.workload import make_requests

    rng = np.random.default_rng(3)
    n_half = 48
    users = rng.integers(0, ds.n_users, 2 * n_half)
    t1 = np.sort(rng.uniform(0.0, 0.02, n_half))
    # generous idle gap: the first ingest window pays the online-refresh jit
    # compile, which must still land inside the gap on the virtual clock
    t2 = 5.0 + np.sort(rng.uniform(0.0, 0.02, n_half))
    reqs = make_requests(np.concatenate([t1, t2]), users, slo_ms)
    events = ds.test[:32].astype(np.int64)

    eng = _build_engine(state, index, ds.train, nbr, cfg, microbatch, 1)
    rep = Scheduler(eng, SchedulerConfig()).run(reqs, ingest_events=[events])
    served = rep.served()
    pre = [r for r in served if r.ingest_epoch == 0]
    post = [r for r in served if r.ingest_epoch == 1]

    eng_no = ServingEngine(state, index, ServingConfig(microbatch=microbatch),
                           train=ds.train, nbr=nbr, dmf_cfg=cfg)
    v0, i0 = eng_no.recommend([r.user for r in pre])
    pre_ok = bool(all((r.vals == v0[j]).all() and (r.idx == i0[j]).all()
                      for j, r in enumerate(pre)))
    eng_in = ServingEngine(state, index, ServingConfig(microbatch=microbatch),
                           train=ds.train, nbr=nbr, dmf_cfg=cfg)
    eng_in.ingest(events)
    v1, i1 = eng_in.recommend([r.user for r in post])
    post_ok = bool(all((r.vals == v1[j]).all() and (r.idx == i1[j]).all()
                       for j, r in enumerate(post)))
    gap_start, gap_end = float(t1[-1]), 5.0
    in_gap = bool(all(gap_start <= s and e <= gap_end + 1e-9
                      for s, e in rep.ingest_intervals)) \
        if rep.ingest_intervals else False
    return {
        "n_windows_run": rep.n_ingest_windows,
        "n_pre_ingest_served": len(pre),
        "n_post_ingest_served": len(post),
        "ingest_ran_in_idle_gap": in_gap,
        "pre_ingest_bit_identical_to_no_ingest": pre_ok,
        "post_ingest_bit_identical_to_ingested_snapshot": post_ok,
    }


def main(full: bool = False, tiny: bool = False) -> dict:
    import jax

    if tiny:
        ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
            n_users=128, n_items=96, n_ratings=900, n_cities=4))
        epochs, microbatch, n_requests = 6, 16, 120
        shard_counts = (1, 2)
    else:
        ds = synthetic_poi.foursquare_like(reduced=not full)
        epochs = 40 if full else 20
        microbatch, n_requests = 64, 1024 if full else 512
        shard_counts = (1, 2, 4, 8)
    slo_ms = 50.0
    load_fracs = (0.5, 1.0, 2.0)     # × measured single-shard capacity

    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    state = dmf.fit(cfg, ds.train, nbr, epochs=epochs).state
    index = index_from_dataset(ds)
    n_devices = len(jax.devices())

    eng1 = _build_engine(state, index, ds.train, nbr, cfg, microbatch, 1)
    capacity = _measure_capacity(eng1, ds.n_users)
    loads = [f * capacity for f in load_fracs]

    res = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items,
            "microbatch": microbatch, "n_requests": n_requests,
            "slo_ms": slo_ms, "n_devices": n_devices,
            "load_fracs_of_capacity": list(load_fracs),
            "workload": "poisson × power-law users",
        },
        "single_shard_capacity_rps": capacity,
        "grid": {},
    }
    mid = len(loads) // 2
    p50s = {}
    for n_shards in shard_counts:
        key = f"shards_{n_shards}"
        if n_shards > n_devices:
            res["grid"][key] = {"skipped": f"{n_devices} devices"}
            continue
        eng = (eng1 if n_shards == 1 else _build_engine(
            state, index, ds.train, nbr, cfg, microbatch, n_shards))
        entry = {"loads": [], "bit_identical_vs_direct": None}
        for li, load in enumerate(loads):
            wl = WorkloadConfig(
                n_requests=n_requests, rate_rps=load, slo_ms=slo_ms,
                users="powerlaw", seed=100 + li)
            reqs = generate(wl, ds.n_users)
            rep_s = Scheduler(eng, SchedulerConfig()).run(reqs)
            rep_l = simulate_lockstep(eng, reqs)
            row = {
                "offered_load_rps": load,
                "offered_frac_of_capacity": load_fracs[li],
                "scheduler": rep_s.summary(slo_ms=slo_ms),
                "lockstep": rep_l.summary(slo_ms=slo_ms),
            }
            entry["loads"].append(row)
            if li == mid:
                entry["bit_identical_vs_direct"] = _bit_identical(
                    rep_s, state, index, ds.train, nbr, cfg, microbatch,
                    n_shards)
                p50s[n_shards] = (
                    row["scheduler"]["latency_ms"]["p50_ms"],
                    row["lockstep"]["latency_ms"]["p50_ms"])
        res["grid"][key] = entry

    max_d = max(p50s)
    res["max_shards_measured"] = max_d
    res["p50_ms_at_max_shards"] = {
        "scheduler": p50s[max_d][0], "lockstep": p50s[max_d][1]}
    res["scheduler_beats_lockstep_p50_at_max_shards"] = bool(
        p50s[max_d][0] < p50s[max_d][1])
    res["ingest_interleave"] = _ingest_interleave_section(
        state, index, ds, nbr, cfg, microbatch, slo_ms)
    common.save_json("BENCH_scheduler", res)   # mirrors to repo root
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
