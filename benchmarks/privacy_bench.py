"""Privacy benchmark: the ε-utility frontier, the DP-path throughput
overhead, and the empirical leakage-audit curves (ISSUE 5 tentpole).

Three questions, answered on the synthetic Foursquare config:

1. **ε vs utility** — train across a noise-multiplier grid (DP off plus
   ascending σ at fixed clip), record the accountant's ε(δ) against
   P@k/R@k: the frontier a deployment picks its operating point on.
2. **Throughput overhead** — epochs/sec of the DP path (fused Pallas
   clip+noise on the exchange hot path) vs the un-noised sparse scan.
   Contract: ≤15% overhead with the fused kernel.
3. **Leakage audit** — `privacy.audit` attack advantage (rating
   reconstruction + membership inference) against the observed outbox
   stream per grid point: advantage must fall as ε falls.

Writes ``BENCH_privacy.json`` (repo root + benchmarks/results mirror):

    PYTHONPATH=src python -m benchmarks.run --only privacy
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import dmf, graph
from repro.data import synthetic_poi

# DP off first, then ascending σ at fixed clip: ε strictly falls along the
# grid, so monotonicity of the utility/advantage columns is readable off
# the arrays directly. Clip/σ chosen so the absolute noise std σ·C stays
# ≤ 1 — beyond that the un-damped u·v feedback loop diverges the tiny and
# reduced-Foursquare configs to NaN (measured), which is a training-regime
# statement, not a frontier point.
SIGMA_GRID = (0.0, 0.25, 1.0, 4.0)
CLIP = 0.25
DELTA = 1e-5


def _time_epochs(cfg, train, nbr, n_timed: int, repeats: int = 3) -> float:
    """Best-of-``repeats`` epochs/sec: this container's CPU shares are
    throttled erratically (single-shot timings swing ±2x), and the
    overhead ratio of two single-shot numbers can even go negative; the
    min-time rep per config is the stable estimator."""
    rng = np.random.default_rng(123)
    state = dmf.init_state(cfg)
    state, _ = dmf.train_epoch(state, nbr, train, cfg, rng)   # warm/compile
    jax.block_until_ready(state.U)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n_timed):
            state, _ = dmf.train_epoch(state, nbr, train, cfg, rng)
        jax.block_until_ready(state.U)
        best = min(best, time.perf_counter() - t0)
    return n_timed / best


def main(full: bool = False, tiny: bool = False, n_timed: int = 3,
         epochs: int | None = None, audit_epochs: int = 1) -> dict:
    if tiny:
        ds = synthetic_poi.generate(synthetic_poi.POIDatasetConfig(
            n_users=192, n_items=96, n_ratings=1200, n_cities=4))
        epochs = epochs or 6
    else:
        ds = synthetic_poi.foursquare_like(reduced=not full)
        epochs = epochs or (60 if full else 30)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)

    def make_cfg(sigma: float, use_pallas: bool = False) -> dmf.DMFConfig:
        return dmf.DMFConfig(
            n_users=ds.n_users, n_items=ds.n_items, dim=10, beta=0.1,
            gamma=0.01, dp_sigma=sigma,
            dp_clip=CLIP if sigma > 0 else float("inf"),
            use_pallas=use_pallas)

    from repro.privacy import audit

    frontier = []
    for sigma in SIGMA_GRID:
        cfg = make_cfg(sigma)
        res = dmf.fit(cfg, ds.train, nbr, epochs=epochs, test=ds.test,
                      dp_delta=DELTA)
        ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
        row = {
            "dp_sigma": sigma,
            "dp_clip": None if sigma == 0 else CLIP,
            "eps": (None if res.privacy is None
                    else res.privacy["eps_max"]),
            "eps_median_active": (None if res.privacy is None
                                  else res.privacy["eps_median_active"]),
            "train_loss_final": float(res.train_losses[-1]),
            "test_loss_final": float(res.test_losses[-1]),
            **{k: float(v) for k, v in ev.items()},
        }
        row.update(audit.run_audit(
            cfg, ds.train, nbr, ds.n_users, ds.n_items, epochs=audit_epochs))
        frontier.append(row)

    adv = [r["rating_inversion_advantage"] for r in frontier]
    mem = [r["membership_advantage"] for r in frontier]

    # DP-path epoch throughput: un-noised scan vs DP via jnp vs DP via the
    # fused Pallas kernel (overhead contract is on the fused path)
    eps_plain = _time_epochs(make_cfg(0.0), ds.train, nbr, n_timed)
    eps_dp_jnp = _time_epochs(make_cfg(1.0), ds.train, nbr, n_timed)
    eps_dp_fused = _time_epochs(make_cfg(1.0, use_pallas=True), ds.train, nbr,
                                n_timed)
    base_fused = _time_epochs(make_cfg(0.0, use_pallas=True), ds.train, nbr,
                              n_timed)

    res = {
        "config": {
            "n_users": ds.n_users, "n_items": ds.n_items, "dim": 10,
            "n_train": int(len(ds.train)), "epochs": epochs,
            "delta": DELTA, "clip": CLIP, "sigma_grid": list(SIGMA_GRID),
            "audit_epochs": audit_epochs,
        },
        "frontier": frontier,
        "attack_advantage_monotone_nonincreasing": bool(
            all(a2 <= a1 + 0.05 for a1, a2 in zip(adv, adv[1:]))
            and all(a2 <= a1 + 0.05 for a1, a2 in zip(mem, mem[1:]))),
        "epochs_per_sec": {
            "sparse_scan": eps_plain,
            "dp_jnp": eps_dp_jnp,
            "dp_fused_pallas": eps_dp_fused,
            "sparse_scan_pallas": base_fused,
        },
        "dp_overhead_fused_vs_pallas_base": base_fused / eps_dp_fused - 1.0,
        "dp_overhead_jnp_vs_base": eps_plain / eps_dp_jnp - 1.0,
    }
    common.save_json("BENCH_privacy", res)   # mirrors to repo root
    return res


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
