"""§Perf before/after table from dry-run artifacts (baseline vs --opt/--sync
variants)."""
from __future__ import annotations

import json
import pathlib

from benchmarks import roofline

DRYRUN = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"

PAIRS = [
    # (arch, shape, baseline tag, variant tag, label)
    ("jamba-1.5-large-398b", "decode_32k", "pod__allreduce",
     "pod__allreduce__serve_ws", "A1 weight-stationary decode"),
    ("jamba-1.5-large-398b", "long_500k", "pod__allreduce",
     "pod__allreduce__serve_ws", "A2 weight-stationary long-context"),
    ("qwen1.5-4b", "train_4k", "pod__allreduce",
     "pod__allreduce__dp", "B1 pure-DP layout (refuted)"),
    ("qwen1.5-4b", "train_4k", "pod__allreduce",
     "pod__gossip", "B2 DMF gossip sync (paper technique)"),
    ("qwen1.5-4b", "train_4k", "pod__allreduce",
     "pod__allreduce__gossip_d1", "B3 gossip D=1 mixing"),
    ("deepseek-v2-236b", "prefill_32k", "pod__allreduce",
     "pod__allreduce__tri", "C triangular causal schedule"),
    # --- extended sweep (beyond the 3 required hillclimbs) ---
    ("deepseek-v2-236b", "decode_32k", "pod__allreduce",
     "pod__allreduce__serve_ws", "X1 serve_ws on deepseek-236b"),
    ("deepseek-v2-lite-16b", "decode_32k", "pod__allreduce",
     "pod__allreduce__serve_ws", "X2 serve_ws on deepseek-lite"),
    ("minitron-4b", "train_4k", "pod__allreduce",
     "pod__allreduce__gossip_d1", "X3 gossip D=1 on minitron"),
    ("deepseek-v2-236b", "train_4k", "pod__allreduce",
     "pod__allreduce__tri", "X4 tri on deepseek-236b train"),
    ("yi-34b", "prefill_32k", "pod__allreduce",
     "pod__allreduce__tri", "X5 tri on yi-34b prefill"),
]


def load(arch, shape, tag):
    p = DRYRUN / f"{arch}__{shape}__{tag}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    if "error" in rec or "skipped" in rec:
        return None
    return roofline.analyze(rec)


def main():
    rows = []
    for arch, shape, base_tag, var_tag, label in PAIRS:
        b = load(arch, shape, base_tag)
        v = load(arch, shape, var_tag)
        if not b or not v:
            rows.append((label, arch, shape, "MISSING", "", "", "", ""))
            continue

        def bound(r):
            return max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])

        speedup = bound(b) / max(bound(v), 1e-12)
        rows.append((
            label, arch, shape,
            f"{b['t_compute_s']:.2e}/{b['t_memory_s']:.2e}/{b['t_collective_s']:.2e}",
            f"{v['t_compute_s']:.2e}/{v['t_memory_s']:.2e}/{v['t_collective_s']:.2e}",
            f"{b['dominant']}→{v['dominant']}",
            f"{speedup:.1f}x",
            f"MFU bound {b['mfu_upper_bound']:.2f}→{v['mfu_upper_bound']:.2f}",
        ))
    return rows


def render(rows):
    out = [
        "| change | arch × shape | before (C/M/X s) | after (C/M/X s) | "
        "dominant | step bound | MFU bound |",
        "|---|---|---|---|---|---|---|",
    ]
    for label, arch, shape, b, v, dom, sp, mfu in rows:
        out.append(f"| {label} | {arch} × {shape} | {b} | {v} | {dom} | {sp} | {mfu} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(main()))
