"""DMF-at-pod-scale example: train the same tiny LM with (a) centralized
all-reduce DP and (b) the paper's gossip protocol (per-learner replicas,
D-hop ring mixing, personal-parameter partition), and compare loss curves
plus learner consensus.

Needs >1 host device:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/decentralized_lm.py --steps 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import gossip as gossip_lib
from repro.data.lm_pipeline import LMDataConfig, SyntheticLM
from repro.launch.train import make_train_step
from repro.models import config as mc
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--walk-length", type=int, default=2)
    args = ap.parse_args()

    if len(jax.devices()) < 4:
        raise SystemExit(
            "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
        )
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev // 2, 2), ("data", "model"))
    cfg = mc.reduced(
        registry.get_config("qwen1.5-4b"), n_kv_heads=2, vocab_size=256,
        d_model=128, d_ff=256, n_heads=4, head_dim=32,
    )
    data = SyntheticLM(LMDataConfig(vocab_size=256, seq_len=64, batch_size=16))

    curves = {}
    for sync in ["allreduce", "gossip"]:
        gcfg = gossip_lib.GossipConfig(
            learner_axis="data", walk_length=args.walk_length)
        step, init_fn, _ = make_train_step(
            cfg, mesh, adamw(3e-3), sync=sync, gossip=gcfg)
        state = init_fn(jax.random.PRNGKey(0))
        losses = []
        for i in range(args.steps):
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = step(state, b)
            losses.append(float(m["loss"]))
            if i % 10 == 0:
                extra = (f" consensus_err={float(m['consensus_err']):.3f}"
                         if "consensus_err" in m else "")
                print(f"[{sync:9s}] step {i:3d} loss {losses[-1]:.4f}{extra}")
        curves[sync] = losses

    print("\nfinal loss: allreduce=%.4f gossip=%.4f" % (
        curves["allreduce"][-1], curves["gossip"][-1]))
    gap = curves["gossip"][-1] - curves["allreduce"][-1]
    print(f"gossip-vs-centralized gap: {gap:+.4f} "
          f"(paper's claim: decentralized training tracks centralized; "
          f"collective traffic is neighbor-only collective-permutes)")


if __name__ == "__main__":
    main()
