"""Serving example: the decentralized POI serving engine end-to-end.

Train DMF (Alg. 1), build the city-bucketed candidate index (paper Fig. 2:
check-ins concentrate in the home city), then drive a request stream
through the batched `ServingEngine` — each request scores only its
home-city bucket with the learner's own factors (u_i, p^i + q^i) via the
fused gather→score→top-k Pallas kernel, one compiled dispatch per
microbatch. Finally stream a few held-out check-ins through the online
refresh and watch the served factors track them without retraining.

    PYTHONPATH=src python examples/poi_serving.py --requests 256 --k 10
"""
import argparse

import numpy as np

from repro.core import dmf, graph, metrics
from repro.data import synthetic_poi
from repro.kernels import ref
from repro.serving import (OnlineConfig, ServingConfig, ServingEngine,
                           index_from_dataset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--microbatch", type=int, default=64)
    args = ap.parse_args()

    ds = synthetic_poi.foursquare_like(reduced=True)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    print("training DMF ...")
    res = dmf.fit(cfg, ds.train, nbr, epochs=args.epochs)

    index = index_from_dataset(ds)
    print(f"candidate index: {index.n_buckets} city buckets, cap={index.cap} "
          f"(J={ds.n_items}), {index.n_truncated_buckets} truncated")

    engine = ServingEngine(
        res.state, index,
        ServingConfig(microbatch=args.microbatch, k=args.k),
        train=ds.train, nbr=nbr, dmf_cfg=cfg,
    )
    rng = np.random.default_rng(0)
    users = rng.integers(0, ds.n_users, args.requests)
    engine.recommend(users[: args.microbatch])        # warm/compile
    engine.stats.reset()

    vals, recs, flags = engine.recommend(users, return_flags=True)
    lat = engine.stats.latency_percentiles()
    print(f"{args.requests} requests in {engine.stats.n_dispatches} "
          f"microbatch dispatches: {engine.requests_per_sec:.0f} req/s, "
          f"p50={lat['p50_ms']:.1f} ms/batch")

    test_mask = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.test)
    filled = recs >= 0
    hits = (np.take_along_axis(test_mask[users], np.maximum(recs, 0), 1)
            & filled).sum()
    print(f"P@{args.k} over requests: {hits / recs.size:.4f}")
    print("sample recommendation for user", int(users[0]), ":", recs[0][:5])

    # engine == dense-oracle spot check (kernel streaming vs lax.top_k).
    # Cold users (no train check-ins) get the flagged popularity slate
    # instead of factor scores — compare the factor path on the rest.
    import jax.numpy as jnp
    sub = users[:16]
    v_ref, i_ref = ref.serve_topk_ref(
        jnp.asarray(res.state.U[sub]),
        jnp.asarray((res.state.P + res.state.Q)[sub]),
        jnp.asarray(index.bucket_items[index.user_bucket[sub]]),
        jnp.asarray(np.asarray(engine.seen)[sub]), args.k)
    warm = ~flags[:16]
    assert warm.any(), "all spot-check users were cold"
    assert (recs[:16][warm] == np.asarray(i_ref)[warm]).all(), \
        "engine != dense oracle"
    assert (vals[:16][warm] == np.asarray(v_ref)[warm]).all(), \
        "engine values != oracle"
    print(f"engine == dense oracle on {int(warm.sum())}/16 factor-scored "
          f"requests (indices and values): OK; "
          f"{int(flags.sum())}/{args.requests} requests served the flagged "
          f"popularity fallback")

    # online refresh: stream held-out check-ins, served loss tracks them
    events = ds.test[: min(64, len(ds.test))]
    before = dmf.test_loss(engine.state, events)
    report = engine.ingest(events, OnlineConfig(steps=3))
    after = dmf.test_loss(engine.state, events)
    print(f"online refresh: {report.n_events} check-ins, "
          f"{len(report.affected_users)} users affected, "
          f"{len(report.touched_users)} factor rows touched; "
          f"loss on streamed events {before:.4f} -> {after:.4f}")
    assert after < before, "online refresh failed to track streamed events"


if __name__ == "__main__":
    main()
