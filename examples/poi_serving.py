"""Serving example: batched POI recommendation requests against a trained
DMF model, scored by the Pallas top-k kernel (kernels/topk_scores.py).

Each "request" is a user id; the server gathers that learner's own factors
(u_i, p^i + q^i) — in production these live on-device; here the simulation
holds them in one process — and returns k unseen POIs.

    PYTHONPATH=src python examples/poi_serving.py --requests 64 --k 10
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import dmf, graph, metrics
from repro.data import synthetic_poi
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=40)
    args = ap.parse_args()

    ds = synthetic_poi.foursquare_like(reduced=True)
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    M = graph.walk_propagation_matrix(W, gcfg)
    cfg = dmf.DMFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10,
                        beta=0.1, gamma=0.01)
    print("training DMF ...")
    res = dmf.fit(cfg, ds.train, M, epochs=args.epochs)

    train_mask = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.train)
    rng = np.random.default_rng(0)
    batch_users = rng.integers(0, ds.n_users, args.requests)

    # batched request: each user scores with their OWN item factors
    U_batch = res.state.U[batch_users]                                 # (R, K)
    V_batch = res.state.P[batch_users] + res.state.Q[batch_users]      # (R, J, K)
    mask = jnp.asarray(train_mask[batch_users])

    t0 = time.perf_counter()
    hits = 0
    test_mask = metrics.masks_from_interactions(ds.n_users, ds.n_items, ds.test)
    recs = []
    vals_loop = []
    for r in range(args.requests):  # per-learner serving (decentralized!)
        vals, idx = ops.recommend_topk(
            U_batch[r][None], V_batch[r], mask[r][None], args.k
        )
        recs.append(np.asarray(idx)[0])
        vals_loop.append(np.asarray(vals)[0])
        hits += test_mask[batch_users[r], np.asarray(idx)[0]].sum()
    dt = time.perf_counter() - t0
    print(f"{args.requests} requests in {dt*1e3:.1f} ms "
          f"({dt/args.requests*1e3:.2f} ms/req, interpret-mode kernel)")
    print(f"P@{args.k} over requests: "
          f"{hits / (args.requests * args.k):.4f}")
    print("sample recommendation for user", int(batch_users[0]), ":", recs[0][:5])

    # same requests, one batched kernel call: per-user factors streamed
    # through the running top-k (the (R, J) score matrix never materializes)
    ops.recommend_topk_peruser(U_batch, V_batch, mask, args.k)  # warm/compile
    t0 = time.perf_counter()
    vals_b, idx_b = ops.recommend_topk_peruser(U_batch, V_batch, mask, args.k)
    dt_b = time.perf_counter() - t0
    # indices can differ at score ties / last-ulp; the score lists must match
    np.testing.assert_allclose(np.asarray(vals_b), np.stack(vals_loop),
                               rtol=1e-5, atol=1e-6)
    print(f"batched: {args.requests} requests in one call, {dt_b*1e3:.1f} ms "
          f"({dt_b/args.requests*1e3:.2f} ms/req)")


if __name__ == "__main__":
    main()
