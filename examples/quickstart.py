"""Quickstart: decentralized POI recommendation with DMF in ~40 lines.

Builds a synthetic city-world, the geographic user graph (Eq. 2), the
random-walk propagation matrix (Eqs. 3-4), trains DMF (Alg. 1) and prints
P@k/R@k against centralized MF.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import baselines, dmf, graph
from repro.data import synthetic_poi


def main():
    # 1. data — users/POIs clustered into cities, geographic coordinates
    ds = synthetic_poi.foursquare_like(reduced=True)
    print(f"users={ds.n_users} POIs={ds.n_items} "
          f"train={len(ds.train)} test={len(ds.test)}")

    # 2. the user adjacency graph from geography (same city, N nearest),
    #    exported as the compact D-hop neighbor table each learner ships to
    gcfg = graph.GraphConfig(n_neighbors=2, walk_length=3)
    W = graph.build_adjacency(ds.user_coords, ds.user_city, gcfg)
    nbr = graph.walk_neighbor_table(W, gcfg)   # includes line-11 self term
    print(f"max gradient fan-out 1+|N^D(i)| = {nbr.idx.shape[1]}")

    # 3. decentralized training (vectorized Alg. 1, one scan per epoch)
    cfg = dmf.DMFConfig(
        n_users=ds.n_users, n_items=ds.n_items, dim=10,
        alpha=0.1, beta=0.1, gamma=0.01, lr=0.1, neg_samples=3,
    )
    res = dmf.fit(cfg, ds.train, nbr, epochs=60, test=ds.test)
    print(f"train loss {res.train_losses[0]:.4f} -> {res.train_losses[-1]:.4f}")

    # 4. evaluate — and compare with centralized MF
    ev = dmf.evaluate(res.state, ds.train, ds.test, ds.n_users, ds.n_items)
    print("DMF:", {k: round(v, 4) for k, v in ev.items()})
    mfc = baselines.MFConfig(n_users=ds.n_users, n_items=ds.n_items, dim=10)
    st, _ = baselines.fit_mf(mfc, ds.train, epochs=60)
    ev_mf = baselines.evaluate_mf(st, ds.train, ds.test, ds.n_users, ds.n_items)
    print("MF :", {k: round(v, 4) for k, v in ev_mf.items()})
    assert ev["R@10"] > ev_mf["R@10"], "DMF should beat centralized MF"
    print("OK — decentralized beats centralized on locality-structured data")


if __name__ == "__main__":
    main()
