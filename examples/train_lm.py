"""End-to-end driver (deliverable b): train a ~100M-param decoder for a few
hundred steps on CPU with the full production stack — config, data pipeline,
AdamW, checkpointing, cosine schedule — using any --arch family reduced to
~100M params.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --arch qwen1.5-4b
    PYTHONPATH=src python examples/train_lm.py --steps 50 --d-model 256  # quick
"""
import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.data.lm_pipeline import LMDataConfig, SyntheticLM
from repro.models import config as mc
from repro.models import transformer
from repro.optim import adamw, apply_updates, linear_warmup_cosine
from repro.utils.tree import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=registry.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--n-layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    base = registry.get_config(args.arch)
    cfg = mc.reduced(
        base,
        d_model=args.d_model,
        n_layers=args.n_layers * len(base.period),
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, min(base.n_kv_heads, args.d_model // 128)),
        d_ff=0 if base.n_routed_experts and not base.ssm_d_state else args.d_model * 4,
        vocab_size=args.vocab,
        loss_chunk=128,
    )
    params, _ = transformer.init_params(cfg, jax.random.PRNGKey(0))
    n = tree_size(params)
    print(f"arch={cfg.name} params={n/1e6:.1f}M layers={cfg.n_layers} "
          f"d={cfg.d_model}")

    opt = adamw(linear_warmup_cosine(3e-3, 20, args.steps), weight_decay=0.01)
    opt_state = opt.init(params)
    data = SyntheticLM(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len, batch_size=args.batch))

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(transformer.loss_fn)(params, batch, cfg)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    ck = pathlib.Path(args.ckpt_dir)
    t0 = time.time()
    for i in range(args.steps):
        b = data.batch(i, n_codebooks=cfg.n_codebooks)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss = step(params, opt_state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq_len * (i + 1) / (time.time() - t0)
            print(f"step {i:4d} loss {float(loss):.4f} tok/s {tok_s:,.0f}")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            ckpt.save(ck / f"step_{i}", params, step=i)
    ckpt.save(ck / f"step_{args.steps}", params, step=args.steps)
    print(f"checkpoints in {ck}; latest step {ckpt.latest_step(ck)}")


if __name__ == "__main__":
    main()
